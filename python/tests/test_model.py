"""L2 correctness: model shapes, KV-cache decode consistency, the
gradient-accumulation equivalence the paper's §4.3 pipeline rests on,
and optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jnp.int32(7))


def toks(key, b, t, vocab=None):
    return jax.random.randint(jax.random.PRNGKey(key), (b, t), 0, vocab or CFG.vocab)


def test_param_spec_matches_init(params):
    for name, shape in M.param_spec(CFG):
        assert params[name].shape == shape, name
        assert params[name].dtype == jnp.float32
    assert set(params) == set(M.PARAM_NAMES)


def test_params_roundtrip(params):
    flat = M.params_to_list(params)
    back = M.list_to_params(flat)
    for n in M.PARAM_NAMES:
        assert back[n] is params[n]


def test_init_statistics(params):
    # GPT-2 style: weights ~ N(0, 0.02); norms are ones.
    std = float(jnp.std(params["wq"]))
    assert 0.015 < std < 0.025
    assert float(jnp.std(params["wo"])) < std  # residual-out downscaled
    np.testing.assert_allclose(params["ln1"], np.ones_like(params["ln1"]))


def test_forward_shape_and_finite(params):
    t = toks(0, 2, CFG.max_seq)
    logits = M.forward(CFG, params, t)
    assert logits.shape == (2, CFG.max_seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_matches_forward(params):
    tp = 8
    t = toks(1, 3, tp)
    last, kc, vc = M.prefill(CFG, params, t)
    full = M.forward(CFG, params, t)
    np.testing.assert_allclose(last, full[:, -1], atol=1e-5, rtol=1e-5)
    assert kc.shape == (CFG.n_layers, 3, CFG.n_heads, CFG.max_seq, CFG.d_head)
    # cache beyond the prompt is untouched (zeros)
    np.testing.assert_allclose(kc[:, :, :, tp:], 0.0)


def test_incremental_decode_matches_full_forward(params):
    """prefill + N decode steps == full-context forward, step by step."""
    tp, n_steps = 6, 5
    seq = toks(2, 2, tp + n_steps)
    logits, kc, vc = M.prefill(CFG, params, seq[:, :tp])
    for i in range(n_steps):
        pos = tp + i
        full = M.forward(CFG, params, seq[:, :pos])
        np.testing.assert_allclose(logits, full[:, -1], atol=1e-4, rtol=1e-4)
        logits, kc, vc = M.decode_step(CFG, params, kc, vc, seq[:, pos], jnp.int32(pos))
    full = M.forward(CFG, params, seq)
    np.testing.assert_allclose(logits, full[:, -1], atol=1e-4, rtol=1e-4)


def test_token_logprobs_are_valid(params):
    t = toks(3, 2, CFG.max_seq)
    tgt = toks(4, 2, CFG.max_seq)
    lp = M.token_logprobs(CFG, params, t, tgt)
    assert lp.shape == (2, CFG.max_seq)
    assert bool(jnp.all(lp <= 0.0))


def _batch(key, b=4):
    t = CFG.max_seq
    tokens = toks(key, b, t)
    targets = toks(key + 1, b, t)
    adv = jax.random.normal(jax.random.PRNGKey(key + 2), (b, t))
    mask = (jax.random.normal(jax.random.PRNGKey(key + 3), (b, t)) > -0.7).astype(jnp.float32)
    return tokens, targets, adv, mask


def test_ga_equivalence(params):
    """THE pipeline invariant (§4.3): sum of per-micro-batch grads, scaled
    by token share, equals the full-batch gradient. The paper's claim
    'gradient accumulation across micro batches maintains mathematical
    equivalence with full batch updates' — verified numerically.

    Our grad_step uses masked-*mean* per call, so equivalence holds when
    micro batches are reweighted by their mask mass; the L3 orchestrator
    does exactly this (see rust training::trainer docs).
    """
    tokens, targets, adv, mask = _batch(10, b=4)
    olp = M.token_logprobs(CFG, params, tokens, targets)

    full_grads, *_ = M.grad_step(CFG, params, tokens, targets, adv, olp, olp, mask)

    acc = M.zeros_like_params(CFG)
    total_mass = float(jnp.sum(mask))
    for lo in (0, 2):
        sl = slice(lo, lo + 2)
        g, *_ = M.grad_step(
            CFG, params, tokens[sl], targets[sl], adv[sl], olp[sl], olp[sl], mask[sl]
        )
        w = float(jnp.sum(mask[sl])) / total_mass
        acc = M.accum_grads(acc, {n: g[n] * w for n in M.PARAM_NAMES})

    for n in M.PARAM_NAMES:
        np.testing.assert_allclose(acc[n], full_grads[n], atol=2e-5, rtol=1e-3)


def test_apply_grads_is_adam(params):
    """One apply_grads step == hand-rolled Adam with clip, bias correction."""
    grads = {n: jax.random.normal(jax.random.PRNGKey(50 + i), p.shape) * 0.01
             for i, (n, p) in enumerate(sorted(params.items()))}
    m = M.zeros_like_params(CFG)
    v = M.zeros_like_params(CFG)
    lr = jnp.float32(1e-3)
    new_p, new_m, new_v, count = M.apply_grads(
        CFG, params, m, v, jnp.int32(0), grads, jnp.float32(1.0), lr
    )
    assert int(count) == 1
    gnorm = np.sqrt(sum(float(jnp.sum(g * g)) for g in grads.values()))
    clip = min(1.0, 1.0 / (gnorm + 1e-12))
    for n in M.PARAM_NAMES:
        g = np.array(grads[n]) * clip
        em = 0.1 * g
        ev = 0.05 * g * g
        m_hat = em / (1 - 0.9)
        v_hat = ev / (1 - 0.95)
        expect = np.array(params[n]) - 1e-3 * m_hat / (np.sqrt(v_hat) + CFG.adam_eps)
        np.testing.assert_allclose(new_p[n], expect, atol=1e-6, rtol=1e-5)
        np.testing.assert_allclose(new_m[n], em, atol=1e-7)
        np.testing.assert_allclose(new_v[n], ev, atol=1e-9)


def test_train_step_equals_grad_plus_apply(params):
    """Fused baseline step ≡ decomposed pipeline path with one micro batch."""
    tokens, targets, adv, mask = _batch(20, b=2)
    olp = M.token_logprobs(CFG, params, tokens, targets)
    m = M.zeros_like_params(CFG)
    v = M.zeros_like_params(CFG)
    lr = jnp.float32(1e-3)

    p1, m1, v1, c1, loss1, *_ = M.train_step(
        CFG, params, m, v, jnp.int32(0), tokens, targets, adv, olp, olp, mask, lr
    )
    grads, loss2, *_ = M.grad_step(CFG, params, tokens, targets, adv, olp, olp, mask)
    p2, m2, v2, c2 = M.apply_grads(
        CFG, params, m, v, jnp.int32(0), grads, jnp.float32(1.0), lr
    )
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    for n in M.PARAM_NAMES:
        np.testing.assert_allclose(p1[n], p2[n], atol=1e-7)


def test_policy_improves_on_repeated_batch(params):
    """A few GRPO steps on a fixed advantage signal increase the
    advantage-weighted logprob — the directional sanity check."""
    tokens, targets, _, _ = _batch(30, b=4)
    mask = jnp.ones_like(tokens, jnp.float32)
    # Reward imitating targets: positive advantage everywhere.
    adv = jnp.ones_like(mask)
    p = params
    m = M.zeros_like_params(CFG)
    v = M.zeros_like_params(CFG)
    olp = M.token_logprobs(CFG, p, tokens, targets)
    lp0 = float(jnp.mean(olp))
    count = jnp.int32(0)
    for _ in range(5):
        olp = M.token_logprobs(CFG, p, tokens, targets)
        p, m, v, count, *_ = M.train_step(
            CFG, p, m, v, count, tokens, targets, adv, olp, olp, mask, jnp.float32(5e-3)
        )
    lp1 = float(jnp.mean(M.token_logprobs(CFG, p, tokens, targets)))
    assert lp1 > lp0 + 0.01, (lp0, lp1)


def test_decode_block_matches_sequential_greedy(params):
    """decode_block at ~zero temperature == greedy sequential decode:
    the block path must be numerically the same policy."""
    tp, n = 6, 5
    seq = toks(50, 2, tp)
    logits, kc, vc = M.prefill(CFG, params, seq)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    toks_blk, logps_blk, _, _ = M.decode_block(
        CFG, params, kc, vc, tok0, jnp.int32(tp), jnp.int32(0),
        jnp.float32(1e-6), n,
    )
    # Sequential greedy reference.
    cur, kc2, vc2 = tok0, kc, vc
    expect = []
    for i in range(n):
        lg, kc2, vc2 = M.decode_step(CFG, params, kc2, vc2, cur, jnp.int32(tp + i))
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        expect.append(cur)
    expect = jnp.stack(expect)
    np.testing.assert_array_equal(np.array(toks_blk), np.array(expect))
    # Behaviour logps are valid log-probabilities of the chosen tokens.
    assert bool(jnp.all(logps_blk <= 0.0))


def test_presets_param_counts():
    assert M.PRESETS["m100"].num_params() > 80e6
    assert M.PRESETS["small"].num_params() < 5e6
    for cfg in M.PRESETS.values():
        assert cfg.d_model % cfg.n_heads == 0
