"""Unit tests for scripts/bench_gate.py — the perf regression gate.

Run by the same CI job as the rest of this directory
(`python -m pytest tests -q` from `python/`). The gate is plain-stdlib
Python, so these tests need nothing beyond pytest.
"""

from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

import pytest

_GATE = Path(__file__).resolve().parents[2] / "scripts" / "bench_gate.py"
_spec = importlib.util.spec_from_file_location("bench_gate", _GATE)
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


def run_gate(tmp_path, baseline, current, threshold=0.25, capsys=None):
    """Write the two dicts as JSON files and invoke the gate's main()."""
    bp = tmp_path / "baseline.json"
    cp = tmp_path / "current.json"
    bp.write_text(json.dumps(baseline))
    cp.write_text(json.dumps(current))
    argv = sys.argv
    sys.argv = [
        "bench_gate.py",
        "--baseline",
        str(bp),
        "--current",
        str(cp),
        "--threshold",
        str(threshold),
    ]
    try:
        return bench_gate.main()
    finally:
        sys.argv = argv


def test_passes_within_threshold(tmp_path):
    assert run_gate(tmp_path, {"group": 100.0}, {"group": 110.0}) == 0


def test_fails_beyond_threshold(tmp_path):
    assert run_gate(tmp_path, {"group": 100.0}, {"group": 200.0}) == 1


def test_missing_baseline_file_is_advisory(tmp_path):
    cp = tmp_path / "current.json"
    cp.write_text(json.dumps({"group": 100.0}))
    argv = sys.argv
    sys.argv = [
        "bench_gate.py",
        "--baseline",
        str(tmp_path / "absent.json"),
        "--current",
        str(cp),
    ]
    try:
        assert bench_gate.main() == 0
    finally:
        sys.argv = argv


def test_zero_baseline_is_skipped_not_divided(tmp_path, capsys):
    # A zero baseline (interrupted bench run) must neither crash with a
    # ZeroDivisionError nor produce an inf delta that always gates.
    assert run_gate(tmp_path, {"group": 0.0}, {"group": 100.0}) == 0
    out = capsys.readouterr().out
    assert "unusable baseline" in out


def test_nan_baseline_is_skipped_with_warning(tmp_path, capsys):
    # json.load parses bare NaN into float('nan'); a NaN delta compares
    # False against any threshold, which silently passed before the
    # isfinite guard.
    bp = tmp_path / "baseline.json"
    cp = tmp_path / "current.json"
    bp.write_text('{"group": NaN, "healthy": 100.0}')
    cp.write_text('{"group": 100.0, "healthy": 500.0}')
    argv = sys.argv
    sys.argv = ["bench_gate.py", "--baseline", str(bp), "--current", str(cp)]
    try:
        # The NaN group is skipped; the healthy group still regresses.
        assert bench_gate.main() == 1
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert "unusable baseline" in out
    assert "healthy" in out


def test_nan_current_is_skipped_with_warning(tmp_path, capsys):
    bp = tmp_path / "baseline.json"
    cp = tmp_path / "current.json"
    bp.write_text('{"group": 100.0}')
    cp.write_text('{"group": NaN}')
    argv = sys.argv
    sys.argv = ["bench_gate.py", "--baseline", str(bp), "--current", str(cp)]
    try:
        assert bench_gate.main() == 0
    finally:
        sys.argv = argv
    assert "unusable current" in capsys.readouterr().out


def test_non_timing_keys_never_gate(tmp_path):
    # `speedup` is better-is-higher: halving it must not trip the gate.
    assert (
        run_gate(
            tmp_path,
            {"group": 100.0, "speedup": 4.0},
            {"group": 100.0, "speedup": 2.0},
        )
        == 0
    )


def test_one_sided_keys_are_reported_not_fatal(tmp_path):
    assert run_gate(tmp_path, {"gone": 100.0}, {"new": 100.0}) == 0
