"""Toolchain-independent environment checks. These always collect and
run, so the CI python job never ends with 'no tests ran' (pytest exit
code 5) when JAX is absent — the heavy modules are gated in
conftest.py instead."""

import importlib.util
import os

HERE = os.path.dirname(os.path.abspath(__file__))
PKG = os.path.join(os.path.dirname(HERE), "compile")


def test_compile_package_layout():
    # The AOT pipeline the Rust runtime consumes.
    for rel in (
        "model.py",
        "aot.py",
        os.path.join("kernels", "__init__.py"),
        os.path.join("kernels", "attention.py"),
        os.path.join("kernels", "grpo_loss.py"),
        os.path.join("kernels", "ref.py"),
    ):
        assert os.path.exists(os.path.join(PKG, rel)), rel


def test_gating_is_consistent():
    # If JAX is importable, the JAX-dependent modules must NOT have been
    # ignored (and vice versa) — guards the conftest logic itself.
    import conftest

    jax_present = importlib.util.find_spec("jax") is not None
    ignored = set(conftest.collect_ignore)
    if jax_present:
        assert "test_model.py" not in ignored
        assert "test_aot.py" not in ignored
    else:
        assert {"test_kernels.py", "test_model.py", "test_aot.py"} <= ignored
