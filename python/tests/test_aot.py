"""AOT interchange contract: the manifest + HLO text the Rust runtime
consumes. Builds the tiny preset into a tmpdir once and checks the ABI."""

import json
import os

import pytest

from compile import aot
from compile import model as M

CFG = M.PRESETS["tiny"]
B_ROLL, T_PROMPT, B_GRAD = 2, 8, 2


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(CFG, out, B_ROLL, T_PROMPT, B_GRAD, decode_block=4)
    return out, manifest


EXPECTED = {
    "init", "prefill", "decode", "decode_blk", "logprob", "grad", "accum",
    "apply", "train",
}


def test_all_artifacts_present(built):
    out, manifest = built
    assert set(manifest["artifacts"]) == EXPECTED
    for art in manifest["artifacts"].values():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path)
        text = open(path).read()
        # Parseable HLO text with an entry computation; no 64-bit-id proto.
        assert "ENTRY" in text and "HloModule" in text


def test_manifest_json_serializable(built):
    _, manifest = built
    json.dumps(manifest)  # no numpy leftovers


def test_param_abi(built):
    _, manifest = built
    spec = manifest["param_spec"]
    assert [s["name"] for s in spec] == list(M.PARAM_NAMES)
    assert [tuple(s["shape"]) for s in spec] == [s for _, s in M.param_spec(CFG)]


def test_init_signature(built):
    _, manifest = built
    art = manifest["artifacts"]["init"]
    assert len(art["inputs"]) == 1 and art["inputs"][0]["dtype"] == "int32"
    assert len(art["outputs"]) == len(M.PARAM_NAMES)
    for o, (_, shape) in zip(art["outputs"], M.param_spec(CFG)):
        assert tuple(o["shape"]) == shape


def test_rollout_signatures(built):
    _, manifest = built
    pre = manifest["artifacts"]["prefill"]
    assert tuple(pre["inputs"][-1]["shape"]) == (B_ROLL, T_PROMPT)
    logits, kc, vc = pre["outputs"]
    assert tuple(logits["shape"]) == (B_ROLL, CFG.vocab)
    cache = (CFG.n_layers, B_ROLL, CFG.n_heads, CFG.max_seq, CFG.d_head)
    assert tuple(kc["shape"]) == cache and tuple(vc["shape"]) == cache

    dec = manifest["artifacts"]["decode"]
    names = [i["name"] for i in dec["inputs"]]
    assert names[-4:] == ["k_cache", "v_cache", "token", "pos"]
    assert tuple(dec["outputs"][0]["shape"]) == (B_ROLL, CFG.vocab)

    blk = manifest["artifacts"]["decode_blk"]
    names = [i["name"] for i in blk["inputs"]]
    assert names[-2:] == ["seed", "temperature"]
    # tokens [n, B] + logps [n, B] + two caches
    assert tuple(blk["outputs"][0]["shape"]) == (4, B_ROLL)
    assert blk["outputs"][0]["dtype"] == "int32"
    assert tuple(blk["outputs"][1]["shape"]) == (4, B_ROLL)


def test_training_signatures(built):
    _, manifest = built
    grad = manifest["artifacts"]["grad"]
    n = len(M.PARAM_NAMES)
    assert len(grad["inputs"]) == n + 6
    assert len(grad["outputs"]) == n + 5  # grads + loss/kl/ratio/ent/gnorm
    for o in grad["outputs"][n:]:
        assert tuple(o["shape"]) == ()

    apply_ = manifest["artifacts"]["apply"]
    assert len(apply_["inputs"]) == 4 * n + 3
    assert len(apply_["outputs"]) == 3 * n + 1

    accum = manifest["artifacts"]["accum"]
    assert len(accum["inputs"]) == 2 * n and len(accum["outputs"]) == n

    train = manifest["artifacts"]["train"]
    assert len(train["inputs"]) == 3 * n + 1 + 6 + 1
    assert len(train["outputs"]) == 3 * n + 1 + 5


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()
