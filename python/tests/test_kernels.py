"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the
core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention
from compile.kernels.grpo_loss import grpo_loss, grpo_token_loss

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(1, 70),
    dh=st.sampled_from([4, 8, 16, 64]),
    causal=st.booleans(),
)
def test_attention_matches_ref(b, h, t, dh, causal):
    q = rand(1, (b, h, t, dh))
    k = rand(2, (b, h, t, dh))
    v = rand(3, (b, h, t, dh))
    out = flash_attention(q, k, v, causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    t=st.integers(2, 48),
    bq=st.sampled_from([1, 4, 16, 128]),
    bk=st.sampled_from([1, 4, 16, 128]),
)
def test_attention_block_shape_invariance(t, bq, bk):
    """Tiling is an implementation detail: any block shape, same numbers."""
    q = rand(4, (1, 2, t, 8))
    k = rand(5, (1, 2, t, 8))
    v = rand(6, (1, 2, t, 8))
    base = flash_attention(q, k, v, True)
    out = flash_attention(q, k, v, True, bq, bk)
    np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5)


def test_attention_cross_shapes_decode_window():
    """t_q < t_k: causality is over absolute positions (decode-time use)."""
    t_q, t_k = 4, 20
    q = rand(7, (1, 2, t_q, 8))
    k = rand(8, (1, 2, t_k, 8))
    v = rand(9, (1, 2, t_k, 8))
    out = flash_attention(q, k, v, True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_attention_grad_matches_ref():
    q = rand(10, (2, 2, 24, 8))
    k = rand(11, (2, 2, 24, 8))
    v = rand(12, (2, 2, 24, 8))

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


def test_attention_softmax_rows_sum_to_one_property():
    """With v = identity basis, output rows are convex combinations."""
    t, dh = 16, 16
    q = rand(13, (1, 1, t, dh))
    k = rand(14, (1, 1, t, dh))
    v = jnp.eye(t, dh)[None, None]
    out = flash_attention(q, k, v, True)
    np.testing.assert_allclose(np.sum(np.array(out), axis=-1), np.ones((1, 1, t)), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dtypes(dtype):
    q = rand(15, (1, 2, 16, 8), dtype)
    k = rand(16, (1, 2, 16, 8), dtype)
    v = rand(17, (1, 2, 16, 8), dtype)
    out = flash_attention(q, k, v, True)
    assert out.dtype == dtype
    expect = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32), expect, atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# GRPO loss
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    t=st.integers(1, 300),
    clip=st.sampled_from([0.1, 0.2, 0.3]),
    beta=st.sampled_from([0.0, 0.02, 0.1]),
)
def test_grpo_token_loss_matches_ref(b, t, clip, beta):
    logp = rand(20, (b, t), scale=0.5)
    old = rand(21, (b, t), scale=0.5)
    refp = rand(22, (b, t), scale=0.5)
    adv = rand(23, (b, t))
    mask = (rand(24, (b, t)) > 0).astype(jnp.float32)
    out = grpo_token_loss(logp, old, refp, adv, mask, clip, beta)
    expect = ref.grpo_token_loss_ref(logp, old, refp, adv, mask, clip_eps=clip, kl_beta=beta)
    np.testing.assert_allclose(out, expect, atol=1e-6, rtol=1e-5)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), t=st.integers(1, 130))
def test_grpo_grad_matches_ref(b, t):
    logp = rand(30, (b, t), scale=0.3)
    old = rand(31, (b, t), scale=0.3)
    refp = rand(32, (b, t), scale=0.3)
    adv = rand(33, (b, t))
    mask = (rand(34, (b, t)) > -0.5).astype(jnp.float32)
    g1 = jax.grad(lambda x: grpo_loss(x, old, refp, adv, mask))(logp)
    g2 = jax.grad(lambda x: ref.grpo_loss_ref(x, old, refp, adv, mask))(logp)
    np.testing.assert_allclose(g1, g2, atol=1e-6, rtol=1e-5)


def test_grpo_masked_tokens_contribute_nothing():
    logp = rand(40, (2, 9))
    old = rand(41, (2, 9))
    refp = rand(42, (2, 9))
    adv = rand(43, (2, 9))
    mask = jnp.zeros((2, 9))
    out = grpo_token_loss(logp, old, refp, adv, mask)
    np.testing.assert_allclose(out, np.zeros((2, 9)), atol=0)


def test_grpo_onpolicy_no_kl_equals_negative_adv():
    """On-policy (logp == old == ref): ratio=1, kl=0 → loss_t = -adv."""
    logp = rand(44, (2, 9), scale=0.5)
    adv = rand(45, (2, 9))
    mask = jnp.ones((2, 9))
    out = grpo_token_loss(logp, logp, logp, adv, mask, 0.2, 0.5)
    np.testing.assert_allclose(out, -adv, atol=1e-6, rtol=1e-6)


def test_grpo_clip_caps_positive_update():
    """ratio far above 1+eps with A>0: surrogate is capped at (1+eps)·A."""
    old = jnp.zeros((1, 4))
    logp = jnp.full((1, 4), 2.0)  # ratio = e^2 >> 1.2
    adv = jnp.ones((1, 4))
    mask = jnp.ones((1, 4))
    out = grpo_token_loss(logp, old, old, adv, mask, 0.2, 0.0)
    np.testing.assert_allclose(out, -1.2 * np.ones((1, 4)), atol=1e-6)
    # and the gradient wrt logp is zero there (clipped branch active)
    g = jax.grad(lambda x: grpo_loss(x, old, old, adv, mask, 0.2, 0.0))(logp)
    np.testing.assert_allclose(g, np.zeros((1, 4)), atol=1e-7)


def test_grpo_kl_penalty_nonnegative():
    """k3 estimator is ≥ 0 pointwise, so beta>0 only increases the loss."""
    logp = rand(46, (3, 17), scale=0.7)
    old = rand(47, (3, 17), scale=0.7)
    refp = rand(48, (3, 17), scale=0.7)
    adv = rand(49, (3, 17))
    mask = jnp.ones((3, 17))
    l0 = grpo_token_loss(logp, old, refp, adv, mask, 0.2, 0.0)
    l1 = grpo_token_loss(logp, old, refp, adv, mask, 0.2, 0.3)
    assert np.all(np.array(l1) >= np.array(l0) - 1e-7)
