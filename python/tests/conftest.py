"""Collection gating: the kernel/model/AOT tests import JAX (and
test_kernels additionally Hypothesis) at module scope. On machines
without the accelerator toolchain, importing them would abort pytest
collection with an error; instead we skip those modules cleanly and
leave the environment-level tests (test_env.py) to run everywhere."""

import importlib.util

collect_ignore = []


def _missing(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is None
    except (ImportError, ValueError):
        return True


if _missing("jax"):
    collect_ignore += ["test_kernels.py", "test_model.py", "test_aot.py"]
elif _missing("hypothesis"):
    collect_ignore += ["test_kernels.py"]
