"""Tiled causal flash-attention Pallas kernel (L1 hot-spot).

TPU adaptation of the paper's NPU inference hot path (§3 "Hardware
Adaptation" in DESIGN.md): instead of the vendor SDK's fused attention
op, we express the HBM↔VMEM schedule with ``BlockSpec``s — the grid
iterates over (batch·head, q-block); each grid step streams the K/V rows
for that head through VMEM in ``block_k``-sized chunks with an online
(streaming) softmax, so the full [T, T] score matrix never materializes.
Matmul tiles are kept MXU-shaped (the q-block × d_head and block_k ×
d_head operands feed the 128×128 systolic array; fp32 here, bf16-ready).

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
validated against ``ref.attention_ref`` in python/tests/.

Autodiff: ``pallas_call`` has no automatic VJP, so the public entry point
``flash_attention`` wraps the kernel in ``jax.custom_vjp``. The backward
pass recomputes attention probabilities flash-style from the saved
log-sum-exp row statistics in pure jnp (see ``ref.py`` note) — the
forward hot path is the Pallas kernel, the backward is the analytic
recompute.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic-array edge; for the
# small e2e model (T=128..192, Dh=64) the VMEM footprint per grid step is
#   q-block:  block_q * dh * 4B
#   k/v:      2 * T * dh * 4B   (streamed in block_k chunks by the inner loop)
#   out+acc:  block_q * (dh + 2) * 4B
# ≈ 2·T·dh·4 dominated; at T=8192, dh=128 that is 8 MiB — inside the
# 16 MiB VMEM budget, recorded in DESIGN.md §Perf.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int, causal: bool, q_offset: int):
    """One grid step: one (batch·head, q-block) tile.

    q_ref: [block_q, dh] VMEM tile of queries
    k_ref/v_ref: [t_k, dh] — full key/value rows for this head; the loop
      below realizes the block_k-chunked VMEM schedule.
    o_ref: [block_q, dh] output tile; lse_ref: [block_q] row log-sum-exp
      (saved as residual for the custom_vjp backward).
    """
    block_q, dh = q_ref.shape
    t_k = k_ref.shape[0]
    qi = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32) * (1.0 / math.sqrt(dh))
    # Absolute query positions for the causal mask.
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset

    num_kb = pl.cdiv(t_k, block_k)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_start = kb * block_k
        # dynamic_slice clamps the start so the tail block overlaps the
        # previous one; mask to the *logical* [k_start, k_start+block_k)
        # range so overlapped rows are not double-counted.
        start_eff = jnp.minimum(k_start, max(t_k - block_k, 0))
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[...], start_eff, block_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[...], start_eff, block_k, axis=0)
        s = q @ k_blk.T.astype(jnp.float32)  # [block_q, block_k] on the MXU
        k_pos = start_eff + jax.lax.iota(jnp.int32, block_k)
        valid = (k_pos[None, :] >= k_start) & (k_pos[None, :] < t_k)
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid, s, _NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_blk.astype(jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))

    l_safe = jnp.where(l_i > 0.0, l_i, 1.0)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m_i + jnp.log(l_safe)).astype(lse_ref.dtype)


def _flash_fwd_raw(q, k, v, *, causal, block_q, block_k):
    """Run the Pallas kernel. q,k,v: [B, H, Tq, Dh] / [B, H, Tk, Dh]."""
    b, h, t_q, dh = q.shape
    t_k = k.shape[2]
    bq = min(block_q, t_q)
    bk = min(block_k, t_k)
    grid = (b * h, pl.cdiv(t_q, bq))
    # Cross-attention offset so causality refers to absolute positions when
    # t_q != t_k (decode-time use: queries are the last t_q positions).
    q_offset = t_k - t_q if causal else 0

    qr = q.reshape(b * h, t_q, dh)
    kr = k.reshape(b * h, t_k, dh)
    vr = v.reshape(b * h, t_k, dh)

    kernel = functools.partial(
        _flash_kernel, block_k=bk, causal=causal, q_offset=q_offset
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, t_k, dh), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, t_k, dh), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, bq), lambda bh, qi: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t_q, dh), q.dtype),
            jax.ShapeDtypeStruct((b * h, t_q), jnp.float32),
        ],
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, t_q, dh), lse.reshape(b, h, t_q)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Causal flash attention. q,k,v: [B, H, T, Dh] → [B, H, Tq, Dh]."""
    out, _ = _flash_fwd_raw(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return out


def _fwd(q, k, v, causal, block_q, block_k):
    out, lse = _flash_fwd_raw(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return out, (q, k, v, out, lse)


def _bwd(causal, block_q, block_k, res, g):
    """Flash-style backward: recompute P from the saved LSE (pure jnp).

    Standard flash-attention gradient identities:
      P   = exp(QKᵀ/√d − lse)
      dV  = Pᵀ dO
      dP  = dO Vᵀ
      dS  = P ∘ (dP − rowsum(dO ∘ O))
      dQ  = dS K/√d ;  dK = dSᵀ Q/√d
    """
    q, k, v, out, lse = res
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    t_q, t_k = q.shape[2], k.shape[2]
    if causal:
        qpos = jnp.arange(t_q) + (t_k - t_q)
        mask = qpos[:, None] >= jnp.arange(t_k)[None, :]
        s = jnp.where(mask, s, _NEG_INF)
    p = jnp.exp(s - lse[..., None])
    g32 = g.astype(jnp.float32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, g32)
    dp = jnp.einsum("bhqd,bhkd->bhqk", g32, v.astype(jnp.float32))
    delta = jnp.sum(g32 * out.astype(jnp.float32), axis=-1, keepdims=True)
    ds = p * (dp - delta)
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
