"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth for pytest/hypothesis correctness checks
(``python/tests/``) and are also used as the backward implementations in
the kernels' ``custom_vjp`` rules where an analytic jnp gradient is
simpler than a hand-written backward kernel (documented per-kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Reference scaled-dot-product attention.

    Shapes: q, k, v are [B, H, T, Dh]; returns [B, H, T, Dh].
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        t_q, t_k = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((t_q, t_k), dtype=bool), k=t_k - t_q)
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def grpo_token_loss_ref(
    logp: jax.Array,
    old_logp: jax.Array,
    ref_logp: jax.Array,
    adv: jax.Array,
    mask: jax.Array,
    *,
    clip_eps: float = 0.2,
    kl_beta: float = 0.02,
) -> jax.Array:
    """Per-token GRPO objective (to be *minimized*).

    PPO-style clipped surrogate with the k3 KL estimator against the
    reference policy (DeepSeekMath / GRPO, Shao et al. 2024):

      ratio   = exp(logp - old_logp)
      surr    = min(ratio * A, clip(ratio, 1-eps, 1+eps) * A)
      kl_k3   = exp(ref_logp - logp) - (ref_logp - logp) - 1
      loss_t  = -(surr - beta * kl_k3) * mask

    All inputs share one shape; returns per-token loss, same shape. The
    caller reduces (masked mean).
    """
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    surr = jnp.minimum(ratio * adv, clipped * adv)
    log_r = ref_logp - logp
    kl = jnp.exp(log_r) - log_r - 1.0
    return -(surr - kl_beta * kl) * mask


def grpo_loss_ref(logp, old_logp, ref_logp, adv, mask, *, clip_eps=0.2, kl_beta=0.02):
    """Masked-mean reduction of :func:`grpo_token_loss_ref`."""
    per_tok = grpo_token_loss_ref(
        logp, old_logp, ref_logp, adv, mask, clip_eps=clip_eps, kl_beta=kl_beta
    )
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok) / denom


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Reference RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w
