"""Fused GRPO token-loss Pallas kernel (L1).

The policy-gradient loss hot-spot: for every (sequence, token) position
compute the PPO-clip surrogate with the k3 KL estimator in a single VMEM
pass — ratio/exp, clip, min, KL and masking are fused so the [B·T] loss
tile is produced without materializing the five intermediates that the
naive jnp version creates. The grid tiles the flattened token stream in
``BLOCK``-sized chunks (vector-lane shaped, 8·128 = 1024).

Both forward and backward are Pallas kernels (the gradient is analytic
and elementwise):

  d loss_t / d logp = -(surr' - beta * kl') * mask, where
    surr' = ratio * A        if the unclipped branch is active, else 0
    kl'   = 1 - exp(ref - logp)      (d/d logp of k3)

Validated against ``ref.grpo_token_loss_ref`` (value) and
``jax.grad`` of the reference (gradient) in python/tests/.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _fwd_kernel(logp_ref, old_ref, refp_ref, adv_ref, mask_ref, out_ref, *, clip_eps, kl_beta):
    logp = logp_ref[...]
    ratio = jnp.exp(logp - old_ref[...])
    adv = adv_ref[...]
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    surr = jnp.minimum(ratio * adv, clipped * adv)
    log_r = refp_ref[...] - logp
    kl = jnp.exp(log_r) - log_r - 1.0
    out_ref[...] = -(surr - kl_beta * kl) * mask_ref[...]


def _bwd_kernel(logp_ref, old_ref, refp_ref, adv_ref, mask_ref, g_ref, dlogp_ref, *, clip_eps, kl_beta):
    logp = logp_ref[...]
    ratio = jnp.exp(logp - old_ref[...])
    adv = adv_ref[...]
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    unclipped_active = (ratio * adv) <= (clipped * adv)
    # d surr / d logp: ratio*adv on the unclipped branch, 0 when the min
    # picks the clipped branch (clip has zero grad outside the band; on
    # ties jnp.minimum takes the first arg, matching <=).
    dsurr = jnp.where(unclipped_active, ratio * adv, 0.0)
    dkl = 1.0 - jnp.exp(refp_ref[...] - logp)
    dlogp_ref[...] = -(dsurr - kl_beta * dkl) * mask_ref[...] * g_ref[...]


def _pad_flat(x, n_pad):
    flat = x.reshape(-1)
    return jnp.pad(flat, (0, n_pad)) if n_pad else flat


def _run_elementwise(kernel, args, n, dtype):
    """Tile a flat elementwise kernel over ceil(n/BLOCK) grid steps."""
    block = min(BLOCK, max(n, 1))
    n_blocks = pl.cdiv(n, block)
    n_pad = n_blocks * block - n
    padded = [_pad_flat(a, n_pad) for a in args]
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in padded],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), dtype),
        interpret=True,
    )(*padded)
    return out[:n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def grpo_token_loss(logp, old_logp, ref_logp, adv, mask, clip_eps=0.2, kl_beta=0.02):
    """Per-token GRPO loss; all inputs share one shape, output matches."""
    shape = logp.shape
    n = logp.size
    kern = functools.partial(_fwd_kernel, clip_eps=clip_eps, kl_beta=kl_beta)
    out = _run_elementwise(kern, [logp, old_logp, ref_logp, adv, mask], n, logp.dtype)
    return out.reshape(shape)


def _loss_fwd(logp, old_logp, ref_logp, adv, mask, clip_eps, kl_beta):
    out = grpo_token_loss(logp, old_logp, ref_logp, adv, mask, clip_eps, kl_beta)
    return out, (logp, old_logp, ref_logp, adv, mask)


def _loss_bwd(clip_eps, kl_beta, res, g):
    logp, old_logp, ref_logp, adv, mask = res
    shape = logp.shape
    n = logp.size
    kern = functools.partial(_bwd_kernel, clip_eps=clip_eps, kl_beta=kl_beta)
    dlogp = _run_elementwise(
        kern, [logp, old_logp, ref_logp, adv, mask, g], n, logp.dtype
    ).reshape(shape)
    zeros = jnp.zeros_like(logp)
    # old_logp / ref_logp / adv / mask are treated as constants (stop-grad
    # semantics of the RL objective).
    return dlogp, zeros, zeros, zeros, zeros


grpo_token_loss.defvjp(_loss_fwd, _loss_bwd)


def grpo_loss(logp, old_logp, ref_logp, adv, mask, clip_eps=0.2, kl_beta=0.02):
    """Masked-mean GRPO loss over the token stream (scalar)."""
    per_tok = grpo_token_loss(logp, old_logp, ref_logp, adv, mask, clip_eps, kl_beta)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok) / denom
