"""AOT compile path: lower every L2 entry point to HLO text + manifest.

Run once by ``make artifacts`` (no-op if inputs unchanged); the Rust
runtime (``rust/src/runtime``) loads the HLO text via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU
client. Python is never on the request path.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--preset small]
        [--b-roll 4] [--prompt-len 32] [--b-grad 8]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def spec(shape: Sequence[int], dtype=F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(name: str, s: jax.ShapeDtypeStruct) -> dict:
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class Builder:
    """Lower flat-arg functions, write HLO files, collect the manifest."""

    def __init__(self, cfg: M.ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.artifacts: dict = {}

    def add(
        self,
        name: str,
        fn: Callable,
        inputs: List[Tuple[str, jax.ShapeDtypeStruct]],
    ) -> None:
        in_specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        self.artifacts[name] = {
            "file": fname,
            "inputs": [_spec_json(n, s) for n, s in inputs],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs],
        }
        print(f"  {name:>8}: {len(text) / 1024:.0f} KiB HLO, "
              f"{len(inputs)} in / {len(outs)} out")


def param_inputs(cfg: M.ModelConfig, prefix: str = "") -> List[Tuple[str, jax.ShapeDtypeStruct]]:
    return [(prefix + n, spec(s)) for n, s in M.param_spec(cfg)]


def build(cfg: M.ModelConfig, out_dir: str, b_roll: int, t_prompt: int, b_grad: int, decode_block: int = 16) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(cfg, out_dir)
    t = cfg.max_seq
    l, h, dh, v = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.vocab
    np_ = len(M.PARAM_NAMES)

    cache = spec((l, b_roll, h, t, dh))
    batch = [
        ("tokens", spec((b_grad, t), I32)),
        ("targets", spec((b_grad, t), I32)),
        ("adv", spec((b_grad, t))),
        ("old_logp", spec((b_grad, t))),
        ("ref_logp", spec((b_grad, t))),
        ("mask", spec((b_grad, t))),
    ]

    # --- init ---------------------------------------------------------
    def init_fn(seed):
        return tuple(M.params_to_list(M.init_params(cfg, seed)))

    b.add("init", init_fn, [("seed", spec((), I32))])

    # --- rollout path ---------------------------------------------------
    def prefill_fn(*args):
        params = M.list_to_params(args[:np_])
        return M.prefill(cfg, params, args[np_])

    b.add("prefill", prefill_fn,
          param_inputs(cfg) + [("tokens", spec((b_roll, t_prompt), I32))])

    def decode_fn(*args):
        params = M.list_to_params(args[:np_])
        kc, vc, token, pos = args[np_:]
        return M.decode_step(cfg, params, kc, vc, token, pos)

    b.add("decode", decode_fn,
          param_inputs(cfg) + [("k_cache", cache), ("v_cache", cache),
                               ("token", spec((b_roll,), I32)), ("pos", spec((), I32))])

    def decode_blk_fn(*args):
        params = M.list_to_params(args[:np_])
        kc, vc, token, pos, seed, temp = args[np_:]
        return M.decode_block(cfg, params, kc, vc, token, pos, seed, temp, decode_block)

    b.add("decode_blk", decode_blk_fn,
          param_inputs(cfg) + [("k_cache", cache), ("v_cache", cache),
                               ("token", spec((b_roll,), I32)), ("pos", spec((), I32)),
                               ("seed", spec((), I32)), ("temperature", spec(()))])

    # --- eval path (old/ref logprobs over whole sequences) --------------
    def logprob_fn(*args):
        params = M.list_to_params(args[:np_])
        tokens, targets = args[np_:]
        return (M.token_logprobs(cfg, params, tokens, targets),)

    b.add("logprob", logprob_fn,
          param_inputs(cfg) + [("tokens", spec((b_grad, t), I32)),
                               ("targets", spec((b_grad, t), I32))])

    # --- training path ---------------------------------------------------
    def grad_fn(*args):
        params = M.list_to_params(args[:np_])
        grads, loss, kl, ratio, ent, gnorm = M.grad_step(cfg, params, *args[np_:])
        return tuple(M.params_to_list(grads)) + (loss, kl, ratio, ent, gnorm)

    b.add("grad", grad_fn, param_inputs(cfg) + batch)

    def accum_fn(*args):
        acc = M.list_to_params(args[:np_])
        grads = M.list_to_params(args[np_:])
        return tuple(M.params_to_list(M.accum_grads(acc, grads)))

    b.add("accum", accum_fn,
          param_inputs(cfg, "acc_") + param_inputs(cfg, "g_"))

    def apply_fn(*args):
        p = M.list_to_params(args[:np_])
        m = M.list_to_params(args[np_:2 * np_])
        vv = M.list_to_params(args[2 * np_:3 * np_])
        count = args[3 * np_]
        acc = M.list_to_params(args[3 * np_ + 1:4 * np_ + 1])
        scale, lr = args[4 * np_ + 1:]
        new_p, new_m, new_v, count = M.apply_grads(cfg, p, m, vv, count, acc, scale, lr)
        return (tuple(M.params_to_list(new_p)) + tuple(M.params_to_list(new_m))
                + tuple(M.params_to_list(new_v)) + (count,))

    b.add("apply", apply_fn,
          param_inputs(cfg, "p_") + param_inputs(cfg, "m_") + param_inputs(cfg, "v_")
          + [("count", spec((), I32))] + param_inputs(cfg, "acc_")
          + [("scale", spec(())), ("lr", spec(()))])

    def train_fn(*args):
        p = M.list_to_params(args[:np_])
        m = M.list_to_params(args[np_:2 * np_])
        vv = M.list_to_params(args[2 * np_:3 * np_])
        count = args[3 * np_]
        rest = args[3 * np_ + 1:]
        new_p, new_m, new_v, count, loss, kl, ratio, ent, gnorm = M.train_step(
            cfg, p, m, vv, count, *rest
        )
        return (tuple(M.params_to_list(new_p)) + tuple(M.params_to_list(new_m))
                + tuple(M.params_to_list(new_v)) + (count, loss, kl, ratio, ent, gnorm))

    b.add("train", train_fn,
          param_inputs(cfg, "p_") + param_inputs(cfg, "m_") + param_inputs(cfg, "v_")
          + [("count", spec((), I32))] + batch + [("lr", spec(()))])

    manifest = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "d_head": cfg.d_head, "num_params": cfg.num_params(),
            "clip_eps": cfg.clip_eps, "kl_beta": cfg.kl_beta,
        },
        "shapes": {"b_roll": b_roll, "t_prompt": t_prompt, "b_grad": b_grad,
                   "t_train": t, "decode_block": decode_block},
        "param_spec": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
        "artifacts": b.artifacts,
    }
    return manifest


def input_fingerprint() -> str:
    """Hash of the compile-path sources, for Makefile-style staleness."""
    here = os.path.dirname(__file__)
    hasher = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    hasher.update(fh.read())
    return hasher.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--b-roll", type=int, default=4,
                    help="rollout batch = GRPO group size per prefill/decode")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--b-grad", type=int, default=8,
                    help="rows per grad_step execution")
    ap.add_argument("--decode-block", type=int, default=16,
                    help="tokens generated per decode_blk execution")
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    print(f"AOT: preset={args.preset} params={cfg.num_params() / 1e6:.1f}M -> {args.out}")
    manifest = build(cfg, args.out, args.b_roll, args.prompt_len, args.b_grad, args.decode_block)
    manifest["preset"] = args.preset
    manifest["fingerprint"] = input_fingerprint()
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
