"""L2: decoder-only transformer policy + GRPO training step (JAX).

This is the compute graph of one FlexMARL *agent policy*: a small
GPT-style decoder (RMSNorm, RoPE, tied embeddings, scan-over-layers) with

  * ``prefill`` / ``decode_step``  — the rollout-engine inference path
    (KV-cache incremental decoding),
  * ``grad_step`` / ``accum_grads`` / ``apply_grads`` — the training-engine
    path, deliberately split so the L3 orchestrator can realize the
    paper's §4.3 micro-batch pipeline: gradients are *computed* per micro
    batch and *cached/accumulated*, and parameters are updated once per
    global batch (gradient accumulation ≡ full-batch update),
  * ``train_step`` — the fused synchronous step used by the baselines.

Everything here is build-time Python: ``aot.py`` lowers each entry point
to HLO text; the Rust runtime loads and executes the artifacts. The L1
Pallas kernels (``kernels/attention.py``, ``kernels/grpo_loss.py``) are
called from the forward pass so they lower into the same HLO.

Functions use *flat* parameter lists (see ``PARAM_NAMES``) because HLO
entry computations take positional array arguments; ``params_to_list`` /
``list_to_params`` convert. The ordering is part of the artifact ABI and
is recorded in ``artifacts/manifest.json``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.grpo_loss import grpo_loss

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for one agent policy."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    max_seq: int = 128  # Tmax: KV-cache capacity == training context
    rope_theta: float = 10000.0
    clip_eps: float = 0.2
    kl_beta: float = 0.02
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def num_params(self) -> int:
        return sum(int(math.prod(s)) for _, s in param_spec(self))


PRESETS: Dict[str, ModelConfig] = {
    # Unit-test sized.
    "tiny": ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=32),
    # e2e default on this single-core container (~3.4M params).
    "small": ModelConfig(),
    # ~25M — mid preset for bigger hosts.
    "base": ModelConfig(vocab=4096, d_model=512, n_layers=6, n_heads=8, d_ff=2048, max_seq=256),
    # ~100M (GPT-2-small class) — the system-prompt reference scale.
    "m100": ModelConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12, d_ff=3072, max_seq=512),
}


# ---------------------------------------------------------------------------
# Parameter pytree <-> flat list ABI
# ---------------------------------------------------------------------------

PARAM_NAMES: Tuple[str, ...] = (
    "tok_emb",  # [V, D] (tied LM head)
    "ln1",      # [L, D]
    "wq",       # [L, D, D]
    "wk",       # [L, D, D]
    "wv",       # [L, D, D]
    "wo",       # [L, D, D]
    "ln2",      # [L, D]
    "w1",       # [L, D, F]
    "w2",       # [L, F, D]
    "ln_f",     # [D]
)


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    v, d, l, f = cfg.vocab, cfg.d_model, cfg.n_layers, cfg.d_ff
    return [
        ("tok_emb", (v, d)),
        ("ln1", (l, d)),
        ("wq", (l, d, d)),
        ("wk", (l, d, d)),
        ("wv", (l, d, d)),
        ("wo", (l, d, d)),
        ("ln2", (l, d)),
        ("w1", (l, d, f)),
        ("w2", (l, f, d)),
        ("ln_f", (d,)),
    ]


def params_to_list(params: Params) -> List[jax.Array]:
    return [params[n] for n in PARAM_NAMES]


def list_to_params(flat) -> Params:
    flat = list(flat)
    assert len(flat) == len(PARAM_NAMES), (len(flat), len(PARAM_NAMES))
    return dict(zip(PARAM_NAMES, flat))


def init_params(cfg: ModelConfig, seed: jax.Array) -> Params:
    """GPT-2-style init: N(0, 0.02), residual-out layers scaled by 1/√(2L)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(PARAM_NAMES))
    out_scale = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    params: Params = {}
    for i, (name, shape) in enumerate(param_spec(cfg)):
        if name.startswith("ln"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = out_scale if name in ("wo", "w2") else 0.02
            params[name] = (jax.random.normal(keys[i], shape) * scale).astype(jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope_freqs(cfg: ModelConfig, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for RoPE. positions: [T] int32 → ([T, Dh/2], ...)."""
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, H, T, Dh]; cos/sin: [T, Dh/2]."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """Full-context training forward. tokens: [B, T] int32 → logits [B, T, V].

    Attention runs through the L1 Pallas flash kernel. Layers are folded
    with ``lax.scan`` over the stacked weights (compile-time/HLO-size win;
    ablation vs unroll in EXPERIMENTS.md §Perf).
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens]  # [B, T, D]
    cos, sin = _rope_freqs(cfg, jnp.arange(t, dtype=jnp.int32))

    def block(x, layer):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = layer
        h = rmsnorm(x, ln1)
        q = _apply_rope(_split_heads(h @ wq, cfg), cos, sin)
        k = _apply_rope(_split_heads(h @ wk, cfg), cos, sin)
        v = _split_heads(h @ wv, cfg)
        att = flash_attention(q, k, v, True)
        x = x + _merge_heads(att) @ wo
        h2 = rmsnorm(x, ln2)
        x = x + (jax.nn.gelu(h2 @ w1) @ w2)
        return x, None

    layers = (
        params["ln1"], params["wq"], params["wk"], params["wv"],
        params["wo"], params["ln2"], params["w1"], params["w2"],
    )
    x, _ = jax.lax.scan(block, x, layers)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["tok_emb"].T  # tied head


def token_logprobs(cfg: ModelConfig, params: Params, tokens: jax.Array, targets: jax.Array) -> jax.Array:
    """log p(target_t | tokens_{<=t}) for every position. [B, T]."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Rollout path: prefill + incremental decode with KV cache
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig, params: Params, tokens: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Process the prompt, build Tmax-padded KV caches.

    tokens: [B, Tp] → (logits_last [B, V], k_cache, v_cache [L, B, H, Tmax, Dh]).
    """
    b, tp = tokens.shape
    tmax = cfg.max_seq
    x = params["tok_emb"][tokens]
    cos, sin = _rope_freqs(cfg, jnp.arange(tp, dtype=jnp.int32))

    def block(x, layer):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = layer
        h = rmsnorm(x, ln1)
        q = _apply_rope(_split_heads(h @ wq, cfg), cos, sin)
        k = _apply_rope(_split_heads(h @ wk, cfg), cos, sin)
        v = _split_heads(h @ wv, cfg)
        att = flash_attention(q, k, v, True)
        x = x + _merge_heads(att) @ wo
        h2 = rmsnorm(x, ln2)
        x = x + (jax.nn.gelu(h2 @ w1) @ w2)
        kc = jnp.zeros((b, cfg.n_heads, tmax, cfg.d_head), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        return x, (kc, vc)

    layers = (
        params["ln1"], params["wq"], params["wk"], params["wv"],
        params["wo"], params["ln2"], params["w1"], params["w2"],
    )
    x, (k_cache, v_cache) = jax.lax.scan(block, x, layers)
    x = rmsnorm(x[:, -1, :], params["ln_f"])  # last position only
    logits = x @ params["tok_emb"].T
    return logits, k_cache, v_cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    k_cache: jax.Array,
    v_cache: jax.Array,
    token: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One autoregressive step at position ``pos`` (scalar int32).

    token: [B] int32. Caches are functionally updated; the Rust runtime
    keeps them device-resident across steps so the update stays on-device.

    Decode attention over the cache is a single-query (memory-bound)
    matvec; the Pallas kernel targets the MXU-bound multi-query shapes, so
    here plain jnp is used on purpose (see DESIGN.md §Perf/L2).
    """
    tmax = cfg.max_seq
    x = params["tok_emb"][token][:, None, :]  # [B, 1, D]
    cos, sin = _rope_freqs(cfg, pos[None].astype(jnp.int32))
    # Mask: positions 0..pos valid.
    valid = (jnp.arange(tmax) <= pos)[None, None, None, :]  # [1,1,1,Tmax]

    def block(x, layer):
        ln1, wq, wk, wv, wo, ln2, w1, w2, kc, vc = layer
        h = rmsnorm(x, ln1)
        q = _apply_rope(_split_heads(h @ wq, cfg), cos, sin)  # [B,H,1,Dh]
        k = _apply_rope(_split_heads(h @ wk, cfg), cos, sin)
        v = _split_heads(h @ wv, cfg)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, pos, 0))
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kc) / math.sqrt(cfg.d_head)
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhqk,bhkd->bhqd", probs, vc)
        x = x + _merge_heads(att) @ wo
        h2 = rmsnorm(x, ln2)
        x = x + (jax.nn.gelu(h2 @ w1) @ w2)
        return x, (kc, vc)

    layers = (
        params["ln1"], params["wq"], params["wk"], params["wv"],
        params["wo"], params["ln2"], params["w1"], params["w2"],
        k_cache, v_cache,
    )
    x, (k_cache, v_cache) = jax.lax.scan(block, x, layers)
    x = rmsnorm(x[:, 0, :], params["ln_f"])
    logits = x @ params["tok_emb"].T
    return logits, k_cache, v_cache


def decode_block(
    cfg: ModelConfig,
    params: Params,
    k_cache: jax.Array,
    v_cache: jax.Array,
    token: jax.Array,
    pos: jax.Array,
    seed: jax.Array,
    temperature: jax.Array,
    n_steps: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Generate ``n_steps`` tokens inside ONE executable (§Perf/L2+L3).

    The token-by-token path pays a full host↔device literal round-trip of
    params + KV caches per generated token; folding the sample loop into
    the HLO via ``lax.scan`` (with temperature sampling on-graph, seeded
    by the coordinator) amortizes that cost over the block. Given the
    last accepted token at ``pos``, emits tokens for positions
    pos+1 … pos+n_steps.

    Returns (tokens [n, B], behaviour logps [n, B], k_cache, v_cache).
    """
    key = jax.random.PRNGKey(seed)

    def step(carry, _):
        kc, vc, tok, p, key = carry
        logits, kc, vc = decode_step(cfg, params, kc, vc, tok, p)
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits / jnp.maximum(temperature, 1e-4), axis=-1)
        nxt = nxt.astype(jnp.int32)
        logp_all = jax.nn.log_softmax(logits, axis=-1)
        logp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
        return (kc, vc, nxt, p + 1, key), (nxt, logp)

    (k_cache, v_cache, _, _, _), (toks, logps) = jax.lax.scan(
        step, (k_cache, v_cache, token, pos, key), None, length=n_steps
    )
    return toks, logps, k_cache, v_cache


# ---------------------------------------------------------------------------
# Training path: GRPO gradients, accumulation, Adam
# ---------------------------------------------------------------------------


def grpo_objective(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    targets: jax.Array,
    adv: jax.Array,
    old_logp: jax.Array,
    ref_logp: jax.Array,
    mask: jax.Array,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """Scalar GRPO loss + (kl, ratio_mean, entropy) diagnostics."""
    logits = forward(cfg, params, tokens)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    logp = jnp.take_along_axis(logp_all, targets[..., None], axis=-1)[..., 0]
    loss = grpo_loss(logp, old_logp, ref_logp, adv, mask, cfg.clip_eps, cfg.kl_beta)

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    log_r = ref_logp - logp
    kl = jnp.sum((jnp.exp(log_r) - log_r - 1.0) * mask) / denom
    ratio = jnp.sum(jnp.exp(logp - old_logp) * mask) / denom
    probs = jnp.exp(logp_all)
    ent = jnp.sum(-jnp.sum(probs * logp_all, axis=-1) * mask) / denom
    return loss, (kl, ratio, ent)


def grad_step(cfg: ModelConfig, params: Params, tokens, targets, adv, old_logp, ref_logp, mask):
    """Gradient *computation only* (§4.3: decoupled from parameter update).

    Returns (grads, loss, kl, ratio, entropy, grad_norm).
    """
    (loss, (kl, ratio, ent)), grads = jax.value_and_grad(
        lambda p: grpo_objective(cfg, p, tokens, targets, adv, old_logp, ref_logp, mask),
        has_aux=True,
    )(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))
    return grads, loss, kl, ratio, ent, gnorm


def zeros_like_params(cfg: ModelConfig) -> Params:
    return {n: jnp.zeros(s, jnp.float32) for n, s in param_spec(cfg)}


def accum_grads(acc: Params, grads: Params) -> Params:
    """Gradient-cache accumulation (one micro batch into the agent's cache)."""
    return {n: acc[n] + grads[n] for n in PARAM_NAMES}


def apply_grads(
    cfg: ModelConfig,
    params: Params,
    m: Params,
    v: Params,
    count: jax.Array,
    acc: Params,
    scale: jax.Array,
    lr: jax.Array,
    max_grad_norm: float = 1.0,
) -> Tuple[Params, Params, Params, jax.Array]:
    """Unified parameter update (policy_version += 1 on the L3 side).

    Adam with bias correction + global-norm clipping. ``scale`` is
    1/num_micro_batches so the cached sum equals the full-batch mean —
    the mathematical-equivalence property the paper's pipeline rests on
    (tested in python/tests/test_model.py::test_ga_equivalence).
    """
    g = {n: acc[n] * scale for n in PARAM_NAMES}
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in g.values()))
    clip = jnp.minimum(1.0, max_grad_norm / (gnorm + 1e-12))
    g = {n: x * clip for n, x in g.items()}

    count = count + 1
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    new_p, new_m, new_v = {}, {}, {}
    for n in PARAM_NAMES:
        new_m[n] = b1 * m[n] + (1.0 - b1) * g[n]
        new_v[n] = b2 * v[n] + (1.0 - b2) * jnp.square(g[n])
        m_hat = new_m[n] / bc1
        v_hat = new_v[n] / bc2
        new_p[n] = params[n] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return new_p, new_m, new_v, count


def train_step(cfg: ModelConfig, params, m, v, count, tokens, targets, adv, old_logp, ref_logp, mask, lr):
    """Fused synchronous step (baselines / tests): grad + Adam in one HLO."""
    grads, loss, kl, ratio, ent, gnorm = grad_step(
        cfg, params, tokens, targets, adv, old_logp, ref_logp, mask
    )
    one = jnp.asarray(1.0, jnp.float32)
    new_p, new_m, new_v, count = apply_grads(cfg, params, m, v, count, grads, one, lr)
    return new_p, new_m, new_v, count, loss, kl, ratio, ent, gnorm
