#!/usr/bin/env python3
"""Perf regression gate over the committed BENCH_*.json baselines.

Usage:
    bench_gate.py --baseline rust/BENCH_hotpath.json \
                  --current  BENCH_hotpath.json [--threshold 0.25]

Compares every shared *timing* key (nanosecond values) of `current`
against `baseline` and fails (exit 1) if any named group regressed by
more than `threshold` (default +25%). Non-timing bookkeeping keys
(`speedup`, `grid_runs`, `jobs_n`, `sessions`, `sessions_per_s`) are
ignored — `speedup` and `sessions_per_s` are better-is-higher and
machine-dependent, the others are run metadata.

First-run behaviour: if the baseline file does not exist yet, the gate
prints a warning and exits 0 so the very first CI run can commit the
initial baselines instead of failing on their absence.

Keys present on only one side are reported but never fatal: new
benchmarks have no history, and deleted ones have no present.
"""

from __future__ import annotations

import argparse
import json
import math
import sys

# Bookkeeping keys that are not nanosecond timings and must not gate.
# `sessions` is run metadata and `sessions_per_s` is better-is-higher
# throughput (BENCH_serve.json); gating either as a lower-is-better
# nanosecond timing would invert their meaning. `dist_steps` and
# `dist_workers` are run metadata from BENCH_dist.json.
NON_TIMING_KEYS = {
    "speedup",
    "grid_runs",
    "jobs_n",
    "sessions",
    "sessions_per_s",
    "dist_steps",
    "dist_workers",
}


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: expected a JSON object of name -> nanoseconds")
    return data


def timing_items(data: dict) -> dict:
    return {
        k: float(v)
        for k, v in data.items()
        if k not in NON_TIMING_KEYS and isinstance(v, (int, float))
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--current", required=True, help="freshly produced BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max allowed fractional regression per group (default 0.25 = +25%%)",
    )
    args = ap.parse_args()

    try:
        baseline = timing_items(load(args.baseline))
    except FileNotFoundError:
        print(
            f"::warning::no baseline at {args.baseline} — first run, gate is advisory. "
            f"Commit {args.current} as the baseline to arm it."
        )
        return 0

    current = timing_items(load(args.current))

    regressions = []
    for name in sorted(baseline.keys() & current.keys()):
        base, cur = baseline[name], current[name]
        # A zero, negative, NaN, or infinite baseline cannot anchor a
        # ratio: dividing by it yields inf/NaN deltas, and a NaN delta
        # compares False against the threshold — a silent pass. Such
        # entries come from interrupted/smoke bench runs; skip loudly
        # rather than gate on garbage.
        if not math.isfinite(base) or base <= 0.0:
            print(f"::warning::skipping '{name}': unusable baseline timing ({base})")
            continue
        if not math.isfinite(cur):
            print(f"::warning::skipping '{name}': unusable current timing ({cur})")
            continue
        delta = (cur - base) / base
        marker = "REGRESSED" if delta > args.threshold else "ok"
        print(f"  {marker:9s} {name}: {base:.0f} ns -> {cur:.0f} ns ({delta:+.1%})")
        if delta > args.threshold:
            regressions.append((name, delta))

    for name in sorted(baseline.keys() - current.keys()):
        print(f"::warning::benchmark '{name}' vanished from {args.current}")
    for name in sorted(current.keys() - baseline.keys()):
        print(f"  new       {name}: no baseline yet (not gated)")

    if regressions:
        worst = ", ".join(f"{n} ({d:+.1%})" for n, d in regressions)
        print(f"::error::perf gate: {len(regressions)} group(s) regressed "
              f"beyond +{args.threshold:.0%}: {worst}")
        return 1
    print(f"perf gate passed ({len(baseline.keys() & current.keys())} groups compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
