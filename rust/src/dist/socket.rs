//! Socket transport: workers are child processes, frames cross TCP on
//! localhost (DESIGN.md §14).
//!
//! Framing is a `u32` big-endian length prefix followed by the frame
//! bytes — the frame *content* is byte-identical to the channel
//! transport's (the codec text carries its own magic/version/checksum,
//! so content integrity never depends on the carrier). An unexpected
//! EOF anywhere in a read is a clean disconnect (`Ok(None)`): a worker
//! killed mid-send looks exactly like a worker that hung up, and the
//! coordinator's fault plane reclaims its shard either way.
//!
//! Worker identity is assigned by accept order — arrival order is
//! nondeterministic, but identity flows from the `init` frame and
//! shard assembly is slot-ordered, so run output is unaffected.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

use crate::error::PallasError;

use super::proto::MAX_FRAME_LEN;
use super::transport::{FrameRx, FrameTx, Link, Transport};
use super::worker;

fn io_err(endpoint: &str, what: &str, e: &std::io::Error) -> PallasError {
    PallasError::Transport {
        endpoint: endpoint.to_string(),
        reason: format!("{what}: {e}"),
    }
}

struct SockTx {
    stream: TcpStream,
    endpoint: String,
}

impl FrameTx for SockTx {
    fn send(&mut self, frame: &[u8]) -> Result<(), PallasError> {
        let len = u32::try_from(frame.len()).map_err(|_| PallasError::Transport {
            endpoint: self.endpoint.clone(),
            reason: format!("frame of {} bytes exceeds the u32 length prefix", frame.len()),
        })?;
        self.stream
            .write_all(&len.to_be_bytes())
            .and_then(|_| self.stream.write_all(frame))
            .and_then(|_| self.stream.flush())
            .map_err(|e| io_err(&self.endpoint, "send failed", &e))
    }
}

struct SockRx {
    stream: TcpStream,
    endpoint: String,
}

impl FrameRx for SockRx {
    fn recv(&mut self) -> Result<Option<Vec<u8>>, PallasError> {
        let mut len_buf = [0u8; 4];
        if let Err(e) = self.stream.read_exact(&mut len_buf) {
            return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Ok(None) // peer hung up (or died) between frames
            } else {
                Err(io_err(&self.endpoint, "recv failed", &e))
            };
        }
        let len = u32::from_be_bytes(len_buf);
        if len > MAX_FRAME_LEN {
            return Err(PallasError::Transport {
                endpoint: self.endpoint.clone(),
                reason: format!(
                    "frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap — framing \
                     desynchronized or the peer speaks another protocol"
                ),
            });
        }
        let mut buf = vec![0u8; len as usize];
        if let Err(e) = self.stream.read_exact(&mut buf) {
            return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Ok(None) // peer died mid-send; treat as disconnect
            } else {
                Err(io_err(&self.endpoint, "recv failed", &e))
            };
        }
        Ok(Some(buf))
    }
}

/// Transport whose workers are child processes of this binary
/// (`flexmarl dist-worker --connect ADDR`), connected over TCP on
/// 127.0.0.1. The multi-host shape of the paper's disaggregated
/// rollout plane, scoped to one machine.
pub struct SocketTransport {
    exe: PathBuf,
    children: Vec<Child>,
}

impl SocketTransport {
    /// Spawn workers from an explicit binary path (tests pass
    /// `env!("CARGO_BIN_EXE_flexmarl")`).
    pub fn new(exe: impl Into<PathBuf>) -> SocketTransport {
        SocketTransport {
            exe: exe.into(),
            children: Vec::new(),
        }
    }

    /// Spawn workers from the currently running binary — the CLI path.
    pub fn current_exe() -> Result<SocketTransport, PallasError> {
        let exe = std::env::current_exe().map_err(|e| PallasError::Transport {
            endpoint: "socket".to_string(),
            reason: format!("cannot resolve own binary path for worker spawn: {e}"),
        })?;
        Ok(SocketTransport::new(exe))
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn launch(&mut self, n: usize) -> Result<Vec<Link>, PallasError> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| io_err("socket", "cannot bind localhost listener", &e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| io_err("socket", "cannot read listener address", &e))?;

        for _ in 0..n {
            let spawned = Command::new(&self.exe)
                .arg("dist-worker")
                .arg("--connect")
                .arg(addr.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null()) // run output is the coordinator's alone
                .stderr(Stdio::inherit())
                .spawn();
            match spawned {
                Ok(child) => self.children.push(child),
                Err(e) => {
                    self.close(); // reap the siblings already spawned
                    return Err(io_err("socket", "cannot spawn dist-worker child", &e));
                }
            }
        }

        let mut links = Vec::with_capacity(n);
        for worker in 0..n {
            let (stream, _) = listener
                .accept()
                .map_err(|e| io_err("socket", "accept failed", &e))?;
            stream.set_nodelay(true).ok();
            let endpoint = format!("worker {worker} (socket)");
            let rx_stream = stream
                .try_clone()
                .map_err(|e| io_err(&endpoint, "cannot clone stream", &e))?;
            links.push(Link {
                worker,
                tx: Box::new(SockTx {
                    stream,
                    endpoint: endpoint.clone(),
                }),
                rx: Box::new(SockRx {
                    stream: rx_stream,
                    endpoint,
                }),
            });
        }
        Ok(links)
    }

    fn close(&mut self) {
        // Links are dropped first, so children see EOF and exit; wait()
        // reaps them. kill() first covers the error paths where a child
        // never got (or will never honor) a shutdown.
        for mut child in self.children.drain(..) {
            match child.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    child.kill().ok();
                }
            }
            child.wait().ok();
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.close();
    }
}

/// Entry point of the `dist-worker` subcommand: connect back to the
/// coordinator and run the worker loop until shutdown or disconnect.
pub fn run_connected(addr: &str) -> Result<(), PallasError> {
    let endpoint = format!("coordinator (socket {addr})");
    let stream = TcpStream::connect(addr)
        .map_err(|e| io_err(&endpoint, "cannot connect to coordinator", &e))?;
    stream.set_nodelay(true).ok();
    let rx_stream = stream
        .try_clone()
        .map_err(|e| io_err(&endpoint, "cannot clone stream", &e))?;
    let mut tx = SockTx {
        stream,
        endpoint: endpoint.clone(),
    };
    let mut rx = SockRx {
        stream: rx_stream,
        endpoint: endpoint.clone(),
    };
    worker::run(&mut tx, &mut rx, &endpoint)
}
