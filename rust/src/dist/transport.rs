//! Location-agnostic frame carriers (DESIGN.md §14).
//!
//! The coordinator talks to workers through [`FrameTx`]/[`FrameRx`]
//! pairs and never learns where the peer lives: the same byte frames
//! ([`crate::dist::proto`]) flow over an in-process channel
//! ([`ChannelTransport`]) or a localhost socket
//! ([`crate::dist::socket::SocketTransport`]). A [`Transport`] owns
//! worker placement — it launches N workers and hands back one
//! [`Link`] per worker.
//!
//! Error vocabulary: a broken carrier is `Ok(None)` on receive (clean
//! disconnect — the coordinator's fault plane handles it) and
//! `Err(Transport)` on send; corrupt *content* inside an intact
//! carrier is detected one layer up by frame decoding. The
//! [`CorruptingTransport`] test wrapper flips a payload byte to prove
//! that path stays typed end-to-end.

use std::sync::mpsc::{Receiver, Sender};

use crate::error::PallasError;
use crate::util::pool::WorkerPool;

/// Sending half of a link. `send` failing means the peer is gone —
/// callers treat it like a disconnect, not a crash.
pub trait FrameTx: Send {
    fn send(&mut self, frame: &[u8]) -> Result<(), PallasError>;
}

/// Receiving half of a link. `Ok(None)` is a clean end-of-stream
/// (peer exited or dropped its sender); `Err` is a carrier-level
/// failure with a typed diagnostic.
pub trait FrameRx: Send {
    fn recv(&mut self) -> Result<Option<Vec<u8>>, PallasError>;
}

/// One coordinator⇄worker connection.
pub struct Link {
    pub worker: usize,
    pub tx: Box<dyn FrameTx>,
    pub rx: Box<dyn FrameRx>,
}

/// Worker placement: launch N workers, return their links. The
/// coordinator's protocol logic is identical across implementations —
/// that is the "pluggable, location-agnostic" contract.
pub trait Transport: Send {
    /// Short tag used in endpoint diagnostics ("channel", "socket").
    fn name(&self) -> &'static str;

    /// Start `n` workers and return one link per worker, indexed
    /// `0..n`. Workers send nothing until they receive `init`.
    fn launch(&mut self, n: usize) -> Result<Vec<Link>, PallasError>;

    /// Reap worker resources after the links are dropped (join
    /// threads, wait on children). Must be safe to call twice.
    fn close(&mut self) {}
}

// ---------------------------------------------------------------------------
// ChannelTransport: workers are threads, frames cross std::sync::mpsc
// ---------------------------------------------------------------------------

struct ChanTx(Sender<Vec<u8>>);

impl FrameTx for ChanTx {
    fn send(&mut self, frame: &[u8]) -> Result<(), PallasError> {
        self.0.send(frame.to_vec()).map_err(|_| PallasError::Transport {
            endpoint: "channel".to_string(),
            reason: "peer hung up (receiver dropped)".to_string(),
        })
    }
}

struct ChanRx(Receiver<Vec<u8>>);

impl FrameRx for ChanRx {
    fn recv(&mut self) -> Result<Option<Vec<u8>>, PallasError> {
        // RecvError means every sender is gone: a clean disconnect.
        Ok(self.0.recv().ok())
    }
}

/// In-process transport: each worker is a [`WorkerPool`] job running
/// the ordinary worker loop; frames cross paired mpsc channels. The
/// degenerate placement that keeps the whole protocol testable without
/// processes — and the reference the socket transport must match
/// byte-for-byte.
pub struct ChannelTransport {
    pool: Option<WorkerPool>,
}

impl ChannelTransport {
    pub fn new() -> ChannelTransport {
        ChannelTransport { pool: None }
    }
}

impl Default for ChannelTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for ChannelTransport {
    fn name(&self) -> &'static str {
        "channel"
    }

    fn launch(&mut self, n: usize) -> Result<Vec<Link>, PallasError> {
        let pool = WorkerPool::new(n);
        let mut links = Vec::with_capacity(n);
        for worker in 0..n {
            // Coordinator→worker and worker→coordinator directions.
            let (c2w_tx, c2w_rx) = std::sync::mpsc::channel::<Vec<u8>>();
            let (w2c_tx, w2c_rx) = std::sync::mpsc::channel::<Vec<u8>>();
            pool.submit(move || {
                let mut tx = ChanTx(w2c_tx);
                let mut rx = ChanRx(c2w_rx);
                // A worker failure must not poison the pool (panics
                // would); it is reported on stderr and surfaces to the
                // coordinator as a disconnect when the endpoints drop.
                if let Err(e) = crate::dist::worker::run(&mut tx, &mut rx, "coordinator (channel)")
                {
                    eprintln!("dist worker thread failed: {e}");
                }
            });
            links.push(Link {
                worker,
                tx: Box::new(ChanTx(c2w_tx)),
                rx: Box::new(ChanRx(w2c_rx)),
            });
        }
        self.pool = Some(pool);
        Ok(links)
    }

    fn close(&mut self) {
        // Links are dropped by now, so worker loops see EOF and their
        // jobs finish; shutdown() drains any submit still in flight
        // and joins (the util::pool shutdown contract).
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }
}

// ---------------------------------------------------------------------------
// CorruptingTransport: test wrapper proving corrupt frames stay typed
// ---------------------------------------------------------------------------

/// Wraps another transport and flips one payload byte of the Nth
/// (1-based) worker→coordinator frame on worker 0's link — an
/// in-memory bit-rot injector. The coordinator must surface a typed
/// checksum-mismatch [`PallasError::Transport`], never a panic.
pub struct CorruptingTransport<T: Transport> {
    inner: T,
    nth: u64,
}

struct CorruptingRx {
    inner: Box<dyn FrameRx>,
    nth: u64,
    seen: u64,
}

impl FrameRx for CorruptingRx {
    fn recv(&mut self) -> Result<Option<Vec<u8>>, PallasError> {
        let frame = self.inner.recv()?;
        Ok(frame.map(|mut bytes| {
            self.seen += 1;
            if self.seen == self.nth {
                // Flip the first payload byte (just past the header
                // line) so the header parses but the checksum fails.
                if let Some(nl) = bytes.iter().position(|&b| b == b'\n') {
                    if nl + 1 < bytes.len() {
                        bytes[nl + 1] ^= 0x01;
                    }
                }
            }
            bytes
        }))
    }
}

impl<T: Transport> CorruptingTransport<T> {
    /// Corrupt the `nth` (1-based) inbound frame from worker 0.
    pub fn new(inner: T, nth: u64) -> CorruptingTransport<T> {
        CorruptingTransport { inner, nth }
    }
}

impl<T: Transport> Transport for CorruptingTransport<T> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn launch(&mut self, n: usize) -> Result<Vec<Link>, PallasError> {
        let mut links = self.inner.launch(n)?;
        if let Some(link) = links.iter_mut().find(|l| l.worker == 0) {
            let inner_rx = std::mem::replace(
                &mut link.rx,
                Box::new(ChanRx(std::sync::mpsc::channel().1)),
            );
            link.rx = Box::new(CorruptingRx {
                inner: inner_rx,
                nth: self.nth,
                seen: 0,
            });
        }
        Ok(links)
    }

    fn close(&mut self) {
        self.inner.close();
    }
}
