//! The worker side of the distributed plane: claim shards, generate
//! them, ship results (DESIGN.md §14).
//!
//! A worker is stateless beyond its `init` frame. It runs the same
//! loop whether it lives on a pool thread (channel transport) or in a
//! child process (socket transport):
//!
//! 1. wait for `init` (identity, seed, [`crate::dist::proto::GenSpec`]);
//! 2. send `claim`, wait for `assign`/`shutdown`;
//! 3. on `assign (step, slot)`: generate the query shard, compute its
//!    per-agent index rows, send `result`; goto 2.
//!
//! Disconnects (EOF, send failure) mean the coordinator is gone — the
//! worker exits cleanly rather than erroring, since the coordinator
//! owns run-level failure reporting. Protocol violations and corrupt
//! frames return typed errors; a worker never panics on peer input.

use crate::error::PallasError;
use crate::workload::{Generator, TrajectorySpec};

use super::proto::{decode_frame, encode_frame, Msg};
use super::transport::{FrameRx, FrameTx};

/// Per-agent `(calls, token_sum)` rows for one shard — the worker's
/// contribution to the coordinator's canonical experience-store index.
/// Iteration order (trajectory-major, call order within) matches the
/// coordinator's verification pass exactly, so the f64 sums are
/// bitwise-reproducible on both ends.
pub fn shard_index(trajectories: &[TrajectorySpec], n_agents: usize) -> Vec<(u64, f64)> {
    let mut rows = vec![(0u64, 0.0f64); n_agents];
    for t in trajectories {
        for c in &t.calls {
            rows[c.agent].0 += 1;
            rows[c.agent].1 += c.tokens;
        }
    }
    rows
}

/// Run the worker loop until shutdown, disconnect, or a typed error.
/// `endpoint` names the coordinator link in frame diagnostics.
pub fn run(
    tx: &mut dyn FrameTx,
    rx: &mut dyn FrameRx,
    endpoint: &str,
) -> Result<(), PallasError> {
    let mut frames: u64 = 0;
    let mut next = |rx: &mut dyn FrameRx, n_agents: usize| -> Result<Option<Msg>, PallasError> {
        match rx.recv()? {
            None => Ok(None),
            Some(bytes) => {
                frames += 1;
                decode_frame(&bytes, endpoint, frames, n_agents).map(Some)
            }
        }
    };

    // First frame must be init. Dying before it is a clean exit (the
    // coordinator aborted launch); any other message is a violation.
    let (worker, seed, spec, fail_after) = match next(rx, 0)? {
        None => return Ok(()),
        Some(Msg::Init {
            worker,
            seed,
            spec,
            fail_after,
        }) => (worker, seed, spec, fail_after),
        Some(other) => {
            return Err(PallasError::Protocol {
                expected: "init as the first message".to_string(),
                got: format!("{} before init", other.kind()),
            })
        }
    };

    let wl = spec.to_workload();
    let n_agents = wl.agents.len();
    let generator = Generator::new(&wl, seed);
    let mut assigns: u64 = 0;

    loop {
        if tx.send(&encode_frame(&Msg::Claim { worker })).is_err() {
            return Ok(()); // coordinator gone
        }
        match next(rx, n_agents)? {
            None | Some(Msg::Shutdown) => return Ok(()),
            Some(Msg::Assign { step, slot }) => {
                // Deterministic fault plane: die silently on the
                // configured assign ordinal, exactly like a crash
                // mid-claim — the shard ships nothing and the
                // disconnect returns it to the unclaimed set.
                if fail_after == Some(assigns) {
                    return Ok(());
                }
                assigns += 1;
                let trajectories = generator.query(step as usize, slot as usize);
                let index = shard_index(&trajectories, n_agents);
                let result = Msg::Result {
                    worker,
                    step,
                    slot,
                    trajectories,
                    index,
                };
                if tx.send(&encode_frame(&result)).is_err() {
                    return Ok(()); // coordinator gone
                }
            }
            Some(other) => {
                return Err(PallasError::Protocol {
                    expected: "assign or shutdown".to_string(),
                    got: format!("{} after claim", other.kind()),
                })
            }
        }
    }
}
