//! Distributed coordinator/worker plane (DESIGN.md §14): agent-shard
//! claiming over a pluggable, location-agnostic transport.
//!
//! The paper's rollout plane spreads query generation over disaggregated
//! workers while training consumes a single canonical stream. This
//! module reproduces that split: a *coordinator* owns the canonical
//! experience-store index, the event clock, and shard assignment; N
//! *workers* claim `(step, query-slot)` shards, generate them, and ship
//! results back. The carrier is a [`transport::Transport`] — in-process
//! channels ([`transport::ChannelTransport`]) or child processes over
//! localhost TCP ([`socket::SocketTransport`]) — with one wire format
//! ([`proto`], the checkpoint codec) across both.
//!
//! Determinism contract: run output is **byte-identical** to the
//! single-process scenario path for any worker count and either
//! transport, because
//!
//! 1. a query slot's bits depend only on `(seed, step, slot)`
//!    ([`crate::workload::Generator::query`]), never on which worker
//!    generates it or when;
//! 2. the coordinator assembles slots in slot order, so claim
//!    interleaving cannot reorder output;
//! 3. worker-count bookkeeping goes to stderr only.
//!
//! Fault contract: a worker disconnect (thread exit, child death, EOF
//! mid-send) returns its claimed shard to the unclaimed set and the run
//! completes on the survivors — still byte-identical. Corrupt frames
//! and protocol violations are run-fatal with typed errors; the
//! coordinator never panics on peer behavior.

pub mod proto;
pub mod socket;
pub mod transport;
pub mod worker;

use std::collections::{BTreeSet, VecDeque};
use std::sync::mpsc::{Receiver, Sender};

use crate::config::WorkloadConfig;
use crate::error::PallasError;
use crate::workload::{LenHint, Scenario, StepWorkload, TrajectorySpec, WorkloadSource};

use proto::{decode_frame, encode_frame, GenSpec, Msg};
use transport::{ChannelTransport, FrameTx, Link, Transport};

// ---------------------------------------------------------------------------
// Plan
// ---------------------------------------------------------------------------

/// Which carrier moves frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Workers are pool threads; frames cross in-process channels.
    Channel,
    /// Workers are child processes; frames cross TCP on localhost.
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "channel" => Some(TransportKind::Channel),
            "socket" => Some(TransportKind::Socket),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Socket => "socket",
        }
    }
}

/// Deterministic worker-death injection (the fault plane's dist hook):
/// worker `worker` dies silently on its `after_assigns`-th (0-based)
/// shard assignment. Per-worker counting makes the death point
/// independent of claim interleaving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerFault {
    pub worker: usize,
    pub after_assigns: u64,
}

/// How to distribute a run — the dist analogue of a workload plan,
/// carried by the experiment builder next to `WorkloadPlan`.
#[derive(Debug, Clone, PartialEq)]
pub struct DistPlan {
    pub workers: usize,
    pub transport: TransportKind,
    pub fail: Option<WorkerFault>,
}

impl DistPlan {
    pub fn channel(workers: usize) -> DistPlan {
        DistPlan {
            workers,
            transport: TransportKind::Channel,
            fail: None,
        }
    }

    pub fn socket(workers: usize) -> DistPlan {
        DistPlan {
            workers,
            transport: TransportKind::Socket,
            fail: None,
        }
    }

    pub fn validate(&self) -> Result<(), PallasError> {
        if self.workers == 0 {
            return Err(PallasError::InvalidConfig(
                "dist requires at least one worker (--workers >= 1)".to_string(),
            ));
        }
        if let Some(f) = self.fail {
            if f.worker >= self.workers {
                return Err(PallasError::InvalidConfig(format!(
                    "worker-fail names worker {} but only {} workers are configured",
                    f.worker, self.workers
                )));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// What a pump thread feeds the coordinator: a decoded message from a
/// worker, a clean disconnect, or a run-fatal frame error.
enum Event {
    Msg(usize, Msg),
    Gone(usize),
    Fail(PallasError),
}

/// Live communication state, created lazily on the first pull.
struct Running {
    transport: Box<dyn Transport>,
    /// Sender per worker; `None` once the worker is gone.
    txs: Vec<Option<Box<dyn FrameTx>>>,
    inbox: Receiver<Event>,
    pumps: Vec<std::thread::JoinHandle<()>>,
    /// Workers still connected.
    live: usize,
    dead: Vec<bool>,
    /// Shard currently assigned to each worker (at most one).
    claimed: Vec<Option<(u64, u64)>>,
    /// Workers whose claim arrived when no shard was unclaimed; they
    /// are dispatched first when work appears (next step, or a shard
    /// returned by a death).
    parked: VecDeque<usize>,
}

/// The coordinator as a [`WorkloadSource`]: the engine pulls steps from
/// it exactly as it would from a [`crate::workload::ScenarioSource`],
/// and gets the same bytes — generation just happened elsewhere.
pub struct DistSource {
    shaped: WorkloadConfig,
    scen: Box<dyn Scenario>,
    seed: u64,
    total: usize,
    next: usize,
    plan: DistPlan,
    /// Test seam: a pre-built transport (e.g. corrupting wrapper, or a
    /// socket transport pointing at an explicit binary).
    override_transport: Option<Box<dyn Transport>>,
    state: Option<Running>,
    error: Option<PallasError>,
    /// Event clock: coordinator-processed events, monotone across the
    /// run (claims, results, disconnects).
    clock: u64,
    /// Canonical per-agent experience-store index `(calls, token_sum)`,
    /// folded from verified shard results.
    index: Vec<(u64, f64)>,
    shards: u64,
}

impl DistSource {
    /// `shaped` must already be the scenario-shaped config (the output
    /// of [`crate::workload::scenario::resolve`]), exactly as
    /// [`crate::workload::ScenarioSource::new`] expects.
    pub fn new(
        shaped: WorkloadConfig,
        scen: Box<dyn Scenario>,
        seed: u64,
        total: usize,
        plan: DistPlan,
    ) -> DistSource {
        let n_agents = shaped.agents.len();
        DistSource {
            shaped,
            scen,
            seed,
            total,
            next: 0,
            plan,
            override_transport: None,
            state: None,
            error: None,
            clock: 0,
            index: vec![(0, 0.0); n_agents],
            shards: 0,
        }
    }

    /// Like [`DistSource::new`] but over an explicit transport instead
    /// of one built from `plan.transport`.
    pub fn with_transport(
        shaped: WorkloadConfig,
        scen: Box<dyn Scenario>,
        seed: u64,
        total: usize,
        plan: DistPlan,
        transport: Box<dyn Transport>,
    ) -> DistSource {
        let mut src = DistSource::new(shaped, scen, seed, total, plan);
        src.override_transport = Some(transport);
        src
    }

    /// Events processed so far (the coordinator's event clock).
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Verified shard results folded into the canonical index.
    pub fn shards(&self) -> u64 {
        self.shards
    }

    /// The canonical per-agent `(calls, token_sum)` experience-store
    /// index over every verified shard so far.
    pub fn store_index(&self) -> &[(u64, f64)] {
        &self.index
    }

    fn launch(&mut self) -> Result<Running, PallasError> {
        let mut transport: Box<dyn Transport> = match self.override_transport.take() {
            Some(t) => t,
            None => match self.plan.transport {
                TransportKind::Channel => Box::new(ChannelTransport::new()),
                TransportKind::Socket => Box::new(socket::SocketTransport::current_exe()?),
            },
        };
        let n = self.plan.workers;
        let links = transport.launch(n)?;
        let n_agents = self.shaped.agents.len();
        let tname = transport.name();

        let (ev_tx, inbox) = std::sync::mpsc::channel::<Event>();
        let mut txs: Vec<Option<Box<dyn FrameTx>>> = Vec::with_capacity(n);
        let mut pumps = Vec::with_capacity(n);
        for link in links {
            let Link { worker, tx, rx } = link;
            debug_assert_eq!(worker, txs.len());
            txs.push(Some(tx));
            pumps.push(spawn_pump(
                worker,
                rx,
                ev_tx.clone(),
                format!("worker {worker} ({tname})"),
                n_agents,
            ));
        }
        drop(ev_tx); // pumps hold the only senders: recv errors once all exit

        let mut run = Running {
            transport,
            txs,
            inbox,
            pumps,
            live: n,
            dead: vec![false; n],
            claimed: vec![None; n],
            parked: VecDeque::new(),
        };

        let spec = GenSpec::from_workload(&self.shaped);
        for w in 0..n {
            let init = Msg::Init {
                worker: w,
                seed: self.seed,
                spec: spec.clone(),
                fail_after: self
                    .plan
                    .fail
                    .filter(|f| f.worker == w)
                    .map(|f| f.after_assigns),
            };
            // An init that cannot be delivered means the worker is
            // already gone; its pump will also report the disconnect,
            // and mark_dead is idempotent.
            let delivered = match run.txs[w].as_mut() {
                Some(tx) => tx.send(&encode_frame(&init)).is_ok(),
                None => false,
            };
            if !delivered {
                let mut scratch = BTreeSet::new();
                mark_dead(&mut run, w, &mut scratch);
            }
        }
        Ok(run)
    }

    /// Run one step's claim/assign/result round and assemble the
    /// workload in slot order.
    fn produce(&mut self, run: &mut Running, step: usize) -> Result<StepWorkload, PallasError> {
        let n_queries = self.scen.queries(&self.shaped, self.seed, step);
        let n_agents = self.shaped.agents.len();
        let group_size = self.shaped.group_size;
        let mut slots: Vec<Option<Vec<TrajectorySpec>>> = vec![None; n_queries];
        let mut unclaimed: BTreeSet<u64> = (0..n_queries as u64).collect();
        let mut done = 0usize;

        // Workers parked since the previous step get first claim.
        dispatch(run, step, &mut unclaimed);

        while done < n_queries {
            if run.live == 0 {
                return Err(all_gone(run, self.plan.workers, n_queries - done, step));
            }
            let ev = match run.inbox.recv() {
                Ok(ev) => ev,
                // All pumps exited and the buffer is drained — per-link
                // FIFO means every useful frame was already processed.
                Err(_) => return Err(all_gone(run, self.plan.workers, n_queries - done, step)),
            };
            self.clock += 1;
            match ev {
                // A dead worker's leftover frames are stale, not a
                // violation: per-link FIFO already delivered everything
                // that mattered before its Gone.
                Event::Msg(w, _) if run.dead[w] => {}
                Event::Msg(w, Msg::Claim { worker }) => {
                    if worker != w {
                        return Err(PallasError::Protocol {
                            expected: format!("claim from worker {w} on its own link"),
                            got: format!("claim from worker {worker}"),
                        });
                    }
                    if let Some((s, q)) = run.claimed[w] {
                        return Err(PallasError::Protocol {
                            expected: "claim from an idle worker".to_string(),
                            got: format!(
                                "claim from worker {w} with step {s} slot {q} outstanding"
                            ),
                        });
                    }
                    run.parked.push_back(w);
                    dispatch(run, step, &mut unclaimed);
                }
                Event::Msg(
                    w,
                    Msg::Result {
                        worker,
                        step: rstep,
                        slot,
                        trajectories,
                        index,
                    },
                ) => {
                    if worker != w {
                        return Err(PallasError::Protocol {
                            expected: format!("result from worker {w} on its own link"),
                            got: format!("result from worker {worker}"),
                        });
                    }
                    if run.claimed[w] != Some((rstep, slot)) {
                        return Err(PallasError::Protocol {
                            expected: "result for a claimed shard".to_string(),
                            got: format!("result for step {rstep} slot {slot} from worker {w}"),
                        });
                    }
                    if trajectories.len() != group_size {
                        return Err(PallasError::Protocol {
                            expected: format!("{group_size} trajectories per shard"),
                            got: format!("{} from worker {w}", trajectories.len()),
                        });
                    }
                    // Verify the shipped index rows against the shipped
                    // trajectories (same iteration order as the worker,
                    // hence bitwise f64 equality) before folding them
                    // into the canonical store index.
                    if worker::shard_index(&trajectories, n_agents) != index {
                        return Err(PallasError::Protocol {
                            expected: "index rows matching the shipped trajectories".to_string(),
                            got: format!("diverging rows for step {rstep} slot {slot} from worker {w}"),
                        });
                    }
                    for (row, &(calls, tokens)) in self.index.iter_mut().zip(&index) {
                        row.0 += calls;
                        row.1 += tokens;
                    }
                    run.claimed[w] = None;
                    slots[slot as usize] = Some(trajectories);
                    done += 1;
                    self.shards += 1;
                }
                Event::Msg(w, other) => {
                    return Err(PallasError::Protocol {
                        expected: "claim or result".to_string(),
                        got: format!("{} from worker {w}", other.kind()),
                    });
                }
                Event::Gone(w) => {
                    mark_dead(run, w, &mut unclaimed);
                    dispatch(run, step, &mut unclaimed);
                }
                Event::Fail(e) => return Err(e),
            }
        }

        // Slot-ordered assembly: byte-identical to the monolithic
        // generator whatever the claim interleaving was.
        let trajectories = slots
            .into_iter()
            .flat_map(|s| s.expect("all shards accounted for"))
            .collect();
        Ok(StepWorkload { step, trajectories })
    }

    fn teardown(&mut self) {
        if let Some(mut run) = self.state.take() {
            let shutdown = encode_frame(&Msg::Shutdown);
            for tx in run.txs.iter_mut().flatten() {
                let _ = tx.send(&shutdown);
            }
            run.txs.clear(); // hang up: workers see EOF even if shutdown was lost
            for p in run.pumps.drain(..) {
                let _ = p.join();
            }
            let mut transport = run.transport;
            // close() reaps worker threads/children; a panic crossing
            // Drop would abort, so contain it.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                transport.close()
            }));
        }
    }
}

impl WorkloadSource for DistSource {
    fn next_step(&mut self) -> Option<StepWorkload> {
        if self.error.is_some() {
            return None;
        }
        if self.next >= self.total {
            self.teardown();
            return None;
        }
        if self.state.is_none() {
            match self.launch() {
                Ok(run) => self.state = Some(run),
                Err(e) => {
                    self.error = Some(e);
                    return None;
                }
            }
        }
        let mut run = self.state.take().expect("launched above");
        let step = self.next;
        let produced = self.produce(&mut run, step);
        self.state = Some(run);
        match produced {
            Ok(w) => {
                self.next += 1;
                Some(w)
            }
            Err(e) => {
                self.error = Some(e);
                self.teardown();
                None
            }
        }
    }

    fn len_hint(&self) -> LenHint {
        LenHint::Exact(self.total - self.next)
    }

    fn take_error(&mut self) -> Option<PallasError> {
        self.error.take()
    }

    /// O(1), mirroring [`crate::workload::ScenarioSource`]: shard bits
    /// depend only on `(seed, step, slot)`, so resuming is a cursor
    /// assignment — workers are not even launched yet.
    fn fast_forward(&mut self, n: usize) -> Result<(), PallasError> {
        if self.next != 0 {
            return Err(PallasError::InvalidConfig(format!(
                "fast_forward on a source already at step {}",
                self.next
            )));
        }
        if n > self.total {
            return Err(PallasError::InvalidConfig(format!(
                "cannot resume to step {n}: scenario has {} steps",
                self.total
            )));
        }
        self.next = n;
        Ok(())
    }
}

impl Drop for DistSource {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// One pump per link: decode inbound frames into coordinator events.
/// Exits after reporting a disconnect or a frame error; exits silently
/// if the coordinator hung up first.
fn spawn_pump(
    worker: usize,
    mut rx: Box<dyn transport::FrameRx>,
    ev_tx: Sender<Event>,
    endpoint: String,
    n_agents: usize,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut frames: u64 = 0;
        loop {
            let ev = match rx.recv() {
                Ok(Some(bytes)) => {
                    frames += 1;
                    match decode_frame(&bytes, &endpoint, frames, n_agents) {
                        Ok(msg) => Event::Msg(worker, msg),
                        Err(e) => {
                            let _ = ev_tx.send(Event::Fail(e));
                            return;
                        }
                    }
                }
                Ok(None) => {
                    let _ = ev_tx.send(Event::Gone(worker));
                    return;
                }
                Err(e) => {
                    let _ = ev_tx.send(Event::Fail(e));
                    return;
                }
            };
            if ev_tx.send(ev).is_err() {
                return;
            }
        }
    })
}

/// Idempotent worker-death bookkeeping: drop its sender, return its
/// claimed shard (if any) to the unclaimed set, forget its parking.
fn mark_dead(run: &mut Running, w: usize, unclaimed: &mut BTreeSet<u64>) {
    if run.dead[w] {
        return;
    }
    run.dead[w] = true;
    run.live -= 1;
    run.txs[w] = None;
    if let Some((_, slot)) = run.claimed[w].take() {
        unclaimed.insert(slot);
    }
    run.parked.retain(|&p| p != w);
}

/// Hand unclaimed shards (smallest slot first — determinism by
/// convention, though assembly order never depends on it) to parked
/// workers. A send failure is a death: the shard goes back and the
/// loop moves on to the next parked worker.
fn dispatch(run: &mut Running, step: usize, unclaimed: &mut BTreeSet<u64>) {
    while !unclaimed.is_empty() {
        let Some(w) = run.parked.pop_front() else {
            break;
        };
        if run.dead[w] {
            continue;
        }
        let slot = *unclaimed.iter().next().expect("nonempty");
        unclaimed.remove(&slot);
        let msg = Msg::Assign {
            step: step as u64,
            slot,
        };
        let sent = match run.txs[w].as_mut() {
            Some(tx) => tx.send(&encode_frame(&msg)).is_ok(),
            None => false,
        };
        if sent {
            run.claimed[w] = Some((step as u64, slot));
        } else {
            unclaimed.insert(slot);
            mark_dead(run, w, unclaimed);
        }
    }
}

/// The no-survivors diagnostic: typed, names the transport and the
/// stranded work so the operator knows the run (not a worker) failed.
fn all_gone(run: &Running, workers: usize, missing: usize, step: usize) -> PallasError {
    PallasError::Transport {
        endpoint: format!("all {workers} workers ({})", run.transport.name()),
        reason: format!(
            "every worker is gone with {missing} query shard(s) unassembled at step {step}; \
             cannot make progress"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{scenario, ScenarioSource};
    use transport::CorruptingTransport;

    fn resolved(name: &str) -> (WorkloadConfig, Box<dyn Scenario>) {
        let mut wl = WorkloadConfig::ma();
        wl.scenario = name.to_string();
        scenario::resolve(&wl).unwrap()
    }

    fn drain(src: &mut dyn WorkloadSource) -> Vec<StepWorkload> {
        let mut out = Vec::new();
        while let Some(w) = src.next_step() {
            out.push(w);
        }
        out
    }

    fn reference(name: &str, seed: u64, steps: usize) -> Vec<StepWorkload> {
        let (shaped, scen) = resolved(name);
        drain(&mut ScenarioSource::new(shaped, scen, seed, steps))
    }

    #[test]
    fn channel_dist_is_byte_identical_to_scenario_source() {
        // The tentpole contract, at the source level: any worker count,
        // same bytes — including an open-loop preset whose per-step
        // query count varies.
        for name in ["baseline", "poisson"] {
            let golden = reference(name, 2048, 4);
            for workers in [1usize, 2, 8] {
                let (shaped, scen) = resolved(name);
                let mut src =
                    DistSource::new(shaped, scen, 2048, 4, DistPlan::channel(workers));
                let got = drain(&mut src);
                assert!(src.take_error().is_none());
                // PartialEq on CallSpec is bit-level f64 equality.
                assert_eq!(got, golden, "{name} with {workers} workers");
                assert!(src.shards() > 0);
            }
        }
    }

    #[test]
    fn coordinator_index_matches_the_assembled_workload() {
        let (shaped, scen) = resolved("baseline");
        let n_agents = shaped.agents.len();
        let mut src = DistSource::new(shaped, scen, 2048, 3, DistPlan::channel(2));
        let steps = drain(&mut src);
        assert!(src.take_error().is_none());
        let mut want = vec![(0u64, 0.0f64); n_agents];
        for w in &steps {
            for t in &w.trajectories {
                for c in &t.calls {
                    want[c.agent].0 += 1;
                    want[c.agent].1 += c.tokens;
                }
            }
        }
        // Identical iteration order end-to-end → bitwise equality.
        assert_eq!(src.store_index(), &want[..]);
        assert!(src.clock() > 0);
    }

    #[test]
    fn dying_worker_returns_shard_and_run_stays_byte_identical() {
        let golden = reference("baseline", 2048, 4);
        // Victim 0 dies on its very first assign; victim 1 after two.
        for fail in [
            WorkerFault { worker: 0, after_assigns: 0 },
            WorkerFault { worker: 1, after_assigns: 2 },
        ] {
            let (shaped, scen) = resolved("baseline");
            let mut plan = DistPlan::channel(3);
            plan.fail = Some(fail);
            let mut src = DistSource::new(shaped, scen, 2048, 4, plan);
            let got = drain(&mut src);
            assert!(src.take_error().is_none(), "fault {fail:?}");
            assert_eq!(got, golden, "fault {fail:?}");
        }
    }

    #[test]
    fn all_workers_dead_is_a_typed_transport_error() {
        let (shaped, scen) = resolved("baseline");
        let mut plan = DistPlan::channel(1);
        plan.fail = Some(WorkerFault { worker: 0, after_assigns: 0 });
        let mut src = DistSource::new(shaped, scen, 2048, 2, plan);
        assert!(src.next_step().is_none());
        let err = src.take_error().expect("typed error");
        assert!(matches!(err, PallasError::Transport { .. }), "{err:?}");
        let msg = err.to_string();
        assert!(msg.contains("all 1 workers (channel)"), "{msg}");
        assert!(msg.contains("cannot make progress"), "{msg}");
        // Idempotent thereafter.
        assert!(src.next_step().is_none());
        assert!(src.take_error().is_none());
    }

    #[test]
    fn corrupted_frame_surfaces_a_typed_checksum_error() {
        // Satellite: in-memory corrupting transport proves a flipped
        // byte in transit becomes a typed frame diagnostic — not a
        // panic, not silent acceptance. Frame 2 on worker 0's link is
        // its first result (frame 1 is its claim).
        let (shaped, scen) = resolved("baseline");
        let mut src = DistSource::with_transport(
            shaped,
            scen,
            2048,
            2,
            DistPlan::channel(1),
            Box::new(CorruptingTransport::new(ChannelTransport::new(), 2)),
        );
        assert!(src.next_step().is_none());
        let err = src.take_error().expect("typed error");
        let msg = err.to_string();
        assert!(msg.contains("transport worker 0 (channel)"), "{msg}");
        assert!(msg.contains("frame 2:"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
    }

    #[test]
    fn fast_forward_matches_a_skipped_scenario_source() {
        let golden = reference("bursty", 7, 5);
        let (shaped, scen) = resolved("bursty");
        let mut src = DistSource::new(shaped, scen, 7, 5, DistPlan::channel(2));
        src.fast_forward(3).unwrap();
        assert_eq!(src.len_hint(), LenHint::Exact(2));
        let got = drain(&mut src);
        assert!(src.take_error().is_none());
        assert_eq!(got, golden[3..]);
        // And the ScenarioSource guards are mirrored.
        let (shaped, scen) = resolved("bursty");
        let mut src = DistSource::new(shaped, scen, 7, 5, DistPlan::channel(1));
        assert!(src.fast_forward(6).is_err());
        src.next_step().unwrap();
        assert!(src.fast_forward(1).is_err());
    }

    #[test]
    fn plan_validation_rejects_nonsense() {
        assert!(DistPlan::channel(0).validate().is_err());
        let mut p = DistPlan::socket(2);
        p.fail = Some(WorkerFault { worker: 2, after_assigns: 0 });
        assert!(p.validate().is_err());
        assert!(DistPlan::channel(8).validate().is_ok());
        assert_eq!(TransportKind::parse("socket"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn misbehaving_worker_trips_protocol_errors() {
        // A rogue transport whose single "worker" reads init then sends
        // a claim wearing the wrong worker id.
        struct RogueTx(std::sync::mpsc::Sender<Vec<u8>>);
        impl FrameTx for RogueTx {
            fn send(&mut self, frame: &[u8]) -> Result<(), PallasError> {
                let _ = self.0.send(frame.to_vec());
                Ok(())
            }
        }
        struct RogueRx(std::sync::mpsc::Receiver<Vec<u8>>);
        impl transport::FrameRx for RogueRx {
            fn recv(&mut self) -> Result<Option<Vec<u8>>, PallasError> {
                Ok(self.0.recv().ok())
            }
        }
        struct RogueTransport;
        impl Transport for RogueTransport {
            fn name(&self) -> &'static str {
                "rogue"
            }
            fn launch(&mut self, n: usize) -> Result<Vec<Link>, PallasError> {
                assert_eq!(n, 1);
                let (c2w_tx, c2w_rx) = std::sync::mpsc::channel::<Vec<u8>>();
                let (w2c_tx, w2c_rx) = std::sync::mpsc::channel::<Vec<u8>>();
                std::thread::spawn(move || {
                    let _init = c2w_rx.recv(); // swallow init
                    let _ = w2c_tx.send(encode_frame(&Msg::Claim { worker: 5 }));
                    // keep the link open until the coordinator hangs up
                    while c2w_rx.recv().is_ok() {}
                });
                Ok(vec![Link {
                    worker: 0,
                    tx: Box::new(RogueTx(c2w_tx)),
                    rx: Box::new(RogueRx(w2c_rx)),
                }])
            }
        }

        let (shaped, scen) = resolved("baseline");
        let mut src = DistSource::with_transport(
            shaped,
            scen,
            2048,
            1,
            DistPlan::channel(1),
            Box::new(RogueTransport),
        );
        assert!(src.next_step().is_none());
        let err = src.take_error().expect("typed error");
        assert!(matches!(err, PallasError::Protocol { .. }), "{err:?}");
        let msg = err.to_string();
        assert!(
            msg.contains("expected claim from worker 0 on its own link, got claim from worker 5"),
            "{msg}"
        );
    }
}
