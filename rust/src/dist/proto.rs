//! Wire protocol of the distributed plane (DESIGN.md §14).
//!
//! Every message is one *frame*: the two-line [`crate::util::codec`]
//! text (magic `flexmarl-dist`, version [`PROTO_VERSION`], fnv1a64
//! checksum) — the same byte format checkpoints use, per the paper's
//! "unified and location-agnostic communication". A frame is identical
//! whether it crosses an in-process channel or a socket; only the
//! carrier differs ([`crate::dist::transport`]).
//!
//! Message taxonomy (tabulated in DESIGN.md §14):
//!
//! | dir | kind       | payload |
//! |-----|------------|---------|
//! | C→W | `init`     | seed, worker id, [`GenSpec`], optional fault plan |
//! | C→W | `assign`   | (step, slot) shard |
//! | C→W | `shutdown` | — |
//! | W→C | `claim`    | worker id |
//! | W→C | `result`   | (step, slot), trajectories, per-agent index rows |
//!
//! Decode failures surface in [`crate::workload::TraceReader`]'s
//! diagnostic style: a typed [`PallasError::Transport`] whose reason
//! carries the 1-based frame index on that link plus recovery guidance
//! — never a panic, pinned by the corrupting-transport tests.

use crate::config::{AgentConfig, ModelScale, WorkloadConfig};
use crate::error::PallasError;
use crate::util::codec::{as_ju64, ju64, Codec, CodecError};
use crate::util::json::Json;
use crate::workload::{trajectory_from_json, trajectory_to_json, TrajectorySpec};

/// First-line magic distinguishing dist frames from checkpoints (and
/// anything else sharing the codec substrate).
pub const MAGIC: &str = "flexmarl-dist";

/// Protocol version. Both ends must speak the same one; a mismatch is
/// a typed frame rejection, not garbage state.
pub const PROTO_VERSION: u64 = 1;

/// The dist vocabulary over the shared frame codec.
pub const CODEC: Codec = Codec {
    magic: MAGIC,
    version: PROTO_VERSION,
};

/// Refuse absurd length prefixes before allocating: no legitimate
/// frame (one query's trajectory group) comes near this.
pub const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;

// ---------------------------------------------------------------------------
// GenSpec: everything a worker needs to generate query shards
// ---------------------------------------------------------------------------

/// The generation parameters of a shaped [`WorkloadConfig`], shipped in
/// `init`. Exactly the fields [`crate::workload::Generator`] reads —
/// agent names/models are presentation-only there, so a worker
/// reconstructs a placeholder config around these and produces
/// bit-identical trajectories (`f64` survives the JSON round-trip
/// bit-exactly; the byte-identity contract rests on that).
#[derive(Debug, Clone, PartialEq)]
pub struct GenSpec {
    /// Per agent: `(invoke_weight, mean_tokens, token_sigma)`.
    pub agents: Vec<(f64, f64, f64)>,
    pub min_turns: usize,
    pub max_turns: usize,
    pub group_size: usize,
    pub max_tokens: f64,
    pub env_mu: f64,
    pub env_sigma: f64,
}

impl GenSpec {
    /// Capture the generation parameters of an (already-shaped) config.
    pub fn from_workload(wl: &WorkloadConfig) -> GenSpec {
        GenSpec {
            agents: wl
                .agents
                .iter()
                .map(|a| (a.invoke_weight, a.mean_tokens, a.token_sigma))
                .collect(),
            min_turns: wl.min_turns,
            max_turns: wl.max_turns,
            group_size: wl.group_size,
            max_tokens: wl.max_tokens,
            env_mu: wl.env_mu,
            env_sigma: wl.env_sigma,
        }
    }

    /// Rebuild a config a [`crate::workload::Generator`] can run on.
    /// Names, models, and the step-level fields (`queries_per_step`,
    /// `inter_query`, scenario, trace) are placeholders: per-query
    /// generation never reads them.
    pub fn to_workload(&self) -> WorkloadConfig {
        WorkloadConfig {
            name: "dist".to_string(),
            agents: self
                .agents
                .iter()
                .enumerate()
                .map(|(i, &(invoke_weight, mean_tokens, token_sigma))| AgentConfig {
                    name: format!("agent{i}"),
                    model: ModelScale::B14,
                    invoke_weight,
                    mean_tokens,
                    token_sigma,
                })
                .collect(),
            queries_per_step: 1,
            min_turns: self.min_turns,
            max_turns: self.max_turns,
            group_size: self.group_size,
            inter_query: 1,
            max_tokens: self.max_tokens,
            env_mu: self.env_mu,
            env_sigma: self.env_sigma,
            scenario: "baseline".to_string(),
            trace: None,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "agents",
                Json::arr(self.agents.iter().map(|&(w, m, s)| {
                    Json::arr([Json::num(w), Json::num(m), Json::num(s)])
                })),
            ),
            ("min_turns", Json::num(self.min_turns as f64)),
            ("max_turns", Json::num(self.max_turns as f64)),
            ("group_size", Json::num(self.group_size as f64)),
            ("max_tokens", Json::num(self.max_tokens)),
            ("env_mu", Json::num(self.env_mu)),
            ("env_sigma", Json::num(self.env_sigma)),
        ])
    }

    fn from_json(j: &Json) -> Result<GenSpec, String> {
        let agents_j = j
            .at(&["agents"])
            .and_then(Json::as_arr)
            .ok_or_else(|| "init spec missing 'agents'".to_string())?;
        let mut agents = Vec::with_capacity(agents_j.len());
        for a in agents_j {
            let triple = a
                .as_arr()
                .filter(|t| t.len() == 3)
                .ok_or_else(|| "init spec agent is not [weight,mean,sigma]".to_string())?;
            let mut vals = [0.0f64; 3];
            for (i, v) in triple.iter().enumerate() {
                vals[i] = v
                    .as_f64()
                    .ok_or_else(|| "init spec agent field is not a number".to_string())?;
            }
            agents.push((vals[0], vals[1], vals[2]));
        }
        let us = |key: &str| -> Result<usize, String> {
            j.at(&[key])
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("init spec missing '{key}'"))
        };
        let fl = |key: &str| -> Result<f64, String> {
            j.at(&[key])
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("init spec missing '{key}'"))
        };
        Ok(GenSpec {
            agents,
            min_turns: us("min_turns")?,
            max_turns: us("max_turns")?,
            group_size: us("group_size")?,
            max_tokens: fl("max_tokens")?,
            env_mu: fl("env_mu")?,
            env_sigma: fl("env_sigma")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// One protocol message (see the module-level taxonomy table).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// C→W: identity, seed, generation parameters, and (fault-plane)
    /// an optional deterministic death plan: die silently on assign
    /// number `fail_after` (0-based).
    Init {
        worker: usize,
        seed: u64,
        spec: GenSpec,
        fail_after: Option<u64>,
    },
    /// C→W: generate query shard `(step, slot)` and ship the result.
    Assign { step: u64, slot: u64 },
    /// C→W: the run is over; exit cleanly.
    Shutdown,
    /// W→C: idle, ready for a shard.
    Claim { worker: usize },
    /// W→C: shard `(step, slot)` done. `index` is the worker's
    /// per-agent `(calls, token_sum)` rows for this shard — the
    /// coordinator verifies them against the shipped trajectories
    /// before folding them into its canonical experience-store index.
    Result {
        worker: usize,
        step: u64,
        slot: u64,
        trajectories: Vec<TrajectorySpec>,
        index: Vec<(u64, f64)>,
    },
}

impl Msg {
    /// Message kind tag — the Protocol-error vocabulary.
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Init { .. } => "init",
            Msg::Assign { .. } => "assign",
            Msg::Shutdown => "shutdown",
            Msg::Claim { .. } => "claim",
            Msg::Result { .. } => "result",
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Msg::Init {
                worker,
                seed,
                spec,
                fail_after,
            } => {
                let mut fields = vec![
                    ("kind", Json::str("init")),
                    ("worker", Json::num(*worker as f64)),
                    ("seed", ju64(*seed)),
                    ("spec", spec.to_json()),
                ];
                if let Some(k) = fail_after {
                    fields.push(("fail_after", ju64(*k)));
                }
                Json::obj(fields)
            }
            Msg::Assign { step, slot } => Json::obj(vec![
                ("kind", Json::str("assign")),
                ("step", ju64(*step)),
                ("slot", ju64(*slot)),
            ]),
            Msg::Shutdown => Json::obj(vec![("kind", Json::str("shutdown"))]),
            Msg::Claim { worker } => Json::obj(vec![
                ("kind", Json::str("claim")),
                ("worker", Json::num(*worker as f64)),
            ]),
            Msg::Result {
                worker,
                step,
                slot,
                trajectories,
                index,
            } => Json::obj(vec![
                ("kind", Json::str("result")),
                ("worker", Json::num(*worker as f64)),
                ("step", ju64(*step)),
                ("slot", ju64(*slot)),
                (
                    "trajectories",
                    Json::arr(trajectories.iter().map(trajectory_to_json)),
                ),
                (
                    "index",
                    Json::arr(index.iter().map(|&(calls, tokens)| {
                        Json::arr([ju64(calls), Json::num(tokens)])
                    })),
                ),
            ]),
        }
    }

    fn from_json(j: &Json, n_agents: usize) -> Result<Msg, String> {
        let kind = j
            .at(&["kind"])
            .and_then(Json::as_str)
            .ok_or_else(|| "message missing 'kind'".to_string())?;
        let worker = |j: &Json| -> Result<usize, String> {
            j.at(&["worker"])
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("{kind} missing 'worker'"))
        };
        let u64_field = |key: &str| -> Result<u64, String> {
            j.at(&[key])
                .and_then(as_ju64)
                .ok_or_else(|| format!("{kind} missing '{key}'"))
        };
        match kind {
            "init" => Ok(Msg::Init {
                worker: worker(j)?,
                seed: u64_field("seed")?,
                spec: GenSpec::from_json(
                    j.at(&["spec"]).ok_or_else(|| "init missing 'spec'".to_string())?,
                )?,
                fail_after: j.at(&["fail_after"]).and_then(as_ju64),
            }),
            "assign" => Ok(Msg::Assign {
                step: u64_field("step")?,
                slot: u64_field("slot")?,
            }),
            "shutdown" => Ok(Msg::Shutdown),
            "claim" => Ok(Msg::Claim { worker: worker(j)? }),
            "result" => {
                let trajs_j = j
                    .at(&["trajectories"])
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "result missing 'trajectories'".to_string())?;
                let mut trajectories = Vec::with_capacity(trajs_j.len());
                for t in trajs_j {
                    trajectories.push(trajectory_from_json(t, n_agents)?);
                }
                let index_j = j
                    .at(&["index"])
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "result missing 'index'".to_string())?;
                let mut index = Vec::with_capacity(index_j.len());
                for row in index_j {
                    let pair = row
                        .as_arr()
                        .filter(|p| p.len() == 2)
                        .ok_or_else(|| "result index row is not [calls,tokens]".to_string())?;
                    index.push((
                        as_ju64(&pair[0]).ok_or_else(|| "result index: bad calls".to_string())?,
                        pair[1]
                            .as_f64()
                            .ok_or_else(|| "result index: bad tokens".to_string())?,
                    ));
                }
                Ok(Msg::Result {
                    worker: worker(j)?,
                    step: u64_field("step")?,
                    slot: u64_field("slot")?,
                    trajectories,
                    index,
                })
            }
            other => Err(format!("unknown message kind '{other}'")),
        }
    }
}

// ---------------------------------------------------------------------------
// Frame encode / decode
// ---------------------------------------------------------------------------

/// Serialize a message into frame bytes (codec text, UTF-8).
pub fn encode_frame(msg: &Msg) -> Vec<u8> {
    CODEC.encode(&msg.to_json()).into_bytes()
}

/// Build the typed frame diagnostic: 1-based frame index on this link
/// plus a preformatted reason — the [`crate::workload::TraceReader`]
/// line-diagnostic style, for streams.
pub fn frame_error(endpoint: &str, frame: u64, reason: impl Into<String>) -> PallasError {
    PallasError::Transport {
        endpoint: endpoint.to_string(),
        reason: format!("frame {frame}: {}", reason.into()),
    }
}

/// Render a structured codec rejection with dist-plane guidance.
fn codec_reason(e: &CodecError) -> String {
    match e {
        CodecError::NoPayload | CodecError::TornTail => {
            "truncated frame (the stream was cut mid-frame); the peer likely died mid-send".into()
        }
        CodecError::BadHeader(e) => format!(
            "unreadable frame header: {e} — framing desynchronized or the peer \
             speaks another protocol"
        ),
        CodecError::BadMagic => {
            "not a flexmarl-dist frame (bad magic) — the peer is not a dist worker/coordinator"
                .into()
        }
        CodecError::BadVersion { got, want } => format!(
            "unsupported dist protocol version {got} (want {want}) — both ends must \
             run the same build"
        ),
        CodecError::MissingChecksum => "frame header missing 'checksum'".into(),
        CodecError::ChecksumMismatch { want, got } => format!(
            "checksum mismatch (header {want}, payload {got}) — the frame was \
             corrupted in transit"
        ),
        CodecError::BadPayload(e) => format!("unreadable frame payload: {e}"),
    }
}

/// Validate and parse one received frame. `frame` is the 1-based count
/// of frames received on this link so far; every rejection is a typed
/// [`PallasError::Transport`] naming the link, the frame index, and
/// recovery guidance — never a panic.
pub fn decode_frame(
    bytes: &[u8],
    endpoint: &str,
    frame: u64,
    n_agents: usize,
) -> Result<Msg, PallasError> {
    let text = std::str::from_utf8(bytes).map_err(|_| {
        frame_error(
            endpoint,
            frame,
            "frame is not UTF-8 — the stream is corrupt or framing desynchronized",
        )
    })?;
    let j = CODEC
        .decode(text)
        .map_err(|e| frame_error(endpoint, frame, codec_reason(&e)))?;
    Msg::from_json(&j, n_agents).map_err(|e| frame_error(endpoint, frame, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Generator;

    fn spec() -> GenSpec {
        GenSpec::from_workload(&WorkloadConfig::ma())
    }

    #[test]
    fn every_message_roundtrips_through_frame_bytes() {
        let wl = WorkloadConfig::ma();
        let trajectories = Generator::new(&wl, 2048).query(1, 0);
        let index = crate::dist::worker::shard_index(&trajectories, wl.agents.len());
        let msgs = vec![
            Msg::Init {
                worker: 3,
                seed: u64::MAX - 5,
                spec: spec(),
                fail_after: Some(2),
            },
            Msg::Init {
                worker: 0,
                seed: 2048,
                spec: spec(),
                fail_after: None,
            },
            Msg::Assign { step: 7, slot: 2 },
            Msg::Shutdown,
            Msg::Claim { worker: 1 },
            Msg::Result {
                worker: 1,
                step: 7,
                slot: 2,
                trajectories,
                index,
            },
        ];
        for m in msgs {
            let bytes = encode_frame(&m);
            let back = decode_frame(&bytes, "worker 1 (test)", 1, wl.agents.len()).unwrap();
            // PartialEq on TrajectorySpec is bit-level f64 equality —
            // the wire round-trip must be exact.
            assert_eq!(back, m);
        }
    }

    #[test]
    fn genspec_reconstructs_a_generator_equivalent_config() {
        // The byte-identity keystone: a worker generating from the
        // reconstructed placeholder config produces the same bits as
        // the coordinator would from the real one.
        for wl in [WorkloadConfig::ma(), WorkloadConfig::ca()] {
            let rebuilt = GenSpec::from_workload(&wl).to_workload();
            let a = Generator::new(&wl, 2048);
            let b = Generator::new(&rebuilt, 2048);
            for (step, q) in [(0, 0), (0, 3), (5, 1)] {
                assert_eq!(a.query(step, q), b.query(step, q), "{} {step}/{q}", wl.name);
            }
        }
    }

    #[test]
    fn corrupt_frames_are_typed_with_frame_index_and_guidance() {
        let n = WorkloadConfig::ma().agents.len();
        let good = encode_frame(&Msg::Claim { worker: 0 });

        // Flipped payload byte → checksum mismatch.
        let mut flipped = good.clone();
        let nl = flipped.iter().position(|&b| b == b'\n').unwrap();
        flipped[nl + 1] ^= 0x01;
        let err = decode_frame(&flipped, "worker 0 (channel)", 3, n).unwrap_err();
        assert!(matches!(err, PallasError::Transport { .. }), "{err:?}");
        let msg = err.to_string();
        assert!(msg.contains("transport worker 0 (channel)"), "{msg}");
        assert!(msg.contains("frame 3:"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(msg.contains("corrupted in transit"), "{msg}");

        // Truncated frame.
        let cut = &good[..good.len() - 4];
        let err = decode_frame(cut, "worker 2 (socket)", 1, n).unwrap_err();
        assert!(err.to_string().contains("truncated frame"), "{err}");

        // A checkpoint blob is not a dist frame.
        let ckpt = crate::ckpt::encode(&Json::obj(vec![("x", Json::num(1.0))]));
        let err = decode_frame(ckpt.as_bytes(), "worker 0 (channel)", 2, n).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        // Invalid UTF-8.
        let err = decode_frame(&[0xff, 0xfe, 0x0a, 0x0a], "worker 0 (channel)", 9, n).unwrap_err();
        assert!(err.to_string().contains("not UTF-8"), "{err}");

        // Well-formed frame, unknown message kind.
        let alien = CODEC
            .encode(&Json::obj(vec![("kind", Json::str("gossip"))]))
            .into_bytes();
        let err = decode_frame(&alien, "worker 0 (channel)", 4, n).unwrap_err();
        assert!(err.to_string().contains("unknown message kind 'gossip'"), "{err}");
    }

    #[test]
    fn seed_and_counters_survive_above_2_pow_53() {
        // Seeds are string-encoded (ju64), so the full u64 range
        // round-trips — unlike the trace header's plain JSON number.
        let m = Msg::Init {
            worker: 0,
            seed: (1 << 53) + 1,
            spec: spec(),
            fail_after: None,
        };
        let back = decode_frame(&encode_frame(&m), "w", 1, 8).unwrap();
        assert_eq!(back, m);
    }
}
