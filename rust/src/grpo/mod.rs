//! GRPO algorithm pieces on the coordinator side (§2.1): group-relative
//! advantage normalization, multi-agent credit assignment, and batch
//! assembly for the AOT `grad` artifact.
//!
//! The L2/L1 layers compute the clipped surrogate loss and its gradient;
//! *this* module decides what advantage each token of each agent's
//! sample carries — the part that is multi-agent specific.

/// Group-relative advantages (GRPO, Shao et al. 2024): within one query's
/// candidate group, A_i = (r_i − mean) / (std + ε). Returns zeros for a
/// degenerate group (all equal rewards) — no gradient, which is correct.
pub fn group_advantages(rewards: &[f64]) -> Vec<f64> {
    let n = rewards.len();
    if n == 0 {
        return Vec::new();
    }
    let mean = rewards.iter().sum::<f64>() / n as f64;
    let var = rewards.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    if std < 1e-8 {
        return vec![0.0; n];
    }
    rewards.iter().map(|r| (r - mean) / std).collect()
}

/// Multi-agent credit assignment: how a trajectory-level (global) reward
/// and an agent's own call-level (local) reward combine into the reward
/// used for that agent's sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CreditAssignment {
    /// Every agent in the trajectory shares the global reward.
    Shared,
    /// Every agent is judged only on its own call's reward.
    Local,
    /// Blend: alpha·global + (1−alpha)·local (the usual compromise for
    /// "collaboration effectiveness + task correctness", §2.1).
    Blend(f64),
}

impl CreditAssignment {
    pub fn credit(&self, global: f64, local: f64) -> f64 {
        match *self {
            CreditAssignment::Shared => global,
            CreditAssignment::Local => local,
            CreditAssignment::Blend(a) => a * global + (1.0 - a) * local,
        }
    }
}

/// One agent-sample ready for training: the (prompt ++ response) token
/// sequence plus per-token advantage/mask rows, padded to `t_train`.
#[derive(Debug, Clone)]
pub struct TrainRow {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub adv: Vec<f32>,
    pub old_logp: Vec<f32>,
    pub mask: Vec<f32>,
}

/// Assemble a training row from a prompt + sampled response.
///
/// Layout (teacher-forcing): `tokens[t]` predicts `targets[t] =
/// sequence[t+1]`; response positions get `advantage` and mask 1; prompt
/// positions and padding get mask 0.
pub fn make_row(
    prompt: &[i32],
    response: &[i32],
    response_logp: &[f32],
    advantage: f32,
    t_train: usize,
) -> TrainRow {
    assert_eq!(response.len(), response_logp.len());
    let mut seq: Vec<i32> = Vec::with_capacity(prompt.len() + response.len());
    seq.extend_from_slice(prompt);
    seq.extend_from_slice(response);
    seq.truncate(t_train + 1);

    let mut tokens = vec![0i32; t_train];
    let mut targets = vec![0i32; t_train];
    let mut adv = vec![0f32; t_train];
    let mut old_logp = vec![0f32; t_train];
    let mut mask = vec![0f32; t_train];

    let n_in = seq.len().saturating_sub(1).min(t_train);
    tokens[..n_in].copy_from_slice(&seq[..n_in]);
    targets[..n_in].copy_from_slice(&seq[1..n_in + 1]);
    // Response tokens start being *predicted* at position prompt_len-1
    // (the position whose target is response[0]).
    let resp_start = prompt.len().saturating_sub(1);
    for (j, (&_r, &lp)) in response.iter().zip(response_logp).enumerate() {
        let pos = resp_start + j;
        if pos >= t_train {
            break;
        }
        adv[pos] = advantage;
        old_logp[pos] = lp;
        mask[pos] = 1.0;
    }
    TrainRow {
        tokens,
        targets,
        adv,
        old_logp,
        mask,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn advantages_zero_mean_unit_scale() {
        let a = group_advantages(&[1.0, 2.0, 3.0, 4.0]);
        let mean: f64 = a.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
        assert!(a[3] > 0.0 && a[0] < 0.0);
        // Order preserved.
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn degenerate_group_gets_zero() {
        assert_eq!(group_advantages(&[0.5; 8]), vec![0.0; 8]);
        assert!(group_advantages(&[]).is_empty());
    }

    #[test]
    fn prop_advantages_invariants() {
        forall("group advantage invariants", 200, |rng| {
            let n = rng.below(16) as usize + 2;
            let rewards: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let a = group_advantages(&rewards);
            let mean: f64 = a.iter().sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-9, "mean {mean}");
            // Shift invariance.
            let shifted: Vec<f64> = rewards.iter().map(|r| r + 100.0).collect();
            let a2 = group_advantages(&shifted);
            for (x, y) in a.iter().zip(&a2) {
                assert!((x - y).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn credit_assignment_modes() {
        assert_eq!(CreditAssignment::Shared.credit(1.0, 0.0), 1.0);
        assert_eq!(CreditAssignment::Local.credit(1.0, 0.25), 0.25);
        let b = CreditAssignment::Blend(0.5).credit(1.0, 0.0);
        assert!((b - 0.5).abs() < 1e-12);
    }

    #[test]
    fn make_row_layout() {
        let prompt = vec![10, 11, 12];
        let response = vec![20, 21];
        let logp = vec![-0.5, -0.7];
        let row = make_row(&prompt, &response, &logp, 1.5, 8);
        // seq = [10,11,12,20,21]; tokens = seq[..4], targets = seq[1..5]
        assert_eq!(&row.tokens[..4], &[10, 11, 12, 20]);
        assert_eq!(&row.targets[..4], &[11, 12, 20, 21]);
        // Response predicted at positions 2 and 3.
        assert_eq!(row.mask, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(row.adv[2], 1.5);
        assert_eq!(row.old_logp[3], -0.7);
        // Prompt positions carry no advantage.
        assert_eq!(row.adv[0], 0.0);
    }

    #[test]
    fn make_row_truncates_long_sequences() {
        let prompt: Vec<i32> = (0..6).collect();
        let response: Vec<i32> = (100..120).collect();
        let logp = vec![-1.0; 20];
        let row = make_row(&prompt, &response, &logp, 1.0, 10);
        assert_eq!(row.tokens.len(), 10);
        assert_eq!(row.mask.iter().filter(|&&m| m == 1.0).count(), 5); // positions 5..10
    }
}
