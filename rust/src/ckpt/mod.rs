//! Checkpoint plane: versioned, crash-consistent snapshot files
//! (DESIGN.md §12).
//!
//! A checkpoint is the complete mutable state of a running
//! [`crate::orchestrator::Session`] — event queue, experience store,
//! rollout-manager tables, the retiring step window, counters, series,
//! workload-source position, and every report already yielded — encoded
//! with the in-tree JSON util (the crate is zero-dependency; no serde).
//! This module owns the *file format*; the per-subsystem state codecs
//! live next to the private fields they capture (`sim`, `store`,
//! `rollout`, `training`, `orchestrator::simloop`).
//!
//! File layout (two lines, both newline-terminated):
//!
//! ```text
//! {"magic":"flexmarl-ckpt","version":1,"checksum":"<fnv1a64 hex>"}
//! {...payload...}
//! ```
//!
//! * **Versioned** — `version` is [`FORMAT_VERSION`]; a reader rejects
//!   any other value with a typed [`PallasError::Checkpoint`] (stale
//!   files never deserialize into garbage state).
//! * **Checksummed** — FNV-1a 64 over the exact payload bytes; a
//!   flipped bit or a torn tail is a typed rejection, not a panic.
//! * **Crash-consistent** — [`write_file`] writes a temp file in the
//!   destination directory and atomically renames it over the target:
//!   a reader observes either the old complete checkpoint or the new
//!   complete one, never a partial write.
//!
//! Integer encoding: JSON numbers are f64, exact only to 2^53, so u64
//! ids/sequence counters and the PRNG's u128 state are string-encoded
//! ([`ju64`]/[`ju128`]). `f64` values round-trip bit-exactly through
//! the in-tree JSON (shortest-round-trip formatting, correctly rounded
//! parse) — the foundation of the byte-identical-resume contract.

use crate::error::PallasError;
use crate::util::json::{parse, Json};

/// Checkpoint format version. Bump on any payload-shape change; old
/// readers reject newer files (and vice versa) with a typed error.
pub const FORMAT_VERSION: u64 = 1;

/// First-line magic distinguishing checkpoints from arbitrary JSON.
pub const MAGIC: &str = "flexmarl-ckpt";

// ---------------------------------------------------------------------------
// Integer codecs (JSON numbers are f64 — exact only to 2^53)
// ---------------------------------------------------------------------------

/// Encode a `u64` losslessly (decimal string).
pub fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Encode a `u128` losslessly (decimal string) — PRNG state words.
pub fn ju128(v: u128) -> Json {
    Json::Str(v.to_string())
}

/// Decode [`ju64`]; tolerates a plain in-range JSON number too.
pub fn as_ju64(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().ok(),
        _ => j.as_u64(),
    }
}

/// Decode [`ju128`].
pub fn as_ju128(j: &Json) -> Option<u128> {
    match j {
        Json::Str(s) => s.parse::<u128>().ok(),
        _ => None,
    }
}

/// Encode an `i64` losslessly (decimal string) — store scalar columns.
pub fn ji64(v: i64) -> Json {
    Json::Str(v.to_string())
}

/// Decode [`ji64`]; tolerates a plain in-range JSON number too.
pub fn as_ji64(j: &Json) -> Option<i64> {
    match j {
        Json::Str(s) => s.parse::<i64>().ok(),
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 => Some(*n as i64),
        _ => None,
    }
}

/// FNV-1a 64-bit over `bytes` — the payload checksum. In-tree (the
/// image has no hash crates); collision resistance is not the goal,
/// torn-write and bit-rot *detection* is.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn reject(path: &str, reason: impl Into<String>) -> PallasError {
    PallasError::Checkpoint {
        path: path.to_string(),
        reason: reason.into(),
    }
}

// ---------------------------------------------------------------------------
// Encode / decode
// ---------------------------------------------------------------------------

/// Serialize a payload into the two-line checkpoint text.
pub fn encode(payload: &Json) -> String {
    let body = payload.to_string();
    let header = Json::obj(vec![
        ("magic", Json::str(MAGIC)),
        ("version", Json::num(FORMAT_VERSION as f64)),
        ("checksum", Json::str(format!("{:016x}", fnv1a64(body.as_bytes())))),
    ]);
    format!("{}\n{}\n", header.to_string(), body)
}

/// Validate and parse checkpoint text: magic, format version, checksum,
/// payload JSON. Every rejection is a typed [`PallasError::Checkpoint`]
/// naming `path` (pass `""` for in-memory text).
pub fn decode(text: &str, path: &str) -> Result<Json, PallasError> {
    let Some((header_line, rest)) = text.split_once('\n') else {
        return Err(reject(path, "truncated file (no payload line)"));
    };
    let header = parse(header_line)
        .map_err(|e| reject(path, format!("unreadable header: {e}")))?;
    match header.at(&["magic"]).and_then(Json::as_str) {
        Some(m) if m == MAGIC => {}
        _ => return Err(reject(path, "not a flexmarl checkpoint (bad magic)")),
    }
    let version = header.at(&["version"]).and_then(Json::as_u64).unwrap_or(0);
    if version != FORMAT_VERSION {
        return Err(reject(
            path,
            format!("unsupported checkpoint format version {version} (want {FORMAT_VERSION})"),
        ));
    }
    let want = header
        .at(&["checksum"])
        .and_then(Json::as_str)
        .ok_or_else(|| reject(path, "header missing 'checksum'"))?
        .to_string();
    // The writer always terminates the payload line; a missing final
    // newline is a torn tail even before the checksum says so.
    let Some(body) = rest.strip_suffix('\n') else {
        return Err(reject(
            path,
            "truncated file (payload ends mid-line; the write was torn)",
        ));
    };
    let got = format!("{:016x}", fnv1a64(body.as_bytes()));
    if got != want {
        return Err(reject(
            path,
            format!("checksum mismatch (header {want}, payload {got}) — corrupt or truncated"),
        ));
    }
    parse(body).map_err(|e| reject(path, format!("unreadable payload: {e}")))
}

/// Write a checkpoint crash-consistently: temp file in the destination
/// directory, then atomic rename over `path`. A crash at any instant
/// leaves either the previous complete checkpoint or the new one.
pub fn write_file(path: &str, payload: &Json) -> Result<(), PallasError> {
    let tmp = format!("{path}.tmp.{}", std::process::id());
    std::fs::write(&tmp, encode(payload)).map_err(|e| PallasError::File {
        path: tmp.clone(),
        error: e.to_string(),
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        // Never leave the temp file behind on a failed rename.
        let _ = std::fs::remove_file(&tmp);
        PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        }
    })
}

/// Read and validate a checkpoint file. I/O failures are
/// [`PallasError::File`]; format violations are
/// [`PallasError::Checkpoint`].
pub fn read_file(path: &str) -> Result<Json, PallasError> {
    let text = std::fs::read_to_string(path).map_err(|e| PallasError::File {
        path: path.to_string(),
        error: e.to_string(),
    })?;
    decode(&text, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload() -> Json {
        Json::obj(vec![
            ("kind", Json::str("test")),
            ("seq", ju64(u64::MAX)),
            ("state", ju128(u128::MAX - 7)),
            ("t", Json::num(0.1 + 0.2)), // not exactly representable — must round-trip
        ])
    }

    #[test]
    fn encode_decode_roundtrip() {
        let p = payload();
        let text = encode(&p);
        let back = decode(&text, "").unwrap();
        assert_eq!(back.to_string(), p.to_string());
        assert_eq!(as_ju64(back.at(&["seq"]).unwrap()), Some(u64::MAX));
        assert_eq!(as_ju128(back.at(&["state"]).unwrap()), Some(u128::MAX - 7));
        assert_eq!(
            back.at(&["t"]).and_then(Json::as_f64).unwrap().to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let text = encode(&payload());
        let bad = text.replace("\"kind\":\"test\"", "\"kind\":\"toast\"");
        assert_ne!(bad, text);
        let err = decode(&bad, "ck.json").unwrap_err();
        assert!(matches!(err, PallasError::Checkpoint { .. }), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        assert!(err.to_string().contains("ck.json"), "{err}");
    }

    #[test]
    fn truncated_file_rejected() {
        let text = encode(&payload());
        // Torn tail: payload cut mid-line (no trailing newline).
        let cut = &text[..text.len() - 10];
        let err = decode(cut, "ck.json").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Header-only file: no payload line at all.
        let header_only = text.split_once('\n').unwrap().0;
        let err = decode(header_only, "ck.json").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Empty file.
        assert!(decode("", "ck.json").is_err());
    }

    #[test]
    fn stale_format_version_rejected() {
        let text = encode(&payload());
        let bad = text.replacen("\"version\":1", "\"version\":99", 1);
        assert_ne!(bad, text, "test setup: version field not found");
        let err = decode(&bad, "ck.json").unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("unsupported checkpoint format version 99"),
            "{msg}"
        );
    }

    #[test]
    fn non_checkpoint_json_rejected_by_magic() {
        let err = decode("{\"hello\":1}\n{}\n", "x").unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        let err = decode("not json at all\nstill not\n", "x").unwrap_err();
        assert!(err.to_string().contains("unreadable header"), "{err}");
    }

    #[test]
    fn file_roundtrip_is_atomic_replace() {
        let dir = std::env::temp_dir();
        let path = dir.join("flexmarl_ckpt_test.json");
        let path = path.to_str().unwrap().to_string();
        write_file(&path, &payload()).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(back.to_string(), payload().to_string());
        // Replacing writes through the same atomic path.
        let p2 = Json::obj(vec![("kind", Json::str("v2"))]);
        write_file(&path, &p2).unwrap();
        assert_eq!(read_file(&path).unwrap().to_string(), p2.to_string());
        // No temp litter.
        let tmp = format!("{path}.tmp.{}", std::process::id());
        assert!(!std::path::Path::new(&tmp).exists());
        let _ = std::fs::remove_file(&path);
        // Missing file is a typed File error.
        let err = read_file(&path).unwrap_err();
        assert!(matches!(err, PallasError::File { .. }), "{err:?}");
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_35c8_b3d6_f00c);
    }
}
