//! Checkpoint plane: versioned, crash-consistent snapshot files
//! (DESIGN.md §12).
//!
//! A checkpoint is the complete mutable state of a running
//! [`crate::orchestrator::Session`] — event queue, experience store,
//! rollout-manager tables, the retiring step window, counters, series,
//! workload-source position, and every report already yielded — encoded
//! with the in-tree JSON util (the crate is zero-dependency; no serde).
//! This module owns the *file vocabulary*; the byte format (two-line
//! header+checksum framing, lossless integer codecs, atomic writes) is
//! the shared [`crate::util::codec`] substrate — the same bytes the
//! distributed plane (DESIGN.md §14) ships across channels and
//! sockets — and the per-subsystem state codecs live next to the
//! private fields they capture (`sim`, `store`, `rollout`, `training`,
//! `orchestrator::simloop`).
//!
//! File layout (two lines, both newline-terminated):
//!
//! ```text
//! {"magic":"flexmarl-ckpt","version":1,"checksum":"<fnv1a64 hex>"}
//! {...payload...}
//! ```
//!
//! * **Versioned** — `version` is [`FORMAT_VERSION`]; a reader rejects
//!   any other value with a typed [`PallasError::Checkpoint`] (stale
//!   files never deserialize into garbage state).
//! * **Checksummed** — FNV-1a 64 over the exact payload bytes; a
//!   flipped bit or a torn tail is a typed rejection, not a panic.
//! * **Crash-consistent** — [`write_file`] writes a temp file in the
//!   destination directory and atomically renames it over the target:
//!   a reader observes either the old complete checkpoint or the new
//!   complete one, never a partial write.
//!
//! Integer encoding: JSON numbers are f64, exact only to 2^53, so u64
//! ids/sequence counters and the PRNG's u128 state are string-encoded
//! ([`ju64`]/[`ju128`]). `f64` values round-trip bit-exactly through
//! the in-tree JSON (shortest-round-trip formatting, correctly rounded
//! parse) — the foundation of the byte-identical-resume contract.

use crate::error::PallasError;
use crate::util::codec::{Codec, CodecError};
use crate::util::json::Json;

// The integer codecs and checksum moved to the shared substrate; the
// re-exports keep this module's historical API surface intact.
pub use crate::util::codec::{as_ji64, as_ju128, as_ju64, fnv1a64, ji64, ju128, ju64};

/// Checkpoint format version. Bump on any payload-shape change; old
/// readers reject newer files (and vice versa) with a typed error.
pub const FORMAT_VERSION: u64 = 1;

/// First-line magic distinguishing checkpoints from arbitrary JSON.
pub const MAGIC: &str = "flexmarl-ckpt";

/// The checkpoint vocabulary over the shared frame codec.
const CODEC: Codec = Codec { magic: MAGIC, version: FORMAT_VERSION };

fn reject(path: &str, reason: impl Into<String>) -> PallasError {
    PallasError::Checkpoint {
        path: path.to_string(),
        reason: reason.into(),
    }
}

/// Render a structured codec rejection as this module's historical
/// reason string — pinned byte-for-byte by `tests/ckpt.rs`, so the
/// codec extraction is invisible to everything that matches on them.
fn reason(e: CodecError) -> String {
    match e {
        CodecError::NoPayload => "truncated file (no payload line)".into(),
        CodecError::BadHeader(e) => format!("unreadable header: {e}"),
        CodecError::BadMagic => "not a flexmarl checkpoint (bad magic)".into(),
        CodecError::BadVersion { got, want } => {
            format!("unsupported checkpoint format version {got} (want {want})")
        }
        CodecError::MissingChecksum => "header missing 'checksum'".into(),
        CodecError::TornTail => {
            "truncated file (payload ends mid-line; the write was torn)".into()
        }
        CodecError::ChecksumMismatch { want, got } => {
            format!("checksum mismatch (header {want}, payload {got}) — corrupt or truncated")
        }
        CodecError::BadPayload(e) => format!("unreadable payload: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Encode / decode
// ---------------------------------------------------------------------------

/// Serialize a payload into the two-line checkpoint text.
pub fn encode(payload: &Json) -> String {
    CODEC.encode(payload)
}

/// Validate and parse checkpoint text: magic, format version, checksum,
/// payload JSON. Every rejection is a typed [`PallasError::Checkpoint`]
/// naming `path` (pass `""` for in-memory text).
pub fn decode(text: &str, path: &str) -> Result<Json, PallasError> {
    CODEC.decode(text).map_err(|e| reject(path, reason(e)))
}

/// Write a checkpoint crash-consistently: temp file in the destination
/// directory, then atomic rename over `path`. A crash at any instant
/// leaves either the previous complete checkpoint or the new one.
pub fn write_file(path: &str, payload: &Json) -> Result<(), PallasError> {
    crate::util::codec::write_atomic(path, &encode(payload))
}

/// Read and validate a checkpoint file. I/O failures are
/// [`PallasError::File`]; format violations are
/// [`PallasError::Checkpoint`].
pub fn read_file(path: &str) -> Result<Json, PallasError> {
    let text = std::fs::read_to_string(path).map_err(|e| PallasError::File {
        path: path.to_string(),
        error: e.to_string(),
    })?;
    decode(&text, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload() -> Json {
        Json::obj(vec![
            ("kind", Json::str("test")),
            ("seq", ju64(u64::MAX)),
            ("state", ju128(u128::MAX - 7)),
            ("t", Json::num(0.1 + 0.2)), // not exactly representable — must round-trip
        ])
    }

    #[test]
    fn encode_decode_roundtrip() {
        let p = payload();
        let text = encode(&p);
        let back = decode(&text, "").unwrap();
        assert_eq!(back.to_string(), p.to_string());
        assert_eq!(as_ju64(back.at(&["seq"]).unwrap()), Some(u64::MAX));
        assert_eq!(as_ju128(back.at(&["state"]).unwrap()), Some(u128::MAX - 7));
        assert_eq!(
            back.at(&["t"]).and_then(Json::as_f64).unwrap().to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let text = encode(&payload());
        let bad = text.replace("\"kind\":\"test\"", "\"kind\":\"toast\"");
        assert_ne!(bad, text);
        let err = decode(&bad, "ck.json").unwrap_err();
        assert!(matches!(err, PallasError::Checkpoint { .. }), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        assert!(err.to_string().contains("ck.json"), "{err}");
    }

    #[test]
    fn truncated_file_rejected() {
        let text = encode(&payload());
        // Torn tail: payload cut mid-line (no trailing newline).
        let cut = &text[..text.len() - 10];
        let err = decode(cut, "ck.json").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Header-only file: no payload line at all.
        let header_only = text.split_once('\n').unwrap().0;
        let err = decode(header_only, "ck.json").unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Empty file.
        assert!(decode("", "ck.json").is_err());
    }

    #[test]
    fn stale_format_version_rejected() {
        let text = encode(&payload());
        let bad = text.replacen("\"version\":1", "\"version\":99", 1);
        assert_ne!(bad, text, "test setup: version field not found");
        let err = decode(&bad, "ck.json").unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("unsupported checkpoint format version 99"),
            "{msg}"
        );
    }

    #[test]
    fn non_checkpoint_json_rejected_by_magic() {
        let err = decode("{\"hello\":1}\n{}\n", "x").unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        let err = decode("not json at all\nstill not\n", "x").unwrap_err();
        assert!(err.to_string().contains("unreadable header"), "{err}");
    }

    #[test]
    fn file_roundtrip_is_atomic_replace() {
        let dir = std::env::temp_dir();
        let path = dir.join("flexmarl_ckpt_test.json");
        let path = path.to_str().unwrap().to_string();
        write_file(&path, &payload()).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(back.to_string(), payload().to_string());
        // Replacing writes through the same atomic path.
        let p2 = Json::obj(vec![("kind", Json::str("v2"))]);
        write_file(&path, &p2).unwrap();
        assert_eq!(read_file(&path).unwrap().to_string(), p2.to_string());
        // No temp litter.
        let tmp = format!("{path}.tmp.{}", std::process::id());
        assert!(!std::path::Path::new(&tmp).exists());
        let _ = std::fs::remove_file(&path);
        // Missing file is a typed File error.
        let err = read_file(&path).unwrap_err();
        assert!(matches!(err, PallasError::File { .. }), "{err:?}");
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_35c8_b3d6_f00c);
    }
}
