//! Property-testing helper (substrate; the `proptest` crate is not
//! vendored). Runs a property over many seeded random cases and reports
//! the failing seed so a case can be replayed deterministically:
//!
//! ```
//! use flexmarl::util::proptest::forall;
//! forall("sorted stays sorted", 200, |rng| {
//!     let mut v: Vec<u64> = (0..rng.below(50)).map(|_| rng.below(1000)).collect();
//!     v.sort();
//!     assert!(v.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use super::rng::Pcg64;

/// Run `prop` for `cases` seeded inputs; panic with the seed on failure.
pub fn forall<F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::with_stream(seed, 0x9e37_79b9_7f4a_7c15);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single seed (debugging helper).
pub fn replay<F: FnOnce(&mut Pcg64)>(seed: u64, prop: F) {
    let mut rng = Pcg64::with_stream(seed, 0x9e37_79b9_7f4a_7c15);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("trivial", 50, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always-fails", 3, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed 0"), "{msg}");
    }
}
