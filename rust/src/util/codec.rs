//! Shared state codec: versioned, checksummed, two-line JSON framing
//! (substrate under [`crate::ckpt`] and [`crate::dist`]).
//!
//! The checkpoint plane (DESIGN.md §12) and the distributed plane
//! (DESIGN.md §14) serialize the *same* kinds of state — PRNG words,
//! store rows, trajectory specs — and the paper's "unified and
//! location-agnostic communication" framing is taken literally: a blob
//! encoded here is the same bytes whether it lands in a checkpoint
//! file, crosses an in-process channel, or crosses a socket. This
//! module owns the byte format; callers own the *vocabulary* (magic
//! string, version number, and how a rejection reads to a human).
//!
//! Frame layout (two lines, both newline-terminated):
//!
//! ```text
//! {"magic":"<magic>","version":<v>,"checksum":"<fnv1a64 hex>"}
//! {...payload...}
//! ```
//!
//! * **Versioned** — a reader rejects any version it does not speak
//!   ([`CodecError::BadVersion`]); stale frames never deserialize into
//!   garbage state.
//! * **Checksummed** — FNV-1a 64 over the exact payload bytes; a
//!   flipped bit or a torn tail is a typed rejection, not a panic.
//! * **Integer encoding** — JSON numbers are f64, exact only to 2^53,
//!   so u64 ids/sequence counters and the PRNG's u128 state are
//!   string-encoded ([`ju64`]/[`ju128`]). `f64` values round-trip
//!   bit-exactly through the in-tree JSON (shortest-round-trip
//!   formatting, correctly rounded parse).
//!
//! Every rejection is a structured [`CodecError`]; [`crate::ckpt`]
//! renders them as its historical `PallasError::Checkpoint` reason
//! strings (pinned byte-for-byte by `tests/ckpt.rs`), while the
//! distributed plane renders them frame-indexed in the style of
//! [`crate::workload::TraceReader`]'s line diagnostics.

use crate::error::PallasError;
use crate::util::json::{parse, Json};

// ---------------------------------------------------------------------------
// Integer codecs (JSON numbers are f64 — exact only to 2^53)
// ---------------------------------------------------------------------------

/// Encode a `u64` losslessly (decimal string).
pub fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Encode a `u128` losslessly (decimal string) — PRNG state words.
pub fn ju128(v: u128) -> Json {
    Json::Str(v.to_string())
}

/// Decode [`ju64`]; tolerates a plain in-range JSON number too.
pub fn as_ju64(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().ok(),
        _ => j.as_u64(),
    }
}

/// Decode [`ju128`].
pub fn as_ju128(j: &Json) -> Option<u128> {
    match j {
        Json::Str(s) => s.parse::<u128>().ok(),
        _ => None,
    }
}

/// Encode an `i64` losslessly (decimal string) — store scalar columns.
pub fn ji64(v: i64) -> Json {
    Json::Str(v.to_string())
}

/// Decode [`ji64`]; tolerates a plain in-range JSON number too.
pub fn as_ji64(j: &Json) -> Option<i64> {
    match j {
        Json::Str(s) => s.parse::<i64>().ok(),
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 => Some(*n as i64),
        _ => None,
    }
}

/// FNV-1a 64-bit over `bytes` — the payload checksum. In-tree (the
/// image has no hash crates); collision resistance is not the goal,
/// torn-write and bit-rot *detection* is.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Structured rejections
// ---------------------------------------------------------------------------

/// Why a frame failed to decode. Structured so each consumer can render
/// its own diagnostic vocabulary without re-parsing message strings:
/// `ckpt` maps these onto its pinned legacy reason strings, `dist`
/// prefixes them with a 1-based frame index and recovery guidance.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// No `\n` at all: the header line is the whole text.
    NoPayload,
    /// The header line is not valid JSON; carries the parse error.
    BadHeader(String),
    /// The header's magic is absent or not the expected string.
    BadMagic,
    /// Version mismatch: frame says `got`, reader speaks `want`.
    BadVersion { got: u64, want: u64 },
    /// Header has no `checksum` field.
    MissingChecksum,
    /// The payload line lacks its terminating newline — the write (or
    /// the stream) was cut mid-line.
    TornTail,
    /// FNV-1a over the payload bytes disagrees with the header.
    ChecksumMismatch { want: String, got: String },
    /// Checksum passed but the payload is not valid JSON; carries the
    /// parse error.
    BadPayload(String),
}

// ---------------------------------------------------------------------------
// Encode / decode
// ---------------------------------------------------------------------------

/// A frame vocabulary: the magic string naming the format and the one
/// version this reader/writer speaks. Consts — e.g.
/// [`crate::ckpt::MAGIC`]/[`crate::ckpt::FORMAT_VERSION`] — plug in
/// directly.
#[derive(Debug, Clone, Copy)]
pub struct Codec {
    pub magic: &'static str,
    pub version: u64,
}

impl Codec {
    /// Serialize a payload into the two-line frame text.
    pub fn encode(&self, payload: &Json) -> String {
        let body = payload.to_string();
        let header = Json::obj(vec![
            ("magic", Json::str(self.magic)),
            ("version", Json::num(self.version as f64)),
            ("checksum", Json::str(format!("{:016x}", fnv1a64(body.as_bytes())))),
        ]);
        format!("{}\n{}\n", header.to_string(), body)
    }

    /// Validate and parse frame text: magic, version, checksum, payload
    /// JSON. Every rejection is a structured [`CodecError`].
    pub fn decode(&self, text: &str) -> Result<Json, CodecError> {
        let Some((header_line, rest)) = text.split_once('\n') else {
            return Err(CodecError::NoPayload);
        };
        let header =
            parse(header_line).map_err(|e| CodecError::BadHeader(e.to_string()))?;
        match header.at(&["magic"]).and_then(Json::as_str) {
            Some(m) if m == self.magic => {}
            _ => return Err(CodecError::BadMagic),
        }
        let got = header.at(&["version"]).and_then(Json::as_u64).unwrap_or(0);
        if got != self.version {
            return Err(CodecError::BadVersion { got, want: self.version });
        }
        let want = header
            .at(&["checksum"])
            .and_then(Json::as_str)
            .ok_or(CodecError::MissingChecksum)?
            .to_string();
        // The writer always terminates the payload line; a missing
        // final newline is a torn tail even before the checksum says so.
        let Some(body) = rest.strip_suffix('\n') else {
            return Err(CodecError::TornTail);
        };
        let got = format!("{:016x}", fnv1a64(body.as_bytes()));
        if got != want {
            return Err(CodecError::ChecksumMismatch { want, got });
        }
        parse(body).map_err(|e| CodecError::BadPayload(e.to_string()))
    }
}

/// Write frame text crash-consistently: temp file in the destination
/// directory, then atomic rename over `path`. A crash at any instant
/// leaves either the previous complete file or the new one.
pub fn write_atomic(path: &str, text: &str) -> Result<(), PallasError> {
    let tmp = format!("{path}.tmp.{}", std::process::id());
    std::fs::write(&tmp, text).map_err(|e| PallasError::File {
        path: tmp.clone(),
        error: e.to_string(),
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        // Never leave the temp file behind on a failed rename.
        let _ = std::fs::remove_file(&tmp);
        PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: Codec = Codec { magic: "codec-test", version: 3 };

    fn payload() -> Json {
        Json::obj(vec![
            ("seq", ju64(u64::MAX)),
            ("state", ju128(u128::MAX - 7)),
            ("t", Json::num(0.1 + 0.2)), // not exactly representable — must round-trip
        ])
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let text = C.encode(&payload());
        let back = C.decode(&text).unwrap();
        assert_eq!(back.to_string(), payload().to_string());
        assert_eq!(as_ju64(back.at(&["seq"]).unwrap()), Some(u64::MAX));
        assert_eq!(as_ju128(back.at(&["state"]).unwrap()), Some(u128::MAX - 7));
        assert_eq!(
            back.at(&["t"]).and_then(Json::as_f64).unwrap().to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
    }

    #[test]
    fn every_rejection_is_structured() {
        let text = C.encode(&payload());
        assert_eq!(C.decode("no newline here"), Err(CodecError::NoPayload));
        assert_eq!(
            C.decode(&text[..text.len() - 5]),
            Err(CodecError::TornTail),
            "cut payload must read as torn, not as a checksum failure"
        );
        let wrong_magic = Codec { magic: "other", version: 3 };
        assert_eq!(wrong_magic.decode(&text), Err(CodecError::BadMagic));
        let newer = Codec { magic: "codec-test", version: 4 };
        assert_eq!(newer.decode(&text), Err(CodecError::BadVersion { got: 3, want: 4 }));
        assert!(matches!(
            C.decode("not json\n{}\n"),
            Err(CodecError::BadHeader(_))
        ));
        let flipped = text.replacen("\"seq\"", "\"sEq\"", 1);
        assert!(matches!(
            C.decode(&flipped),
            Err(CodecError::ChecksumMismatch { .. })
        ));
        let no_sum = "{\"magic\":\"codec-test\",\"version\":3}\n{}\n";
        assert_eq!(C.decode(no_sum), Err(CodecError::MissingChecksum));
    }

    #[test]
    fn distinct_magics_do_not_cross_decode() {
        // The ckpt/dist separation: a checkpoint blob must never decode
        // as a dist frame (and vice versa), even though the byte format
        // is shared.
        let a = Codec { magic: "plane-a", version: 1 };
        let b = Codec { magic: "plane-b", version: 1 };
        let frame = a.encode(&payload());
        assert_eq!(b.decode(&frame), Err(CodecError::BadMagic));
        assert!(a.decode(&frame).is_ok());
    }
}
