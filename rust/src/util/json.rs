//! Minimal JSON parser + emitter (substrate).
//!
//! `serde_json` is not vendored in this offline image, so we implement
//! the subset of JSON the system needs: parsing `artifacts/manifest.json`
//! (the Python→Rust AOT ABI), reading experiment/cluster config files,
//! and emitting metrics/reports. Full RFC 8259 value model (objects,
//! arrays, strings with escapes, numbers, booleans, null); no
//! streaming — documents here are ≤ a few MiB.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["artifacts", "grad", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- emit --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parse ------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (surrogate pairs unsupported; our
                            // documents are ASCII identifiers + metrics).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":{"d_model":256,"vocab":512},"xs":[1.5,-2,true,null,"s\"q"]}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // The actual ABI document if present (skips cleanly otherwise).
        if let Ok(text) = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        )) {
            let j = parse(&text).unwrap();
            assert!(j.at(&["artifacts", "grad", "file"]).is_some());
            assert!(j.at(&["param_spec"]).unwrap().as_arr().unwrap().len() == 10);
        }
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }
}
