//! Micro-bench harness (substrate; `criterion` is not vendored).
//!
//! Warmup + timed iterations with mean/std/min reporting; used by the
//! `cargo bench` targets (`harness = false`). Deliberately simple: fixed
//! iteration counts scaled to hit a target measurement time, no outlier
//! rejection beyond reporting min.

use std::time::{Duration, Instant};

use super::stats::Summary;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   mean {:>12}   min {:>12}   ±{}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.std),
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to ~`target` total runtime.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let iters = (target.as_nanos() / once.as_nanos()).clamp(3, 10_000) as u64;

    let mut s = Summary::new();
    let mut min = Duration::MAX;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed();
        s.add(dt.as_secs_f64());
        min = min.min(dt);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(s.mean()),
        std: Duration::from_secs_f64(s.std()),
        min,
    }
}

/// Run-once timing for expensive end-to-end benches (paper tables).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", Duration::from_millis(20), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
