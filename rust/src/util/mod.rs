//! Substrate utilities built from scratch for the offline image:
//! PRNG + distributions, JSON, CLI parsing, statistics, bench harness,
//! a scoped worker pool, and a tiny property-testing helper.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod hash;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
