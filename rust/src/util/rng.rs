//! Deterministic, seedable PRNG + distributions (substrate).
//!
//! The `rand`/`rand_distr` crates are not vendored in this offline image,
//! so we implement PCG64 (O'Neill 2014, XSL-RR variant) plus the handful
//! of distributions the workload generator and simulator need: uniform,
//! normal (Box–Muller), lognormal (the paper's Fig. 1a long-tail
//! interaction latency), exponential (arrival processes), and categorical
//! (skewed agent-invocation patterns, Obs. 2). All experiments run with a
//! fixed seed (paper §8.1 uses 2048) for reproducibility.

/// PCG-XSL-RR 128/64. 128-bit LCG state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream (odd increment) — used to give every simulated
    /// entity (agent, instance, query) its own decorrelated sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output permutation.
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free enough for
    /// simulation purposes (modulo bias < 2^-32 for n << 2^32).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (single value; we waste the pair to
    /// keep the generator allocation-free and stateless).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with parameters of the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - f64() ∈ (0, 1], so the log is finite.
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// The generator's complete internal state `(state, inc)` — what a
    /// checkpoint stores (DESIGN.md §12). Feeding it back through
    /// [`Pcg64::restore`] resumes the stream exactly where it was: the
    /// resumed sequence is bit-identical to the uninterrupted one.
    pub fn state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a captured [`Pcg64::state`] pair. No
    /// warm-up draws happen here — the pair already encodes them.
    pub fn restore(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Pcg64::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Pcg64::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Pcg64::new(8); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn streams_decorrelated() {
        let mut a = Pcg64::with_stream(1, 1);
        let mut b = Pcg64::with_stream(1, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_is_heavy_tailed() {
        let mut r = Pcg64::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal(1.0, 1.2)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[n / 2];
        // Long-tail: mean well above median (Fig. 1a shape).
        assert!(mean > 1.5 * median, "mean={mean} median={median}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(17);
        let w = [8.0, 1.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!(counts[0] > 7_500 && counts[0] < 8_500, "{counts:?}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(23);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        assert_eq!(Pcg64::new(1).below(1), 0);
    }

    /// Property: for any seed/stream, capturing mid-stream and resuming
    /// from the captured state yields exactly the continuation of the
    /// uninterrupted stream. Exercised over the engine's dedicated
    /// stream ids (fault plane 0xfa01–0xfa05, arrival scenarios, the
    /// default stream) and a spread of split points.
    #[test]
    fn state_restore_resumes_bit_identically() {
        let streams: &[u64] = &[
            0xfa01, 0xfa02, 0xfa03, 0xfa04, 0xfa05, // fault-plane streams
            0xda3e_39cb_94b9_5bdb,                  // Pcg64::new default
            0, 1, 2, 0xdead_beef,
        ];
        for &seed in &[0u64, 1, 7, 2048, u64::MAX] {
            for &stream in streams {
                for split in [0usize, 1, 3, 17, 64] {
                    let mut cont = Pcg64::with_stream(seed, stream);
                    let mut pre = Pcg64::with_stream(seed, stream);
                    for _ in 0..split {
                        pre.next_u64();
                        cont.next_u64();
                    }
                    let (st, inc) = pre.state();
                    let mut resumed = Pcg64::restore(st, inc);
                    for k in 0..256 {
                        assert_eq!(
                            resumed.next_u64(),
                            cont.next_u64(),
                            "seed={seed} stream={stream:#x} split={split} draw={k}"
                        );
                    }
                }
            }
        }
    }

    /// The float/distribution surface sits on `next_u64`, so restored
    /// generators reproduce the derived samples bit-for-bit too.
    #[test]
    fn state_restore_covers_distributions() {
        let mut a = Pcg64::new(2048);
        for _ in 0..10 {
            a.lognormal(1.0, 1.2);
        }
        let (st, inc) = a.state();
        let mut b = Pcg64::restore(st, inc);
        for _ in 0..100 {
            assert_eq!(a.f64().to_bits(), b.f64().to_bits());
        }
        let (st2, inc2) = a.state();
        let mut c = Pcg64::restore(st2, inc2);
        assert_eq!(a.exponential(2.0).to_bits(), c.exponential(2.0).to_bits());
        assert_eq!(a.normal().to_bits(), c.normal().to_bits());
        assert_eq!(a.categorical(&[3.0, 1.0]), c.categorical(&[3.0, 1.0]));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(29);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
