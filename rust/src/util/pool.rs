//! Hand-rolled worker pools (substrate; `rayon` is not vendored).
//!
//! [`run_ordered`] fans a work list out over up to `jobs` OS threads and
//! collects results **in input order**, whatever order workers finish
//! in: worker `k` atomically claims the next unclaimed index and writes
//! its result into that index's dedicated slot, so the output vector is
//! a pure function of the input list — never of thread scheduling. This
//! is the determinism substrate under [`crate::exec`] (DESIGN.md §4).
//!
//! [`WorkerPool`] is the persistent counterpart (DESIGN.md §13): the
//! same claim-a-task/write-a-slot discipline, but over long-lived
//! threads fed through a shared queue — the serving plane submits many
//! batches of session work without re-spawning threads per batch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Worker count when the caller does not pin one: `PALLAS_JOBS` (if set
/// to a positive integer), else the machine's available parallelism.
pub fn default_jobs() -> usize {
    match std::env::var("PALLAS_JOBS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n > 0 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Run `f(index, &item)` for every item on up to `jobs` scoped worker
/// threads; return the results in input order.
///
/// Workers pull indices from a shared atomic counter (dynamic
/// load balancing — a slow item never strands the queue behind it) and
/// write each result into its input slot, so:
///
/// * output\[i\] is always f(i, &items\[i\]) — input order, regardless
///   of completion order or `jobs`;
/// * `jobs == 1` degenerates to a plain in-order sequential loop;
/// * `f` must be a pure function of its arguments for the *values* to
///   be thread-count-independent — the pool guarantees only position.
///
/// A panicking `f` aborts the run: a stop flag halts further claims
/// (cells already in flight finish), the worker re-raises its payload,
/// and the scope then panics in the caller — a long sweep does not
/// burn wall time after one cell dies.
pub fn run_ordered<I, T, F>(items: &[I], jobs: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, items.len());
    if jobs == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<T>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                // AssertUnwindSafe: on Err the payload is re-raised
                // immediately and the whole scope panics, so no one
                // ever observes state the unwind may have torn.
                let out =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &items[i])));
                match out {
                    Ok(v) => *slots[i].lock().unwrap() = Some(v),
                    Err(payload) => {
                        stop.store(true, Ordering::Relaxed);
                        std::panic::resume_unwind(payload);
                    }
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("pool: worker skipped a slot"))
        .collect()
}

// ---------------------------------------------------------------------------
// Persistent worker pool (the serving plane's execution substrate)
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    in_flight: usize,
    shutdown: bool,
    /// First panic payload raised by a job; re-raised by
    /// [`WorkerPool::wait_idle`]. Later panics in the same batch are
    /// dropped — one casualty aborts the batch, mirroring
    /// [`run_ordered`]'s stop-flag semantics.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    job_ready: Condvar,
    all_idle: Condvar,
}

/// Long-lived worker pool: `workers` OS threads spawned once, fed
/// through a shared FIFO queue. Unlike [`run_ordered`] (scoped, one
/// shot), a `WorkerPool` outlives any single batch — submit jobs,
/// [`WorkerPool::wait_idle`], submit more.
///
/// Determinism discipline (same as `run_ordered`): the pool guarantees
/// nothing about *completion order*, so callers that need
/// thread-count-independent output must have each job write into its
/// own pre-assigned slot and aggregate in submission order afterwards.
/// The serving plane (DESIGN.md §13) does exactly that.
///
/// A panicking job poisons the current batch: the queue is cleared (no
/// wall time burned on doomed work), the first payload is stored, and
/// `wait_idle` re-raises it. The pool itself stays usable afterwards.
///
/// Shutdown (the coordinator use, DESIGN.md §14): [`WorkerPool::shutdown`]
/// takes `&self`, so it can race concurrent [`WorkerPool::submit`]s.
/// The contract is *no job is ever lost*: the shutdown flag and the
/// queue live under one mutex, the worker loop drains the queue before
/// honoring the flag, and a submit that observes the flag already set
/// runs its job inline on the calling thread.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn a pool of `workers.max(1)` threads.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                panic: None,
            }),
            job_ready: Condvar::new(),
            all_idle: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool {
            shared,
            workers,
            handles: Mutex::new(handles),
        }
    }

    /// Thread count the pool was built with (stable across shutdown).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one job. Never blocks; jobs run in FIFO claim order
    /// across however many workers are free. A submit that races
    /// [`WorkerPool::shutdown`] and loses runs the job *inline* on the
    /// calling thread instead — submitted work is never silently
    /// dropped (a panic then propagates in the caller, like any
    /// directly-invoked closure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            drop(st);
            job();
            return;
        }
        st.queue.push_back(Box::new(job));
        drop(st);
        self.shared.job_ready.notify_one();
    }

    /// Orderly teardown: raise the shutdown flag, wake every worker,
    /// join them all, then re-raise the first stored job panic (if
    /// any) in the caller. The flag and the queue share one mutex and
    /// the worker loop drains the queue before honoring the flag, so
    /// every job enqueued before the flag went up still runs; submits
    /// that arrive after it run inline in *their* caller (see
    /// [`WorkerPool::submit`]). Idempotent — later calls (and the
    /// eventual Drop) find nothing left to join.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.job_ready.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        let payload = self.shared.state.lock().unwrap().panic.take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Block until the queue is empty and every claimed job finished.
    /// If any job panicked since the last wait, re-raises the first
    /// payload here (the pool remains usable for new batches).
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.shared.all_idle.wait(st).unwrap();
        }
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.job_ready.notify_all();
        for h in self.handles.get_mut().unwrap().drain(..) {
            // A worker thread only panics if a panic payload itself
            // panics on drop; don't double-panic the destructor (and
            // unlike shutdown(), never re-raise a stored payload here).
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.panic.is_some() {
                    // Batch is doomed: drop everything still queued so
                    // wait_idle can report the casualty promptly.
                    st.queue.clear();
                }
                if let Some(j) = st.queue.pop_front() {
                    st.in_flight += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        // AssertUnwindSafe: the payload is stored and re-raised in the
        // caller via wait_idle; jobs own their captured state.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        let mut st = shared.state.lock().unwrap();
        st.in_flight -= 1;
        if let Err(payload) = res {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
            st.queue.clear();
        }
        if st.queue.is_empty() && st.in_flight == 0 {
            shared.all_idle.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_and_parallel_agree() {
        let items: Vec<u64> = (0..97).collect();
        let f = |i: usize, x: &u64| x * 3 + i as u64;
        let seq = run_ordered(&items, 1, f);
        for jobs in [2, 3, 8, 64, 1000] {
            assert_eq!(run_ordered(&items, jobs, f), seq, "jobs={jobs}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<u32> = run_ordered(&[] as &[u8], 8, |_, _| 1);
        assert!(out.is_empty());
    }

    /// Satellite: adversarial stub runner in which completion order is
    /// the exact *reverse* of input order (item i blocks until item
    /// i+1 finished) — collection must still be input order.
    #[test]
    fn order_matches_input_under_reversed_completion() {
        let n = 8usize;
        let items: Vec<usize> = (0..n).collect();
        let done: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let finish_seq = AtomicUsize::new(0);
        // jobs == n: every item gets its own worker (the shared counter
        // hands indices out 0..n in claim order), so the reverse chain
        // cannot deadlock.
        let out = run_ordered(&items, n, |i, &x| {
            if i + 1 < n {
                while !done[i + 1].load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            }
            let rank = finish_seq.fetch_add(1, Ordering::SeqCst);
            done[i].store(true, Ordering::Release);
            (x * 10, rank)
        });
        // Values land in input order...
        let vals: Vec<usize> = out.iter().map(|&(v, _)| v).collect();
        assert_eq!(vals, (0..n).map(|x| x * 10).collect::<Vec<_>>());
        // ...even though completion genuinely happened in reverse.
        let ranks: Vec<usize> = out.iter().map(|&(_, r)| r).collect();
        assert_eq!(ranks, (0..n).rev().collect::<Vec<_>>());
    }

    #[test]
    fn jobs_zero_and_oversubscription_clamp() {
        let items = [1u8, 2, 3];
        assert_eq!(run_ordered(&items, 0, |_, &x| x), vec![1, 2, 3]);
        assert_eq!(run_ordered(&items, 999, |_, &x| x), vec![1, 2, 3]);
    }

    #[test]
    fn panicking_item_propagates_and_stops_claims() {
        let claimed = AtomicUsize::new(0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let items: Vec<u64> = (0..64).collect();
            run_ordered(&items, 2, |i, &x| {
                claimed.fetch_add(1, Ordering::SeqCst);
                if i == 0 {
                    panic!("cell died");
                }
                // Give the panicking worker time to raise the stop
                // flag so the tail of the queue goes unclaimed.
                std::thread::sleep(std::time::Duration::from_millis(1));
                x
            })
        }));
        assert!(res.is_err(), "panic must propagate out of the pool");
        let n = claimed.load(Ordering::SeqCst);
        assert!(n < 64, "stop flag did not halt claims ({n}/64 ran)");
    }

    /// Satellite (fault-plane PR): with more workers than items every
    /// surplus worker claims an out-of-range index and exits cleanly —
    /// results are complete, in order, and each item ran exactly once.
    #[test]
    fn more_jobs_than_items_runs_each_item_exactly_once() {
        let items: Vec<usize> = (0..3).collect();
        let runs: Vec<AtomicUsize> = items.iter().map(|_| AtomicUsize::new(0)).collect();
        for jobs in [4, 7, 64] {
            let out = run_ordered(&items, jobs, |i, &x| {
                runs[i].fetch_add(1, Ordering::SeqCst);
                x * 2
            });
            assert_eq!(out, vec![0, 2, 4], "jobs={jobs}");
        }
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 3, "item {i} re-ran under oversubscription");
        }
    }

    /// Satellite (fault-plane PR): a panic still propagates when the
    /// pool is oversubscribed — the stop flag and the unwind must not
    /// race the surplus workers' immediate exit.
    #[test]
    fn panic_propagates_with_more_jobs_than_items() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let items = [1u8, 2];
            run_ordered(&items, 16, |i, &x| {
                if i == 1 {
                    panic!("cell died");
                }
                x
            })
        }));
        assert!(res.is_err());
    }

    // ---- WorkerPool (serving-plane substrate, DESIGN.md §13) ----------

    #[test]
    fn worker_pool_runs_every_job() {
        let pool = WorkerPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let count = Arc::clone(&count);
            pool.submit(move || {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_pool_slot_writes_are_worker_count_independent() {
        // The serving plane's discipline: each job writes its own slot,
        // aggregation reads slots in submission order — identical output
        // for any worker count.
        let run = |workers: usize| -> Vec<u64> {
            let pool = WorkerPool::new(workers);
            let slots: Arc<Vec<Mutex<Option<u64>>>> =
                Arc::new((0..64).map(|_| Mutex::new(None)).collect());
            for i in 0..64u64 {
                let slots = Arc::clone(&slots);
                pool.submit(move || {
                    *slots[i as usize].lock().unwrap() = Some(i * 7 + 1);
                });
            }
            pool.wait_idle();
            slots.iter().map(|m| m.lock().unwrap().expect("slot skipped")).collect()
        };
        let seq = run(1);
        for workers in [2, 3, 8] {
            assert_eq!(run(workers), seq, "workers={workers}");
        }
    }

    #[test]
    fn worker_pool_is_reusable_across_batches() {
        let pool = WorkerPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for batch in 0..3 {
            for _ in 0..10 {
                let count = Arc::clone(&count);
                pool.submit(move || {
                    count.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::SeqCst), (batch + 1) * 10);
        }
    }

    #[test]
    fn worker_pool_panic_reraised_at_wait_idle_and_pool_survives() {
        let pool = WorkerPool::new(2);
        pool.submit(|| panic!("session died"));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.wait_idle()));
        assert!(res.is_err(), "wait_idle must re-raise the job panic");
        // The pool is still serviceable for the next batch.
        let ok = Arc::new(AtomicBool::new(false));
        let ok2 = Arc::clone(&ok);
        pool.submit(move || ok2.store(true, Ordering::SeqCst));
        pool.wait_idle();
        assert!(ok.load(Ordering::SeqCst));
    }

    #[test]
    fn worker_pool_panic_clears_queued_jobs() {
        // One casualty aborts the batch: jobs still queued behind the
        // panicking one are dropped, not run.
        let pool = WorkerPool::new(1);
        let ran_after = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("first job dies"));
        for _ in 0..32 {
            let ran_after = Arc::clone(&ran_after);
            pool.submit(move || {
                ran_after.fetch_add(1, Ordering::SeqCst);
            });
        }
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.wait_idle()));
        assert!(res.is_err());
        assert_eq!(ran_after.load(Ordering::SeqCst), 0, "queued jobs ran after the panic");
    }

    #[test]
    fn worker_pool_zero_workers_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicBool::new(false));
        let done2 = Arc::clone(&done);
        pool.submit(move || done2.store(true, Ordering::SeqCst));
        pool.wait_idle();
        assert!(done.load(Ordering::SeqCst));
    }

    /// Satellite (dist PR): submits racing shutdown never lose jobs.
    /// A submitter thread fires 200 jobs while the main thread calls
    /// `shutdown()` mid-stream; every job must run — either drained by
    /// the workers before they exit, or inline in the submitter after
    /// it observes the flag.
    #[test]
    fn worker_pool_submit_racing_shutdown_loses_no_jobs() {
        for trial in 0..8 {
            let pool = WorkerPool::new(2);
            let ran = Arc::new(AtomicUsize::new(0));
            std::thread::scope(|s| {
                let ran = Arc::clone(&ran);
                let pool = &pool;
                s.spawn(move || {
                    for i in 0..200 {
                        let ran = Arc::clone(&ran);
                        pool.submit(move || {
                            ran.fetch_add(1, Ordering::SeqCst);
                        });
                        if i % 16 == 0 {
                            std::thread::yield_now();
                        }
                    }
                });
                // Vary the interleaving a little across trials.
                for _ in 0..trial * 7 {
                    std::thread::yield_now();
                }
                pool.shutdown();
            });
            // After the scope, the submitter is done and shutdown has
            // joined all workers: every submit must have executed.
            assert_eq!(ran.load(Ordering::SeqCst), 200, "trial {trial} lost jobs");
        }
    }

    #[test]
    fn worker_pool_shutdown_reraises_pending_panic() {
        let pool = WorkerPool::new(1);
        pool.submit(|| panic!("job died before shutdown"));
        // Give the worker a chance to run (not required for
        // correctness: shutdown drains the queue before joining).
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.shutdown()));
        assert!(res.is_err(), "shutdown must surface the stored panic");
        // Second shutdown (and the eventual Drop) are clean no-ops.
        pool.shutdown();
    }

    #[test]
    fn worker_pool_submit_after_shutdown_runs_inline() {
        let pool = WorkerPool::new(2);
        pool.shutdown();
        assert_eq!(pool.workers(), 2, "workers() must survive shutdown");
        let tid = std::thread::current().id();
        let ran_on = Arc::new(Mutex::new(None));
        let ran_on2 = Arc::clone(&ran_on);
        pool.submit(move || {
            *ran_on2.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(
            *ran_on.lock().unwrap(),
            Some(tid),
            "post-shutdown submit must run inline on the caller"
        );
    }

    #[test]
    fn worker_pool_drop_joins_cleanly() {
        let pool = WorkerPool::new(3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let count = Arc::clone(&count);
            pool.submit(move || {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }
}
