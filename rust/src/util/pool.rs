//! Hand-rolled scoped worker pool (substrate; `rayon` is not vendored).
//!
//! [`run_ordered`] fans a work list out over up to `jobs` OS threads and
//! collects results **in input order**, whatever order workers finish
//! in: worker `k` atomically claims the next unclaimed index and writes
//! its result into that index's dedicated slot, so the output vector is
//! a pure function of the input list — never of thread scheduling. This
//! is the determinism substrate under [`crate::exec`] (DESIGN.md §4).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count when the caller does not pin one: `PALLAS_JOBS` (if set
/// to a positive integer), else the machine's available parallelism.
pub fn default_jobs() -> usize {
    match std::env::var("PALLAS_JOBS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n > 0 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Run `f(index, &item)` for every item on up to `jobs` scoped worker
/// threads; return the results in input order.
///
/// Workers pull indices from a shared atomic counter (dynamic
/// load balancing — a slow item never strands the queue behind it) and
/// write each result into its input slot, so:
///
/// * output\[i\] is always f(i, &items\[i\]) — input order, regardless
///   of completion order or `jobs`;
/// * `jobs == 1` degenerates to a plain in-order sequential loop;
/// * `f` must be a pure function of its arguments for the *values* to
///   be thread-count-independent — the pool guarantees only position.
///
/// A panicking `f` aborts the run: a stop flag halts further claims
/// (cells already in flight finish), the worker re-raises its payload,
/// and the scope then panics in the caller — a long sweep does not
/// burn wall time after one cell dies.
pub fn run_ordered<I, T, F>(items: &[I], jobs: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, items.len());
    if jobs == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<T>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                // AssertUnwindSafe: on Err the payload is re-raised
                // immediately and the whole scope panics, so no one
                // ever observes state the unwind may have torn.
                let out =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &items[i])));
                match out {
                    Ok(v) => *slots[i].lock().unwrap() = Some(v),
                    Err(payload) => {
                        stop.store(true, Ordering::Relaxed);
                        std::panic::resume_unwind(payload);
                    }
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("pool: worker skipped a slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_and_parallel_agree() {
        let items: Vec<u64> = (0..97).collect();
        let f = |i: usize, x: &u64| x * 3 + i as u64;
        let seq = run_ordered(&items, 1, f);
        for jobs in [2, 3, 8, 64, 1000] {
            assert_eq!(run_ordered(&items, jobs, f), seq, "jobs={jobs}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<u32> = run_ordered(&[] as &[u8], 8, |_, _| 1);
        assert!(out.is_empty());
    }

    /// Satellite: adversarial stub runner in which completion order is
    /// the exact *reverse* of input order (item i blocks until item
    /// i+1 finished) — collection must still be input order.
    #[test]
    fn order_matches_input_under_reversed_completion() {
        let n = 8usize;
        let items: Vec<usize> = (0..n).collect();
        let done: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let finish_seq = AtomicUsize::new(0);
        // jobs == n: every item gets its own worker (the shared counter
        // hands indices out 0..n in claim order), so the reverse chain
        // cannot deadlock.
        let out = run_ordered(&items, n, |i, &x| {
            if i + 1 < n {
                while !done[i + 1].load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            }
            let rank = finish_seq.fetch_add(1, Ordering::SeqCst);
            done[i].store(true, Ordering::Release);
            (x * 10, rank)
        });
        // Values land in input order...
        let vals: Vec<usize> = out.iter().map(|&(v, _)| v).collect();
        assert_eq!(vals, (0..n).map(|x| x * 10).collect::<Vec<_>>());
        // ...even though completion genuinely happened in reverse.
        let ranks: Vec<usize> = out.iter().map(|&(_, r)| r).collect();
        assert_eq!(ranks, (0..n).rev().collect::<Vec<_>>());
    }

    #[test]
    fn jobs_zero_and_oversubscription_clamp() {
        let items = [1u8, 2, 3];
        assert_eq!(run_ordered(&items, 0, |_, &x| x), vec![1, 2, 3]);
        assert_eq!(run_ordered(&items, 999, |_, &x| x), vec![1, 2, 3]);
    }

    #[test]
    fn panicking_item_propagates_and_stops_claims() {
        let claimed = AtomicUsize::new(0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let items: Vec<u64> = (0..64).collect();
            run_ordered(&items, 2, |i, &x| {
                claimed.fetch_add(1, Ordering::SeqCst);
                if i == 0 {
                    panic!("cell died");
                }
                // Give the panicking worker time to raise the stop
                // flag so the tail of the queue goes unclaimed.
                std::thread::sleep(std::time::Duration::from_millis(1));
                x
            })
        }));
        assert!(res.is_err(), "panic must propagate out of the pool");
        let n = claimed.load(Ordering::SeqCst);
        assert!(n < 64, "stop flag did not halt claims ({n}/64 ran)");
    }

    /// Satellite (fault-plane PR): with more workers than items every
    /// surplus worker claims an out-of-range index and exits cleanly —
    /// results are complete, in order, and each item ran exactly once.
    #[test]
    fn more_jobs_than_items_runs_each_item_exactly_once() {
        let items: Vec<usize> = (0..3).collect();
        let runs: Vec<AtomicUsize> = items.iter().map(|_| AtomicUsize::new(0)).collect();
        for jobs in [4, 7, 64] {
            let out = run_ordered(&items, jobs, |i, &x| {
                runs[i].fetch_add(1, Ordering::SeqCst);
                x * 2
            });
            assert_eq!(out, vec![0, 2, 4], "jobs={jobs}");
        }
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 3, "item {i} re-ran under oversubscription");
        }
    }

    /// Satellite (fault-plane PR): a panic still propagates when the
    /// pool is oversubscribed — the stop flag and the unwind must not
    /// race the surplus workers' immediate exit.
    #[test]
    fn panic_propagates_with_more_jobs_than_items() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let items = [1u8, 2];
            run_ordered(&items, 16, |i, &x| {
                if i == 1 {
                    panic!("cell died");
                }
                x
            })
        }));
        assert!(res.is_err());
    }
}
