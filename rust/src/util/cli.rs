//! Tiny CLI argument parser (substrate; `clap` is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed() {
        let a = args("simulate --table 2 --seed=2048 extra --verbose");
        assert_eq!(a.positional, vec!["simulate", "extra"]);
        assert_eq!(a.get("table"), Some("2"));
        assert_eq!(a.get_u64("seed", 0), 2048);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_positional_not_swallowed_by_eq() {
        let a = args("--out=dir run");
        assert_eq!(a.get("out"), Some("dir"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = args("");
        assert_eq!(a.get_usize("n", 5), 5);
        assert_eq!(a.get_f64("x", 1.5), 1.5);
        assert!(!a.has_flag("v"));
    }
}
