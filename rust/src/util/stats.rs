//! Streaming statistics + fixed-bucket histograms for metrics and the
//! bench harness (criterion is not vendored; `bench.rs` builds on this).

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact-percentile reservoir: keeps every sample (fine at our scales),
/// sorts lazily on query.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).floor() as usize;
        s[idx]
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Log-spaced latency histogram (for Fig. 1a style CDFs).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    lo: f64,
    ratio: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl LogHistogram {
    /// Buckets geometrically spanning [lo, hi).
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n > 0);
        LogHistogram {
            lo,
            ratio: (hi / lo).powf(1.0 / n as f64),
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = (x / self.lo).ln() / self.ratio.ln();
        let idx = idx as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// (bucket_upper_bound, cumulative_fraction) series — a CDF.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let mut cum = self.underflow;
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut edge = self.lo;
        for b in &self.buckets {
            cum += b;
            edge *= self.ratio;
            out.push((edge, cum as f64 / total));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 3.5);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut c = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 3.0;
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            c.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - c.mean()).abs() < 1e-10);
        assert!((a.var() - c.var()).abs() < 1e-9);
        assert_eq!(a.count(), c.count());
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert_eq!(p.median(), 50.0);
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert_eq!(p.p99(), 99.0);
    }

    #[test]
    fn log_histogram_cdf_monotone() {
        let mut h = LogHistogram::new(0.01, 200.0, 32);
        for i in 1..1000 {
            h.add(i as f64 * 0.05);
        }
        let cdf = h.cdf();
        for w in cdf.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }
}
