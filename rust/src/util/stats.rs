//! Streaming statistics + fixed-bucket histograms for metrics and the
//! bench harness (criterion is not vendored; `bench.rs` builds on this).

/// Retained-sample budget for [`Summary`]'s quantile sketch. Up to this
/// many samples the sketch is *exact* (nearest-rank over every sample);
/// beyond it the sketch switches to bounded-memory streaming mode.
const QUANTILE_CAP: usize = 512;

/// Welford online mean/variance plus min/max, with streaming quantile
/// support (p50/p90/p99 for the serving plane's latency report —
/// DESIGN.md §13).
///
/// Quantiles are exact while `n ≤ QUANTILE_CAP`. Past that the sketch
/// thins systematically: it retains every `stride`-th arrival and
/// doubles `stride` whenever the buffer fills, so memory stays O(cap)
/// for any stream length. Estimates are deterministic — a pure function
/// of the input sequence, never of clocks or randomness — so two
/// summaries fed the same stream report bit-identical quantiles.
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    /// Retained samples for the quantile sketch, in arrival order:
    /// exactly the arrivals whose index is ≡ 0 (mod `stride`).
    qsamples: Vec<f64>,
    /// Arrivals represented per retained sample (a power of two; 1
    /// while the sketch is still exact).
    stride: u64,
}

impl Default for Summary {
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            qsamples: Vec::new(),
            stride: 1,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        // Quantile sketch: retain arrivals with index ≡ 0 (mod stride).
        if (self.n - 1) % self.stride == 0 {
            self.qsamples.push(x);
            if self.qsamples.len() >= QUANTILE_CAP {
                self.thin();
            }
        }
    }

    /// Halve the retained set by keeping even positions (in arrival
    /// order they are exactly the arrivals ≡ 0 mod the doubled stride).
    fn thin(&mut self) {
        let mut keep = 0;
        for i in (0..self.qsamples.len()).step_by(2) {
            self.qsamples[keep] = self.qsamples[i];
            keep += 1;
        }
        self.qsamples.truncate(keep);
        self.stride *= 2;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        // Quantile sketch: bring both sides to the coarser stride (so
        // every retained sample represents the same number of
        // arrivals), then concatenate and re-thin under the cap.
        let stride = self.stride.max(other.stride);
        thin_to(&mut self.qsamples, self.stride, stride);
        let mut theirs = other.qsamples.clone();
        thin_to(&mut theirs, other.stride, stride);
        self.qsamples.extend(theirs);
        self.stride = stride;
        while self.qsamples.len() >= QUANTILE_CAP {
            self.thin();
        }
    }

    /// Nearest-rank quantile estimate, `q` in [0, 1]. Exact while the
    /// stream fit the sketch (`n ≤ QUANTILE_CAP`); past that the
    /// estimate comes from the thinned retained set (each kept sample
    /// stands for `stride` arrivals). 0.0 on an empty summary.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.qsamples.is_empty() {
            return 0.0;
        }
        let mut s = self.qsamples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).floor() as usize;
        s[idx]
    }

    /// Median (`quantile(0.5)`).
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Thin `xs` (retained at `from`-stride, arrival order) down to a
/// coarser `to`-stride by keeping every `(to/from)`-th position.
fn thin_to(xs: &mut Vec<f64>, from: u64, to: u64) {
    if from == to {
        return;
    }
    let k = (to / from) as usize;
    let mut keep = 0;
    for i in (0..xs.len()).step_by(k.max(1)) {
        xs[keep] = xs[i];
        keep += 1;
    }
    xs.truncate(keep);
}

/// Exact-percentile reservoir: keeps every sample (fine at our scales),
/// sorts lazily on query.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).floor() as usize;
        s[idx]
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Log-spaced latency histogram (for Fig. 1a style CDFs).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    lo: f64,
    ratio: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl LogHistogram {
    /// Buckets geometrically spanning [lo, hi).
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n > 0);
        LogHistogram {
            lo,
            ratio: (hi / lo).powf(1.0 / n as f64),
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = (x / self.lo).ln() / self.ratio.ln();
        let idx = idx as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// (bucket_upper_bound, cumulative_fraction) series — a CDF.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let mut cum = self.underflow;
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut edge = self.lo;
        for b in &self.buckets {
            cum += b;
            edge *= self.ratio;
            out.push((edge, cum as f64 / total));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 3.5);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut c = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 3.0;
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            c.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - c.mean()).abs() < 1e-10);
        assert!((a.var() - c.var()).abs() < 1e-9);
        assert_eq!(a.count(), c.count());
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert_eq!(p.median(), 50.0);
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert_eq!(p.p99(), 99.0);
    }

    #[test]
    fn summary_quantiles_exact_small_n() {
        // Below the sketch cap, Summary's quantiles are exact and use
        // the same nearest-rank rule as Percentiles.
        let mut s = Summary::new();
        let mut p = Percentiles::new();
        for i in 1..=100 {
            s.add(i as f64);
            p.add(i as f64);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(s.quantile(q), p.quantile(q), "q={q}");
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p90(), 90.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn summary_quantiles_empty_is_zero() {
        let s = Summary::new();
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn summary_quantiles_streaming_large_n() {
        // 50k uniform draws: the thinned sketch must stay within a few
        // percent of the true quantiles while holding ≤ cap samples.
        let mut rng = crate::util::rng::Pcg64::new(7);
        let mut s = Summary::new();
        for _ in 0..50_000 {
            s.add(rng.f64());
        }
        assert!(s.qsamples.len() < 512, "sketch grew past cap: {}", s.qsamples.len());
        assert!((s.p50() - 0.50).abs() < 0.05, "p50 {}", s.p50());
        assert!((s.p90() - 0.90).abs() < 0.05, "p90 {}", s.p90());
        assert!((s.p99() - 0.99).abs() < 0.05, "p99 {}", s.p99());
    }

    #[test]
    fn summary_quantiles_deterministic() {
        // Bit-identical estimates for the same input sequence — the
        // serving plane byte-diffs reports containing these.
        let feed = |seed: u64| {
            let mut rng = crate::util::rng::Pcg64::new(seed);
            let mut s = Summary::new();
            for _ in 0..10_000 {
                s.add(rng.lognormal(0.0, 1.0));
            }
            (s.p50().to_bits(), s.p90().to_bits(), s.p99().to_bits())
        };
        assert_eq!(feed(42), feed(42));
        assert_ne!(feed(42), feed(43));
    }

    #[test]
    fn summary_quantile_merge_stays_close() {
        // Merged sketches approximate the combined stream (exact small
        // merges stay exact; large merges stay within tolerance).
        let mut a = Summary::new();
        let mut b = Summary::new();
        for i in 0..100 {
            a.add(i as f64);
            b.add((100 + i) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!((a.p50() - 99.0).abs() <= 2.0, "p50 {}", a.p50());

        let mut big_a = Summary::new();
        let mut big_b = Summary::new();
        let mut all = Summary::new();
        let mut rng = crate::util::rng::Pcg64::new(11);
        for i in 0..20_000 {
            let x = rng.f64() * 10.0;
            if i % 2 == 0 {
                big_a.add(x);
            } else {
                big_b.add(x);
            }
            all.add(x);
        }
        big_a.merge(&big_b);
        assert_eq!(big_a.count(), all.count());
        assert!((big_a.p50() - all.p50()).abs() < 0.5, "{} vs {}", big_a.p50(), all.p50());
        assert!((big_a.p99() - all.p99()).abs() < 0.5, "{} vs {}", big_a.p99(), all.p99());
    }

    #[test]
    fn log_histogram_cdf_monotone() {
        let mut h = LogHistogram::new(0.01, 200.0, 32);
        for i in 1..1000 {
            h.add(i as f64 * 0.05);
        }
        let cdf = h.cdf();
        for w in cdf.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }
}
