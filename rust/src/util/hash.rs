//! Fast non-cryptographic hashing for hot-path maps (substrate).
//!
//! The coordinator's request table and the experience store's key→slot
//! index sit on the per-call critical path; `std`'s default SipHash is
//! DoS-resistant but ~4–5× slower than needed for trusted in-process
//! keys (sequential request ids, `SampleKey` triples). This is an
//! FxHash-style multiply-xor word hasher: one rotate, one xor, one
//! multiply per 8-byte word. Never use it on attacker-controlled input.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-xor word hasher (FxHash family).
#[derive(Default, Clone)]
pub struct FastHasher {
    state: u64,
}

impl FastHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }
}

pub type BuildFastHasher = BuildHasherDefault<FastHasher>;

/// `HashMap` with the fast hasher — for trusted, in-process keys only.
pub type FastMap<K, V> = HashMap<K, V, BuildFastHasher>;
pub type FastSet<K> = HashSet<K, BuildFastHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FastMap<u64, u64> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i, i * 3);
        }
        for i in 0..1000u64 {
            assert_eq!(m.get(&i), Some(&(i * 3)));
        }
        assert_eq!(m.len(), 1000);
    }

    #[test]
    fn distinct_keys_rarely_collide() {
        let mut seen = FastSet::default();
        let mut hashes = std::collections::BTreeSet::new();
        for i in 0..10_000u64 {
            seen.insert(i);
            let mut h = FastHasher::default();
            h.write_u64(i);
            hashes.insert(h.finish());
        }
        assert_eq!(seen.len(), 10_000);
        // Sequential keys must not alias to a handful of buckets.
        assert!(hashes.len() > 9_990, "only {} distinct hashes", hashes.len());
    }

    #[test]
    fn struct_keys_work() {
        #[derive(Hash, PartialEq, Eq)]
        struct K(u64, u32, u64);
        let mut m: FastMap<K, usize> = FastMap::default();
        m.insert(K(1, 2, 3), 7);
        m.insert(K(1, 3, 2), 8);
        assert_eq!(m[&K(1, 2, 3)], 7);
        assert_eq!(m[&K(1, 3, 2)], 8);
    }
}
