//! Set/Get heterogeneous object store (§7 "System Implementation").
//!
//! FlexMARL unifies device and host memory behind KV semantics: each node
//! runs a *resident daemon* that tracks the distributed metadata of
//! heterogeneous objects; `Set` publishes an object (registering its
//! location), `Get` resolves the location and plans the cheapest transfer
//! path — D2D (intra-node HCCS or cross-node via RDMA), H2D/D2H
//! (offload), or RH2D (cross-node host staging + local host-to-device).
//!
//! Two consumers:
//!  * the simulator asks for *transfer latencies* (`TransferModel`)
//!    computed from `ClusterConfig` bandwidths + control-plane op costs —
//!    including the §9 lesson that per-parameter synchronization is
//!    control-plane dominated (O(N_params) kernel launches) while an
//!    aggregated contiguous buffer is O(1);
//!  * the real mini-cluster stores actual payload bytes (weights,
//!    optimizer state) for instance scaling and training-state swap.

use crate::cluster::{DeviceId, NodeId};
use crate::config::ClusterConfig;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Where an object currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    Device(DeviceId),
    Host(NodeId),
}

/// Transfer path classes of §7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    Local,       // already at destination
    D2dIntra,    // device→device, same node (HCCS)
    D2dCross,    // device→device, across nodes (RDMA)
    H2d,         // host→device, same node
    D2h,         // device→host, same node
    Rh2d,        // remote host → local host (RDMA, zero-copy) → device
    D2hCross,    // device → remote host
}

#[derive(Debug, Clone, Copy)]
pub struct TransferPlan {
    pub path: Path,
    pub bytes: f64,
    pub seconds: f64,
}

/// Latency model over the cluster fabric.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    pub cfg: ClusterConfig,
}

impl TransferModel {
    pub fn new(cfg: ClusterConfig) -> Self {
        TransferModel { cfg }
    }

    fn node_of(&self, d: DeviceId) -> NodeId {
        d / self.cfg.devices_per_node
    }

    /// Plan moving `bytes` from `src` to `dst` as ONE contiguous buffer
    /// (the optimized path: O(1) control-plane).
    pub fn plan(&self, src: Location, dst: Location, bytes: f64) -> TransferPlan {
        let (path, bw) = match (src, dst) {
            (a, b) if a == b => (Path::Local, f64::INFINITY),
            (Location::Device(s), Location::Device(d)) => {
                if self.node_of(s) == self.node_of(d) {
                    (Path::D2dIntra, self.cfg.d2d_bw)
                } else {
                    (Path::D2dCross, self.cfg.rdma_bw)
                }
            }
            (Location::Host(n), Location::Device(d)) => {
                if n == self.node_of(d) {
                    (Path::H2d, self.cfg.h2d_bw)
                } else {
                    // RH2D: RDMA host→host staged, then local H2D; the
                    // stages pipeline, so the slower link dominates.
                    (Path::Rh2d, self.cfg.rdma_bw.min(self.cfg.h2d_bw))
                }
            }
            (Location::Device(d), Location::Host(n)) => {
                if self.node_of(d) == n {
                    (Path::D2h, self.cfg.h2d_bw)
                } else {
                    (Path::D2hCross, self.cfg.rdma_bw.min(self.cfg.h2d_bw))
                }
            }
            (Location::Host(_), Location::Host(_)) => (Path::Rh2d, self.cfg.rdma_bw),
        };
        let wire = if bw.is_finite() { bytes / bw } else { 0.0 };
        TransferPlan {
            path,
            bytes,
            seconds: self.cfg.control_op_s + wire,
        }
    }

    /// The naive parameter-by-parameter synchronization the paper
    /// measured: every parameter tensor is its own transfer op, so the
    /// control plane (task scheduling + kernel launch) is paid `n_ops`
    /// times. §9: >99% of latency for billions of params; aggregating
    /// into one contiguous buffer gave ~200×.
    pub fn plan_per_param(
        &self,
        src: Location,
        dst: Location,
        bytes: f64,
        n_ops: u64,
    ) -> TransferPlan {
        let one = self.plan(src, dst, bytes);
        TransferPlan {
            path: one.path,
            bytes,
            seconds: self.cfg.control_op_s * n_ops as f64 + (one.seconds - self.cfg.control_op_s),
        }
    }
}

/// Object metadata held by the resident daemons.
#[derive(Debug, Clone)]
pub struct ObjectMeta {
    pub location: Location,
    pub bytes: f64,
    pub version: u64,
}

/// The distributed metadata plane + optional payload storage. A single
/// process stands in for all per-node daemons (they share one metadata
/// namespace in the paper too); `node_view` documents which daemon would
/// answer, but resolution is location-transparent either way.
#[derive(Debug, Default)]
pub struct MemStore {
    meta: Mutex<BTreeMap<String, ObjectMeta>>,
    payload: Mutex<BTreeMap<String, Arc<Vec<u8>>>>,
    /// pub/sub: keys → subscriber labels (instances awaiting weights).
    subs: Mutex<BTreeMap<String, Vec<String>>>,
    events: Mutex<Vec<String>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set API: register (and optionally store) an object. Bumps version.
    pub fn set(&self, key: &str, location: Location, bytes: f64, data: Option<Vec<u8>>) -> u64 {
        let mut meta = self.meta.lock().unwrap();
        let version = meta.get(key).map(|m| m.version + 1).unwrap_or(1);
        meta.insert(
            key.to_string(),
            ObjectMeta {
                location,
                bytes,
                version,
            },
        );
        if let Some(d) = data {
            self.payload.lock().unwrap().insert(key.to_string(), Arc::new(d));
        }
        // publish to subscribers
        let subs = self.subs.lock().unwrap();
        if let Some(waiters) = subs.get(key) {
            let mut ev = self.events.lock().unwrap();
            for w in waiters {
                ev.push(format!("notify {w}: {key} v{version}"));
            }
        }
        version
    }

    /// Get API: resolve location and plan the transfer to `dst`.
    pub fn get(&self, key: &str, dst: Location, model: &TransferModel) -> Option<TransferPlan> {
        let meta = self.meta.lock().unwrap();
        let m = meta.get(key)?;
        Some(model.plan(m.location, dst, m.bytes))
    }

    /// Get with relocation: also updates the metadata to the new location
    /// (move semantics, used by swap-in).
    pub fn take(&self, key: &str, dst: Location, model: &TransferModel) -> Option<TransferPlan> {
        let mut meta = self.meta.lock().unwrap();
        let m = meta.get_mut(key)?;
        let plan = model.plan(m.location, dst, m.bytes);
        m.location = dst;
        Some(plan)
    }

    pub fn payload(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.payload.lock().unwrap().get(key).cloned()
    }

    pub fn meta(&self, key: &str) -> Option<ObjectMeta> {
        self.meta.lock().unwrap().get(key).cloned()
    }

    pub fn subscribe(&self, key: &str, subscriber: &str) {
        self.subs
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_default()
            .push(subscriber.to_string());
    }

    pub fn drain_events(&self) -> Vec<String> {
        std::mem::take(&mut self.events.lock().unwrap())
    }

    pub fn remove(&self, key: &str) {
        self.meta.lock().unwrap().remove(key);
        self.payload.lock().unwrap().remove(key);
    }

    pub fn len(&self) -> usize {
        self.meta.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TransferModel {
        TransferModel::new(ClusterConfig::default())
    }

    #[test]
    fn path_classification() {
        let m = model();
        let dpn = m.cfg.devices_per_node;
        assert_eq!(m.plan(Location::Device(0), Location::Device(1), 1e9).path, Path::D2dIntra);
        assert_eq!(
            m.plan(Location::Device(0), Location::Device(dpn), 1e9).path,
            Path::D2dCross
        );
        assert_eq!(m.plan(Location::Host(0), Location::Device(0), 1e9).path, Path::H2d);
        assert_eq!(m.plan(Location::Device(0), Location::Host(0), 1e9).path, Path::D2h);
        assert_eq!(m.plan(Location::Host(1), Location::Device(0), 1e9).path, Path::Rh2d);
        assert_eq!(m.plan(Location::Device(0), Location::Device(0), 1e9).path, Path::Local);
    }

    #[test]
    fn intra_node_faster_than_cross() {
        let m = model();
        let dpn = m.cfg.devices_per_node;
        let intra = m.plan(Location::Device(0), Location::Device(1), 28e9).seconds;
        let cross = m.plan(Location::Device(0), Location::Device(dpn), 28e9).seconds;
        assert!(intra < cross);
    }

    #[test]
    fn contiguous_vs_per_param_200x_lesson() {
        // 14B params in bf16 = 28 GB; per-tensor sync ≈ 400 ops/layer ×
        // many layers — use 1e5 tensor ops (conservative vs per-param).
        let m = model();
        let bytes = 28e9;
        let contiguous = m.plan(Location::Device(0), Location::Device(1), bytes);
        let shattered = m.plan_per_param(Location::Device(0), Location::Device(1), bytes, 7_000_000);
        let speedup = shattered.seconds / contiguous.seconds;
        // §9: control plane >99% of latency, ~200× speedup from O(1).
        assert!(speedup > 100.0, "speedup {speedup}");
        let control_frac =
            (shattered.seconds - bytes / m.cfg.d2d_bw) / shattered.seconds;
        assert!(control_frac > 0.99, "control fraction {control_frac}");
    }

    #[test]
    fn set_get_roundtrip_with_payload() {
        let s = MemStore::new();
        let v1 = s.set("agentA/weights", Location::Device(3), 1e6, Some(vec![1, 2, 3]));
        assert_eq!(v1, 1);
        let v2 = s.set("agentA/weights", Location::Device(3), 1e6, Some(vec![4, 5]));
        assert_eq!(v2, 2);
        assert_eq!(*s.payload("agentA/weights").unwrap(), vec![4, 5]);
        let plan = s.get("agentA/weights", Location::Device(4), &model()).unwrap();
        assert_eq!(plan.path, Path::D2dIntra);
        assert!(s.get("missing", Location::Device(0), &model()).is_none());
    }

    #[test]
    fn take_relocates() {
        let s = MemStore::new();
        s.set("k", Location::Device(0), 2e9, None);
        let p = s.take("k", Location::Host(0), &model()).unwrap();
        assert_eq!(p.path, Path::D2h);
        // Second take from host to device on another node = RH2D.
        let p2 = s.take("k", Location::Device(100), &model()).unwrap();
        assert_eq!(p2.path, Path::Rh2d);
        assert_eq!(s.meta("k").unwrap().location, Location::Device(100));
    }

    #[test]
    fn pubsub_notifies_on_set() {
        let s = MemStore::new();
        s.subscribe("agentB/weights", "instance-7");
        s.set("agentB/weights", Location::Device(1), 1.0, None);
        let ev = s.drain_events();
        assert_eq!(ev.len(), 1);
        assert!(ev[0].contains("instance-7"));
        assert!(s.drain_events().is_empty());
    }

    #[test]
    fn fig11_swap_magnitudes() {
        // Offload (D2H) of ZeRO-3-sharded training states should land in
        // the paper's 0.5 s (3B) → 3.8 s (32B) band given per-device
        // sharding across the process group.
        use crate::config::ModelScale;
        let m = model();
        for (scale, lo, hi) in [
            (ModelScale::B3, 0.1, 1.5),
            (ModelScale::B32, 1.5, 6.0),
        ] {
            let shards = scale.train_group_devices() as f64;
            let per_dev = scale.train_state_bytes() / shards;
            // Per-device D2H offloads run in parallel across the group;
            // PCIe is shared 2:1 per node pair of devices.
            let t = m.plan(Location::Device(0), Location::Host(0), per_dev * 2.0).seconds;
            assert!(t > lo && t < hi, "{}B: {t}s", scale.params_b);
        }
    }
}
