//! Deterministic parallel sweep executor (DESIGN.md §4).
//!
//! The paper's headline numbers sweep frameworks × scenarios × seeds;
//! each cell is an independent, fully self-contained simulation, so the
//! sweep is embarrassingly parallel. The only hard requirement — byte-
//! identical output whatever the thread count (PR 2's CI diffs demand
//! it) — is met by construction:
//!
//! 1. a [`RunSpec`] is a *pure value*: framework, scenario, seed, and
//!    config overrides. [`RunSpec::apply`] derives the cell's
//!    `ExperimentConfig` from the base config and nothing else;
//! 2. per-spec seeds are *derived*, not drawn: [`derive_seed`] is a
//!    pure function of `(base_seed, replicate)`, so spec lists are
//!    identical however the grid is later scheduled;
//! 3. workers share no mutable simulation state — each cell builds its
//!    own engine — and [`crate::util::pool::run_ordered`] collects
//!    results in input order, never completion order.
//!
//! Every multi-run driver routes through here: `baselines::sweep` /
//! `scenario_sweep`, the `sweep` and `scenarios --run` CLI subcommands,
//! and both bench targets.

use crate::config::{ExperimentConfig, Framework};
use crate::error::PallasError;
use crate::metrics::StepReport;
use crate::orchestrator::SimOptions;
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::scenario;

/// Config knobs a grid may vary besides the three main axes. `None`
/// inherits the base config's value.
#[derive(Debug, Clone, Default)]
pub struct Overrides {
    pub steps: Option<usize>,
    pub micro_batch: Option<usize>,
    pub delta_threshold: Option<usize>,
    pub queries_per_step: Option<usize>,
    pub group_size: Option<usize>,
}

impl Overrides {
    fn apply(&self, cfg: &mut ExperimentConfig) {
        if let Some(v) = self.steps {
            cfg.steps = v;
        }
        if let Some(v) = self.micro_batch {
            cfg.pipeline.micro_batch = v;
        }
        if let Some(v) = self.delta_threshold {
            cfg.pipeline.delta_threshold = v;
        }
        if let Some(v) = self.queries_per_step {
            cfg.workload.queries_per_step = v;
        }
        if let Some(v) = self.group_size {
            cfg.workload.group_size = v;
        }
    }
}

/// One cell of a sweep grid: everything needed to derive the cell's
/// config from a base [`ExperimentConfig`], as a pure `Copy` value.
///
/// The scenario label and override block *borrow* from the grid that
/// expanded the spec (`'g`), so [`RunGrid::specs`] performs no per-spec
/// allocation — a framework × scenario × replicate expansion is a flat
/// `Vec` of copies over the grid's own axes, however large the grid.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec<'g> {
    /// Framework of this cell (`Copy` — the flags struct itself).
    pub framework: Framework,
    /// `None` inherits the base config's workload source verbatim
    /// (scenario *and* any trace). `Some(name)` generates fresh under
    /// that preset — a base trace is cleared, because a trace header is
    /// authoritative and would silently override the axis.
    pub scenario: Option<&'g str>,
    /// Derived replicate seed ([`derive_seed`]).
    pub seed: u64,
    /// Extra config knobs, shared by every cell of the grid.
    pub overrides: &'g Overrides,
}

impl RunSpec<'_> {
    /// Derive this cell's concrete config. Pure: same `(self, base)`
    /// in, same config out — the executor's determinism rests on it.
    pub fn apply(&self, base: &ExperimentConfig) -> ExperimentConfig {
        let mut cfg = base.clone();
        cfg.framework = self.framework;
        cfg.seed = self.seed;
        if let Some(s) = self.scenario {
            cfg.workload.scenario = s.to_string();
            cfg.workload.trace = None;
        }
        self.overrides.apply(&mut cfg);
        cfg
    }
}

/// Derived per-replicate RNG seed: SplitMix64 over the base seed and
/// the replicate index. Pure and stable — a spec's seed depends only on
/// its grid coordinates, never on scheduling. Replicate 0 keeps the
/// base seed itself so single-replicate grids match legacy sweeps
/// exactly.
pub fn derive_seed(base: u64, replicate: u64) -> u64 {
    if replicate == 0 {
        return base;
    }
    let mut z = base
        .wrapping_add(replicate.wrapping_mul(0x9e37_79b9_7f4a_7c15))
        .wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A framework × scenario × seed-replicate grid. Axes left empty
/// inherit the base config's value (a single-column axis).
#[derive(Debug, Clone, Default)]
pub struct RunGrid {
    pub frameworks: Vec<Framework>,
    pub scenarios: Vec<String>,
    /// Seed replicates per cell; 0 or 1 = just the base seed.
    pub replicates: usize,
    pub overrides: Overrides,
}

impl RunGrid {
    /// The full paper grid: every baseline framework × every scenario
    /// preset, one replicate.
    pub fn full() -> RunGrid {
        RunGrid {
            frameworks: Framework::all_baselines(),
            scenarios: scenario::owned_names(),
            replicates: 1,
            overrides: Overrides::default(),
        }
    }

    /// Expand to specs in deterministic row-major order: framework,
    /// then scenario, then replicate. This order *is* the output
    /// order, whatever `jobs` the executor later runs with.
    ///
    /// Specs borrow the grid's axes (scenario strings, the override
    /// block) rather than cloning them per cell, so expansion is one
    /// `Vec` allocation plus one tiny axis `Vec` — allocation-free in
    /// the per-spec loop however many cells the grid has.
    pub fn specs(&self, base: &ExperimentConfig) -> Vec<RunSpec<'_>> {
        let base_fw = [base.framework];
        let fw_axis: &[Framework] = if self.frameworks.is_empty() {
            &base_fw
        } else {
            &self.frameworks
        };
        let scen_axis: Vec<Option<&str>> = if self.scenarios.is_empty() {
            vec![None]
        } else {
            self.scenarios.iter().map(|s| Some(s.as_str())).collect()
        };
        let reps = self.replicates.max(1);
        let mut out = Vec::with_capacity(fw_axis.len() * scen_axis.len() * reps);
        for &fw in fw_axis {
            for &scen in &scen_axis {
                for r in 0..reps {
                    out.push(RunSpec {
                        framework: fw,
                        scenario: scen,
                        seed: derive_seed(base.seed, r as u64),
                        overrides: &self.overrides,
                    });
                }
            }
        }
        out
    }
}

/// Execute every spec against the base config on up to `jobs` worker
/// threads; results come back in spec order (bit-identical for any
/// `jobs` — each cell's simulation is self-contained and the pool
/// collects by input index). Resolution failures (unknown scenario,
/// bad trace) surface per-cell as `Err`.
///
/// Known cost on the rare inherited-trace path: cells with
/// `scenario: None` over a trace-backed base each re-read and re-parse
/// the trace file (the PR-2 "parse once" property holds per run, not
/// per sweep). Scenario axes — every sweep this crate ships — clear
/// the trace, so no shipped grid pays it.
pub fn run_specs(
    base: &ExperimentConfig,
    opts: &SimOptions,
    specs: &[RunSpec<'_>],
    jobs: usize,
) -> Vec<Result<StepReport, PallasError>> {
    run_specs_streamed(base, opts, specs, jobs, |_, _| {})
}

/// [`run_specs`] with a per-cell completion callback: `on_cell(i,
/// &result)` fires from the worker thread the moment cell `i`'s
/// simulation finishes — in *completion* order, which depends on
/// scheduling. This is the sweep's streaming surface (`--progress`
/// per-cell lines, `--emit jsonl` cell streams); each callback's
/// *content* is still deterministic per cell, and the returned vector
/// — the only thing the grid report is built from — stays in input
/// order, byte-identical for any `jobs`.
///
/// The callback runs under no lock: serialize shared output
/// (stdout/stderr) yourself if cells may interleave.
pub fn run_specs_streamed(
    base: &ExperimentConfig,
    opts: &SimOptions,
    specs: &[RunSpec<'_>],
    jobs: usize,
    on_cell: impl Fn(usize, &Result<StepReport, PallasError>) + Sync,
) -> Vec<Result<StepReport, PallasError>> {
    // Feed the owned per-cell config straight into the builder:
    // `spec.apply` already materializes it, so going through
    // `try_evaluate` (which clones its borrowed config) would pay a
    // second full-config copy per cell. The typed path all the way
    // down: a cell that trips the engine's event budget comes back as
    // that cell's `Err`, not a worker-thread panic.
    pool::run_ordered(specs, jobs, |i, spec| {
        let res = crate::experiment::Experiment::new(spec.apply(base))
            .options(opts.clone())
            .build()
            .and_then(crate::experiment::Experiment::try_evaluate);
        on_cell(i, &res);
        res
    })
}

/// [`run_specs`] with errors promoted to panics — the library-internal
/// sweep paths whose callers already accept the panicking `evaluate`
/// semantics.
pub fn run_specs_or_panic(
    base: &ExperimentConfig,
    opts: &SimOptions,
    specs: &[RunSpec<'_>],
    jobs: usize,
) -> Vec<StepReport> {
    run_specs(base, opts, specs, jobs)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("workload resolution failed: {e}")))
        .collect()
}

/// One JSON report for a whole grid. Deliberately excludes job count
/// and wall time: the document must be byte-identical for any `jobs`
/// (CI diffs `sweep --jobs 1` against `--jobs 2`). Seeds are emitted
/// as strings — u64 seeds above 2^53 would be lossy as JSON numbers.
/// The per-run `scenario` label is taken from the *report* (the
/// scenario the simulation actually resolved), so inherited axes,
/// alias spellings, and authoritative trace headers all label
/// correctly; `base_steps` is the base config's step count (a spec's
/// `Overrides.steps` shows up in its own report, not here).
pub fn grid_report(
    base: &ExperimentConfig,
    specs: &[RunSpec<'_>],
    reports: &[StepReport],
) -> Json {
    assert_eq!(specs.len(), reports.len(), "one report per spec");
    let runs = specs.iter().zip(reports).map(|(s, r)| {
        Json::obj(vec![
            ("framework", Json::str(s.framework.name)),
            ("scenario", Json::str(r.scenario.clone())),
            ("seed", Json::str(s.seed.to_string())),
            ("report", r.to_json()),
        ])
    });
    Json::obj(vec![
        ("workload", Json::str(base.workload.name.clone())),
        ("base_seed", Json::str(base.seed.to_string())),
        ("base_steps", Json::num(base.steps as f64)),
        ("runs", Json::arr(runs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn small_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.steps = 1;
        cfg
    }

    #[test]
    fn grid_order_is_row_major_and_stable() {
        let base = small_base();
        let grid = RunGrid {
            frameworks: vec![Framework::mas_rl(), Framework::flexmarl()],
            scenarios: vec!["baseline".into(), "uniform".into()],
            replicates: 2,
            overrides: Overrides::default(),
        };
        let specs = grid.specs(&base);
        assert_eq!(specs.len(), 8);
        assert_eq!(specs[0].framework.name, "MAS-RL");
        assert_eq!(specs[0].scenario, Some("baseline"));
        assert_eq!(specs[0].seed, base.seed);
        assert_eq!(specs[1].seed, derive_seed(base.seed, 1));
        assert_ne!(specs[1].seed, base.seed);
        assert_eq!(specs[2].scenario, Some("uniform"));
        assert_eq!(specs[4].framework.name, "FlexMARL");
        // Same grid, same base → identical spec list (pure expansion).
        let again = grid.specs(&base);
        for (a, b) in specs.iter().zip(&again) {
            assert_eq!(a.framework.name, b.framework.name);
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.seed, b.seed);
        }
    }

    #[test]
    fn empty_axes_inherit_base() {
        let mut base = small_base();
        base.framework = Framework::marti();
        base.workload.scenario = "core_skew".into();
        let grid = RunGrid::default();
        let specs = grid.specs(&base);
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].framework.name, "MARTI");
        assert_eq!(specs[0].scenario, None);
        let cfg = specs[0].apply(&base);
        assert_eq!(cfg.workload.scenario, "core_skew");
        assert_eq!(cfg.seed, base.seed);
    }

    #[test]
    fn spec_scenario_clears_base_trace() {
        let mut base = small_base();
        base.workload.trace = Some("recorded.jsonl".into());
        let ov = Overrides::default();
        let spec = RunSpec {
            framework: Framework::flexmarl(),
            scenario: Some("bursty"),
            seed: 7,
            overrides: &ov,
        };
        let cfg = spec.apply(&base);
        assert_eq!(cfg.workload.scenario, "bursty");
        assert_eq!(cfg.workload.trace, None);
        // Inheriting specs keep the trace source.
        let inherit = RunSpec { scenario: None, ..spec };
        assert_eq!(
            inherit.apply(&base).workload.trace.as_deref(),
            Some("recorded.jsonl")
        );
    }

    #[test]
    fn overrides_apply() {
        let base = small_base();
        let ov = Overrides {
            steps: Some(4),
            micro_batch: Some(8),
            delta_threshold: Some(9),
            queries_per_step: Some(3),
            group_size: Some(8),
        };
        let spec = RunSpec {
            framework: Framework::dist_rl(),
            scenario: None,
            seed: base.seed,
            overrides: &ov,
        };
        let cfg = spec.apply(&base);
        assert_eq!(cfg.steps, 4);
        assert_eq!(cfg.pipeline.micro_batch, 8);
        assert_eq!(cfg.pipeline.delta_threshold, 9);
        assert_eq!(cfg.workload.queries_per_step, 3);
        assert_eq!(cfg.workload.group_size, 8);
    }

    #[test]
    fn derive_seed_is_stable_and_decorrelated() {
        assert_eq!(derive_seed(2048, 0), 2048);
        let a = derive_seed(2048, 1);
        let b = derive_seed(2048, 2);
        assert_eq!(a, derive_seed(2048, 1));
        assert_ne!(a, b);
        assert_ne!(a, 2048);
    }

    #[test]
    fn executor_is_thread_count_invariant_on_a_real_grid() {
        let base = small_base();
        let grid = RunGrid {
            frameworks: vec![Framework::flexmarl(), Framework::dist_rl()],
            scenarios: vec!["baseline".into(), "core_skew".into()],
            replicates: 1,
            overrides: Overrides::default(),
        };
        let specs = grid.specs(&base);
        let opts = SimOptions::default();
        let render = |jobs: usize| {
            let reports = run_specs_or_panic(&base, &opts, &specs, jobs);
            grid_report(&base, &specs, &reports).to_pretty()
        };
        let one = render(1);
        assert_eq!(one, render(2));
        assert_eq!(one, render(4));
    }

    #[test]
    fn bad_scenario_surfaces_as_err_in_its_cell_only() {
        let base = small_base();
        let ov = Overrides::default();
        let specs = vec![
            RunSpec {
                framework: Framework::flexmarl(),
                scenario: Some("baseline"),
                seed: base.seed,
                overrides: &ov,
            },
            RunSpec {
                framework: Framework::flexmarl(),
                scenario: Some("gibberish"),
                seed: base.seed,
                overrides: &ov,
            },
        ];
        let out = run_specs(&base, &SimOptions::default(), &specs, 2);
        assert!(out[0].is_ok());
        let err = out[1].as_ref().unwrap_err();
        assert_eq!(
            *err,
            crate::error::PallasError::UnknownScenario("gibberish".into())
        );
        assert!(err.to_string().contains("gibberish"), "{err}");
    }
}
