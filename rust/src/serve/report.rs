//! The plane-level load report (DESIGN.md §13).
//!
//! Everything in a [`LoadReport`] is a pure function of the
//! [`ServeConfig`](super::ServeConfig) — counters and gauges come from
//! the virtual-tick [`Schedule`], latency quantiles from the sessions'
//! *virtual* step latencies — so its JSON is byte-identical for any
//! worker count and CI diffs it directly. Wall-clock numbers live in
//! [`ServeOutcome::wall_s`](super::ServeOutcome::wall_s) and the bench
//! group, never here.

use super::sched::{Disposition, Schedule};
use super::ServeConfig;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Per-tenant admission/service counters plus queue-wait quantiles.
#[derive(Debug, Clone, Default)]
pub struct TenantReport {
    pub name: String,
    /// Requests that arrived (every fate included).
    pub submitted: u64,
    /// Requests that entered the intake queue.
    pub admitted: u64,
    pub rejected_queue_full: u64,
    pub rejected_quota: u64,
    /// Admitted but start deadline passed while queued.
    pub expired: u64,
    /// Dispatched and run to completion.
    pub completed: u64,
    /// Queue wait (dispatch tick − arrival tick) of completed sessions.
    pub wait_ticks: Summary,
}

impl TenantReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tenant", Json::str(self.name.clone())),
            ("submitted", Json::num(self.submitted as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected_queue_full", Json::num(self.rejected_queue_full as f64)),
            ("rejected_quota", Json::num(self.rejected_quota as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("wait_ticks", summary_json(&self.wait_ticks)),
        ])
    }
}

/// The whole plane's deterministic counters, gauges and quantiles.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub mix: String,
    pub seed: u64,
    pub ticks: u64,
    pub slots: usize,
    pub queue_cap: usize,
    pub submitted: u64,
    pub admitted: u64,
    pub rejected_queue_full: u64,
    pub rejected_quota: u64,
    pub expired: u64,
    pub completed: u64,
    /// Tick the last session released its slot.
    pub makespan_ticks: u64,
    /// Deterministic throughput: completed sessions per 1000 virtual
    /// ticks of makespan.
    pub sessions_per_kilotick: f64,
    pub queue_depth_max: usize,
    pub queue_depth_mean: f64,
    /// Queue wait of completed sessions, plane-wide.
    pub wait_ticks: Summary,
    /// Per-step virtual end-to-end latency across completed sessions,
    /// in simulated seconds (p50/p99 are the serve SLO numbers).
    pub step_latency_s: Summary,
    pub tenants: Vec<TenantReport>,
}

impl LoadReport {
    /// Aggregate `schedule` (plus the arrival-ordered virtual step
    /// latencies of completed sessions) into the report.
    pub fn build(cfg: &ServeConfig, schedule: &Schedule, step_latencies: &[f64]) -> LoadReport {
        let mut tenants: Vec<TenantReport> = cfg
            .tenants
            .iter()
            .map(|t| TenantReport {
                name: t.name.clone(),
                ..TenantReport::default()
            })
            .collect();
        let mut wait_ticks = Summary::new();
        for d in &schedule.decisions {
            let t = &mut tenants[d.request.tenant];
            t.submitted += 1;
            match d.disposition {
                Disposition::RejectedQueueFull => t.rejected_queue_full += 1,
                Disposition::RejectedQuota => t.rejected_quota += 1,
                Disposition::Expired => {
                    t.admitted += 1;
                    t.expired += 1;
                }
                Disposition::Completed { start_tick, .. } => {
                    t.admitted += 1;
                    t.completed += 1;
                    let wait = (start_tick - d.request.arrival_tick) as f64;
                    t.wait_ticks.add(wait);
                    wait_ticks.add(wait);
                }
            }
        }
        let sum = |f: fn(&TenantReport) -> u64| tenants.iter().map(f).sum::<u64>();
        let completed = sum(|t| t.completed);
        let mut step_latency_s = Summary::new();
        for &l in step_latencies {
            step_latency_s.add(l);
        }
        LoadReport {
            mix: cfg.mix.clone(),
            seed: cfg.seed,
            ticks: cfg.ticks,
            slots: cfg.slots,
            queue_cap: cfg.queue_cap,
            submitted: sum(|t| t.submitted),
            admitted: sum(|t| t.admitted),
            rejected_queue_full: sum(|t| t.rejected_queue_full),
            rejected_quota: sum(|t| t.rejected_quota),
            expired: sum(|t| t.expired),
            completed,
            makespan_ticks: schedule.makespan_ticks,
            sessions_per_kilotick: completed as f64 * 1000.0
                / (schedule.makespan_ticks.max(1)) as f64,
            queue_depth_max: schedule.queue_depth_max,
            queue_depth_mean: schedule.queue_depth_mean(),
            wait_ticks,
            step_latency_s,
            tenants,
        }
    }

    /// The machine-readable load report — every field deterministic.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mix", Json::str(self.mix.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("ticks", Json::num(self.ticks as f64)),
            ("slots", Json::num(self.slots as f64)),
            ("queue_cap", Json::num(self.queue_cap as f64)),
            ("submitted", Json::num(self.submitted as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected_queue_full", Json::num(self.rejected_queue_full as f64)),
            ("rejected_quota", Json::num(self.rejected_quota as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("makespan_ticks", Json::num(self.makespan_ticks as f64)),
            ("sessions_per_kilotick", Json::num(self.sessions_per_kilotick)),
            ("queue_depth_max", Json::num(self.queue_depth_max as f64)),
            ("queue_depth_mean", Json::num(self.queue_depth_mean)),
            ("wait_ticks", summary_json(&self.wait_ticks)),
            ("step_latency_s", summary_json(&self.step_latency_s)),
            ("tenants", Json::arr(self.tenants.iter().map(|t| t.to_json()))),
        ])
    }
}

/// p50/p90/p99 + moments of a [`Summary`], as deterministic JSON.
fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count() as f64)),
        ("mean", Json::num(s.mean())),
        ("p50", Json::num(s.p50())),
        ("p90", Json::num(s.p90())),
        ("p99", Json::num(s.p99())),
        ("max", Json::num(s.max())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sched;

    #[test]
    fn report_counters_are_complete_and_consistent() {
        let cfg = ServeConfig::mix("mixed", 3).unwrap();
        let schedule = sched::plan(&cfg);
        let report = LoadReport::build(&cfg, &schedule, &[1.0, 2.0]);
        assert_eq!(report.submitted as usize, schedule.decisions.len());
        assert_eq!(
            report.admitted,
            report.expired + report.completed,
            "admitted must split exactly into expired + completed"
        );
        assert_eq!(
            report.submitted,
            report.admitted + report.rejected_queue_full + report.rejected_quota,
            "every request needs exactly one fate"
        );
        let tenant_sum: u64 = report.tenants.iter().map(|t| t.submitted).sum();
        assert_eq!(tenant_sum, report.submitted);
        assert_eq!(report.step_latency_s.count(), 2);
    }

    #[test]
    fn report_json_is_deterministic() {
        let cfg = ServeConfig::mix("flash", 17).unwrap();
        let schedule = sched::plan(&cfg);
        let lats = [0.5, 0.25, 4.0];
        let a = LoadReport::build(&cfg, &schedule, &lats).to_json().to_pretty();
        let b = LoadReport::build(&cfg, &sched::plan(&cfg), &lats).to_json().to_pretty();
        assert_eq!(a, b);
        assert!(a.contains("sessions_per_kilotick"));
        assert!(a.contains("\"p99\""));
    }
}
