//! Rollout-as-a-Service serving plane (DESIGN.md §13).
//!
//! A [`ServePlane`] multiplexes many concurrent simulation sessions —
//! each a complete [`crate::experiment::Experiment`] run — over a pool
//! of long-lived workers ([`crate::util::pool::WorkerPool`]), with
//! admission control, per-tenant quotas, priority classes, weighted
//! fair scheduling, and EDF dispatch.
//!
//! # The determinism contract
//!
//! Everything observable is byte-reproducible for a fixed seed, for
//! **any** worker count:
//!
//! * *Scheduling* happens in virtual ticks, entirely before execution
//!   ([`sched::plan`]): admissions, rejections, expiries, dispatch
//!   order and per-session start/finish ticks are a pure function of
//!   the [`ServeConfig`].
//! * *Execution* only realizes the plan: each admitted session is a
//!   pure function of its derived config and writes its JSONL stream
//!   into a pre-assigned slot ([`crate::orchestrator::CaptureBuffer`]);
//!   aggregation reads slots in arrival order. Thread scheduling can
//!   reorder *work*, never *output*.
//! * Per-session bytes equal the same config run standalone through
//!   [`crate::experiment::Experiment`] with a
//!   [`crate::orchestrator::JsonlSink`] — pinned in `tests/serve.rs`.
//!
//! Wall-clock numbers (worker speedup, real sessions/sec) exist only in
//! [`ServeOutcome::wall_s`] and the bench group — never in the byte-
//! diffed [`report::LoadReport`].

pub mod report;
pub mod sched;

use crate::config::{ExperimentConfig, Framework, WorkloadConfig};
use crate::error::PallasError;
use crate::experiment::Experiment;
use crate::orchestrator::{CaptureBuffer, JsonlSink, SimOptions};
use crate::util::pool::WorkerPool;
use crate::workload::arrival::ArrivalProcess;
use report::LoadReport;
use sched::{Disposition, Request, Schedule};
use std::sync::{Arc, Mutex};

/// One tenant of the serving plane: an arrival stream plus its service
/// class and session shape.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Open-loop arrival process (arrivals per virtual tick).
    pub arrivals: ArrivalProcess,
    /// Strict priority class; lower runs first (0 = most urgent).
    pub priority: u8,
    /// Fair-share weight inside the class (stride scheduling).
    pub weight: u32,
    /// Max outstanding (queued + in-service) sessions.
    pub quota: usize,
    /// Latest start, in ticks after arrival; `None` never expires.
    pub deadline_ticks: Option<u64>,
    /// Traffic-shape scenario each of this tenant's sessions simulates.
    pub scenario: String,
    /// MARL steps per session.
    pub steps: usize,
}

/// Full configuration of one serve run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    pub seed: u64,
    /// Open-loop arrival window, in virtual ticks (the plane then
    /// drains; the schedule's makespan can exceed this).
    pub ticks: u64,
    /// Virtual service concurrency: sessions in service at once. This
    /// is *scheduling* state — physical workers are a [`ServePlane`]
    /// parameter and never affect any output byte.
    pub slots: usize,
    /// Intake queue capacity (the admission bound).
    pub queue_cap: usize,
    /// Virtual ticks one MARL step occupies a slot for.
    pub service_ticks_per_step: u64,
    pub tenants: Vec<TenantSpec>,
    /// Session workload shape (default [`WorkloadConfig::tiny`]).
    pub base: WorkloadConfig,
    /// Optional recorded trace every session replays instead of
    /// generating its workload.
    pub trace: Option<String>,
    /// Mix name, echoed into the load report.
    pub mix: String,
}

/// Named tenant mixes for the CLI, CI and benches.
pub const MIX_NAMES: &[&str] = &["steady", "mixed", "flash"];

fn tenant(
    name: &str,
    arrivals: ArrivalProcess,
    priority: u8,
    weight: u32,
    quota: usize,
    deadline_ticks: Option<u64>,
    scenario: &str,
    steps: usize,
) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        arrivals,
        priority,
        weight,
        quota,
        deadline_ticks,
        scenario: scenario.to_string(),
        steps,
    }
}

impl ServeConfig {
    /// Build a named mix (see [`MIX_NAMES`]). All mixes use
    /// [`WorkloadConfig::tiny`] sessions so hundreds fit in a CI run;
    /// their tenant sets are sized so a default run exercises every
    /// admission outcome (accepts, both reject kinds, expiries).
    pub fn mix(name: &str, seed: u64) -> Result<ServeConfig, PallasError> {
        let tenants = match name {
            "steady" => vec![
                tenant("interactive", ArrivalProcess::poisson(1.0), 0, 2, 8, None, "baseline", 1),
                tenant("batch", ArrivalProcess::poisson(0.8), 1, 1, 6, None, "uniform", 2),
            ],
            "mixed" => vec![
                tenant(
                    "interactive",
                    ArrivalProcess::poisson(1.0),
                    0,
                    4,
                    6,
                    Some(6),
                    "baseline",
                    1,
                ),
                tenant("batch", ArrivalProcess::poisson(1.5), 1, 1, 4, None, "core_skew", 2),
                tenant(
                    "diurnal",
                    ArrivalProcess::poisson(0.8).with_diurnal(1.5, 32),
                    1,
                    2,
                    4,
                    Some(24),
                    "bursty",
                    1,
                ),
            ],
            "flash" => vec![
                tenant(
                    "interactive",
                    ArrivalProcess::poisson(0.8),
                    0,
                    4,
                    6,
                    Some(6),
                    "baseline",
                    1,
                ),
                // Quota larger than the intake queue: a flash crowd
                // can slam the shared queue itself, so this mix
                // exercises queue-full rejects, not just quota ones.
                tenant(
                    "burst",
                    ArrivalProcess::poisson(0.6).with_flash(0.15, 4.0, 3),
                    1,
                    2,
                    20,
                    Some(12),
                    "bursty",
                    1,
                ),
                tenant("batch", ArrivalProcess::poisson(1.2), 2, 1, 3, None, "uniform", 2),
            ],
            other => {
                return Err(PallasError::InvalidConfig(format!(
                    "unknown serve mix '{other}' (available: {})",
                    MIX_NAMES.join(", ")
                )))
            }
        };
        Ok(ServeConfig {
            seed,
            ticks: 200,
            slots: 4,
            queue_cap: 16,
            service_ticks_per_step: 2,
            tenants,
            base: WorkloadConfig::tiny(),
            trace: None,
            mix: name.to_string(),
        })
    }

    pub fn validate(&self) -> Result<(), PallasError> {
        let bad = |m: String| Err(PallasError::InvalidConfig(m));
        if self.tenants.is_empty() {
            return bad("serve: no tenants".into());
        }
        if self.ticks == 0 || self.slots == 0 || self.queue_cap == 0 {
            return bad(format!(
                "serve: ticks ({}), slots ({}) and queue_cap ({}) must be positive",
                self.ticks, self.slots, self.queue_cap
            ));
        }
        if self.service_ticks_per_step == 0 {
            return bad("serve: service_ticks_per_step must be positive".into());
        }
        let mut names: Vec<&str> = self.tenants.iter().map(|t| t.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.tenants.len() {
            return bad("serve: tenant names must be unique".into());
        }
        for t in &self.tenants {
            if t.name.is_empty() {
                return bad("serve: tenant name must be non-empty".into());
            }
            if t.weight == 0 || t.quota == 0 || t.steps == 0 {
                return bad(format!(
                    "serve: tenant '{}': weight ({}), quota ({}) and steps ({}) must be positive",
                    t.name, t.weight, t.quota, t.steps
                ));
            }
            if crate::workload::scenario::by_name(&t.scenario).is_none() {
                return Err(PallasError::UnknownScenario(t.scenario.clone()));
            }
        }
        // The shared session shape must itself be a valid experiment.
        ExperimentConfig::new(self.base.clone(), Framework::flexmarl()).validate()
    }

    /// The standalone config for one admitted session — exactly what a
    /// user would hand to [`Experiment`] directly. The plane's
    /// byte-identity contract is a corollary of sessions being this
    /// pure function of the request.
    pub fn session_config(&self, req: &Request) -> ExperimentConfig {
        let mut wl = self.base.clone();
        wl.scenario = self.tenants[req.tenant].scenario.clone();
        wl.trace = self.trace.clone();
        let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
        cfg.steps = req.steps;
        cfg.seed = req.seed;
        cfg
    }
}

/// One completed session's captured output.
#[derive(Debug, Clone)]
pub struct SessionOutput {
    pub seq: u64,
    pub tenant: String,
    /// Engine seed — rerun [`ServeConfig::session_config`] standalone
    /// with this to reproduce `jsonl` byte-for-byte.
    pub seed: u64,
    pub start_tick: u64,
    pub finish_tick: u64,
    /// The session's JSONL stream: one
    /// [`crate::metrics::StepReport::to_json`] line per step.
    pub jsonl: Vec<u8>,
}

/// Everything one serve run produces.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// The deterministic plan (every request's fate).
    pub schedule: Schedule,
    /// Completed sessions in arrival order.
    pub sessions: Vec<SessionOutput>,
    /// The deterministic load report (byte-diffed in CI).
    pub report: LoadReport,
    /// Wall-clock execution time — stderr/bench material only; never
    /// part of the report.
    pub wall_s: f64,
}

/// The serving plane: a validated config plus a physical worker count.
pub struct ServePlane {
    cfg: ServeConfig,
    workers: usize,
}

impl ServePlane {
    /// Validate `cfg` and bind it to `workers.max(1)` execution
    /// threads. Workers affect wall time only.
    pub fn new(cfg: ServeConfig, workers: usize) -> Result<ServePlane, PallasError> {
        cfg.validate()?;
        Ok(ServePlane {
            cfg,
            workers: workers.max(1),
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run the plane: compute the schedule, execute every admitted
    /// session on the worker pool, aggregate in arrival order.
    pub fn run(&self) -> Result<ServeOutcome, PallasError> {
        let schedule = sched::plan(&self.cfg);
        let jobs: Vec<&sched::Decision> = schedule
            .decisions
            .iter()
            .filter(|d| matches!(d.disposition, Disposition::Completed { .. }))
            .collect();

        // One pre-assigned slot per session; workers write their own
        // slot, the aggregation loop below reads them in arrival order
        // — the WorkerPool determinism discipline.
        type SlotValue = Result<(Vec<u8>, Vec<f64>), PallasError>;
        let slots: Arc<Vec<Mutex<Option<SlotValue>>>> =
            Arc::new(jobs.iter().map(|_| Mutex::new(None)).collect());
        let t0 = std::time::Instant::now();
        {
            let pool = WorkerPool::new(self.workers);
            for (i, d) in jobs.iter().enumerate() {
                let cfg = self.cfg.session_config(&d.request);
                let slots = Arc::clone(&slots);
                pool.submit(move || {
                    *slots[i].lock().expect("serve slot poisoned") = Some(run_session(cfg));
                });
            }
            pool.wait_idle();
        }
        let wall_s = t0.elapsed().as_secs_f64();

        let mut sessions = Vec::with_capacity(jobs.len());
        let mut step_latencies = Vec::new();
        for (i, d) in jobs.iter().enumerate() {
            let res = slots[i]
                .lock()
                .expect("serve slot poisoned")
                .take()
                .expect("serve: worker skipped a session slot");
            let (jsonl, lats) = res?;
            step_latencies.extend(lats);
            let Disposition::Completed {
                start_tick,
                finish_tick,
            } = d.disposition
            else {
                unreachable!("jobs holds only completed dispositions")
            };
            sessions.push(SessionOutput {
                seq: d.request.seq,
                tenant: self.cfg.tenants[d.request.tenant].name.clone(),
                seed: d.request.seed,
                start_tick,
                finish_tick,
                jsonl,
            });
        }
        let report = LoadReport::build(&self.cfg, &schedule, &step_latencies);
        Ok(ServeOutcome {
            schedule,
            sessions,
            report,
            wall_s,
        })
    }
}

/// Execute one admitted session exactly as a standalone run would:
/// fresh engine, default options, a [`JsonlSink`] capturing the
/// per-step stream. Returns the captured bytes plus each step's
/// virtual end-to-end latency (for the report's quantiles).
fn run_session(cfg: ExperimentConfig) -> Result<(Vec<u8>, Vec<f64>), PallasError> {
    let buf = CaptureBuffer::new();
    let outcome = Experiment::new(cfg)
        .options(SimOptions::default())
        .sink(Box::new(JsonlSink::new(Box::new(buf.clone()))))
        .build()?
        .try_run()?;
    let lats = outcome.reports.iter().map(|r| r.e2e_s).collect();
    Ok((buf.contents(), lats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixes_validate_and_unknown_is_typed() {
        for name in MIX_NAMES {
            ServeConfig::mix(name, 7).unwrap().validate().unwrap();
        }
        let e = ServeConfig::mix("warp", 7).unwrap_err();
        assert!(e.to_string().contains("unknown serve mix 'warp'"));
    }

    #[test]
    fn plan_is_deterministic_and_covers_every_request() {
        let cfg = ServeConfig::mix("mixed", 11).unwrap();
        let a = sched::plan(&cfg);
        let b = sched::plan(&cfg);
        assert_eq!(a, b);
        // seq-complete: every arrival 0..n appears exactly once.
        for (i, d) in a.decisions.iter().enumerate() {
            assert_eq!(d.request.seq, i as u64);
        }
        assert!(!a.decisions.is_empty());
    }

    #[test]
    fn default_mixes_exercise_every_admission_outcome() {
        for name in MIX_NAMES {
            let cfg = ServeConfig::mix(name, 2048).unwrap();
            let plan = sched::plan(&cfg);
            let count = |want: fn(&Disposition) -> bool| {
                plan.decisions.iter().filter(|d| want(&d.disposition)).count()
            };
            let completed = count(|d| matches!(d, Disposition::Completed { .. }));
            let rejected = count(|d| {
                matches!(d, Disposition::RejectedQueueFull | Disposition::RejectedQuota)
            });
            assert!(completed > 0, "{name}: nothing completed");
            assert!(rejected > 0, "{name}: admission control never engaged");
        }
    }

    #[test]
    fn expired_requests_are_counted_not_dropped() {
        // Single slow tenant with an immediate deadline and one slot:
        // almost everything queued must expire, and every arrival still
        // has a decision.
        let mut cfg = ServeConfig::mix("steady", 5).unwrap();
        cfg.ticks = 20;
        cfg.slots = 1;
        cfg.tenants.truncate(1);
        cfg.tenants[0].deadline_ticks = Some(0);
        cfg.tenants[0].quota = 100;
        let plan = sched::plan(&cfg);
        let expired = plan
            .decisions
            .iter()
            .filter(|d| d.disposition == Disposition::Expired)
            .count();
        assert!(expired > 0, "no expiries under an immediate deadline");
        for (i, d) in plan.decisions.iter().enumerate() {
            assert_eq!(d.request.seq, i as u64, "an arrival lost its decision");
        }
    }

    #[test]
    fn small_plane_runs_end_to_end() {
        let mut cfg = ServeConfig::mix("steady", 9).unwrap();
        cfg.ticks = 6;
        let out = ServePlane::new(cfg, 2).unwrap().run().unwrap();
        assert_eq!(out.sessions.len() as u64, out.report.completed);
        assert!(out.report.completed > 0);
        for s in &out.sessions {
            assert!(!s.jsonl.is_empty(), "session {} captured no bytes", s.seq);
        }
    }
}
