//! Deterministic admission + dispatch scheduling (DESIGN.md §13).
//!
//! The whole serving schedule — which requests are admitted, rejected,
//! expired, and when each admitted session starts and finishes — is
//! computed here in *virtual ticks*, before any session executes. A
//! session's virtual service time is a pure function of its request
//! (steps × `service_ticks_per_step`), so the plan is a pure function
//! of the [`ServeConfig`]: byte-identical for any physical worker
//! count, which is the plane's determinism contract.
//!
//! Dispatch order inside the plan is a strict hierarchy:
//!
//! 1. **priority class** — lower value runs first, strictly;
//! 2. **weighted fair share** across tenants inside the class — stride
//!    scheduling (lowest pass wins, ties to the lowest tenant index);
//! 3. **EDF** within the winning tenant — earliest start deadline,
//!    `None` last;
//! 4. **arrival sequence** — the seeded stable tie-break.
//!
//! A queued request whose start deadline passes is *expired*: removed
//! from the queue and counted, never silently dropped.

use super::ServeConfig;
use crate::error::{AdmissionReject, PallasError};
use crate::exec::derive_seed;
use crate::workload::arrival::tenant_seed;

/// Stride-scheduling pass increment for weight 1; a tenant with weight
/// `w` advances by `STRIDE_SCALE / w` per dispatch, so dispatch counts
/// converge to the weight ratio.
pub(crate) const STRIDE_SCALE: u64 = 1 << 20;

/// One session request flowing through the plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Global arrival sequence number — the stable tie-break and the
    /// per-session output key (`session-<seq>.jsonl`).
    pub seq: u64,
    /// Index into [`ServeConfig::tenants`].
    pub tenant: usize,
    pub arrival_tick: u64,
    /// Latest tick at which the session may *start*; `None` never
    /// expires.
    pub deadline_tick: Option<u64>,
    /// Strict class (from the tenant spec); lower runs first.
    pub priority: u8,
    /// Virtual ticks the session occupies a slot for.
    pub service_ticks: u64,
    /// MARL steps the session simulates.
    pub steps: usize,
    /// Engine seed for the session — what a standalone
    /// [`crate::experiment::Experiment`] run must use to reproduce its
    /// bytes.
    pub seed: u64,
}

/// Final fate of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// Turned away at intake: the bounded queue was full.
    RejectedQueueFull,
    /// Turned away at intake: the tenant's outstanding-session quota
    /// was reached.
    RejectedQuota,
    /// Admitted but its start deadline passed while queued.
    Expired,
    /// Dispatched into a slot at `start_tick`, releasing it at
    /// `finish_tick`.
    Completed { start_tick: u64, finish_tick: u64 },
}

/// A request plus its fate — the unit the load report aggregates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    pub request: Request,
    pub disposition: Disposition,
}

/// The complete deterministic plan for one serve run.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Every request that ever arrived, in arrival (`seq`) order.
    pub decisions: Vec<Decision>,
    /// Tick at which the last admitted session released its slot.
    pub makespan_ticks: u64,
    /// Intake-depth gauges, sampled once per tick after dispatch.
    pub queue_depth_max: usize,
    pub queue_depth_sum: u64,
    pub ticks_observed: u64,
}

impl Schedule {
    /// Mean intake depth over the run.
    pub fn queue_depth_mean(&self) -> f64 {
        if self.ticks_observed == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.ticks_observed as f64
        }
    }
}

/// Bounded intake queue with typed admission control.
///
/// [`Intake::offer`] is the admission decision: per-tenant quota first
/// (a hog must not consume shared queue space it could never use),
/// then global capacity — both rejections are typed
/// [`PallasError::Admission`] values, handed back with the request so
/// the caller can record its disposition.
pub struct Intake {
    cap: usize,
    queued: Vec<Request>,
}

impl Intake {
    pub fn new(cap: usize) -> Intake {
        Intake {
            cap: cap.max(1),
            queued: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.queued.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queued.is_empty()
    }

    /// Admit or reject `req`. `outstanding` is the tenant's queued +
    /// running session count; `quota` its cap. On `Err` the request
    /// rides back with the typed rejection.
    pub fn offer(
        &mut self,
        req: Request,
        tenant_name: &str,
        outstanding: usize,
        quota: usize,
    ) -> Result<(), (Request, PallasError)> {
        if outstanding >= quota {
            let e = PallasError::Admission {
                tenant: tenant_name.to_string(),
                request: req.seq,
                reject: AdmissionReject::QuotaExceeded,
                limit: quota,
            };
            return Err((req, e));
        }
        if self.queued.len() >= self.cap {
            let e = PallasError::Admission {
                tenant: tenant_name.to_string(),
                request: req.seq,
                reject: AdmissionReject::QueueFull,
                limit: self.cap,
            };
            return Err((req, e));
        }
        self.queued.push(req);
        Ok(())
    }

    /// Remove and return every queued request whose start deadline has
    /// passed (`deadline_tick < now`) — the caller counts them as
    /// expired.
    pub fn drain_expired(&mut self, now: u64) -> Vec<Request> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queued.len() {
            if matches!(self.queued[i].deadline_tick, Some(d) if d < now) {
                out.push(self.queued.remove(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Pick the next request to dispatch under the priority →
    /// fair-share → EDF → seq hierarchy, advancing the winner tenant's
    /// stride pass. `None` when the queue is empty.
    pub fn take_next(&mut self, pass: &mut [u64], strides: &[u64]) -> Option<Request> {
        let top = self.queued.iter().map(|r| r.priority).min()?;
        // Fair share inside the class: the queued tenant with the
        // lowest (pass, index).
        let mut tenant: Option<usize> = None;
        for r in &self.queued {
            if r.priority != top {
                continue;
            }
            match tenant {
                None => tenant = Some(r.tenant),
                Some(best) if (pass[r.tenant], r.tenant) < (pass[best], best) => {
                    tenant = Some(r.tenant)
                }
                Some(_) => {}
            }
        }
        let tenant = tenant?;
        // EDF within the tenant: earliest start deadline, ties to the
        // lowest arrival sequence.
        let mut best: Option<usize> = None;
        for (i, r) in self.queued.iter().enumerate() {
            if r.tenant != tenant || r.priority != top {
                continue;
            }
            let key = (r.deadline_tick.unwrap_or(u64::MAX), r.seq);
            match best {
                None => best = Some(i),
                Some(b) => {
                    let bk = (
                        self.queued[b].deadline_tick.unwrap_or(u64::MAX),
                        self.queued[b].seq,
                    );
                    if key < bk {
                        best = Some(i);
                    }
                }
            }
        }
        let i = best?;
        pass[tenant] = pass[tenant].wrapping_add(strides[tenant]);
        Some(self.queued.remove(i))
    }
}

/// Compute the complete serving schedule for `cfg` — see the module
/// docs for the per-tick phase order (completions → expiry → arrivals
/// → dispatch → gauges).
pub fn plan(cfg: &ServeConfig) -> Schedule {
    let n_tenants = cfg.tenants.len();
    let strides: Vec<u64> = cfg
        .tenants
        .iter()
        .map(|t| STRIDE_SCALE / u64::from(t.weight.max(1)))
        .collect();
    // Standard stride scheduling: a tenant's first dispatch costs one
    // full stride, so lighter weights start further back.
    let mut pass: Vec<u64> = strides.clone();
    let mut outstanding = vec![0usize; n_tenants];
    let seeds: Vec<u64> = (0..n_tenants)
        .map(|i| tenant_seed(cfg.seed, i as u64))
        .collect();

    let mut intake = Intake::new(cfg.queue_cap);
    // (finish_tick, tenant) per in-service session.
    let mut running: Vec<(u64, usize)> = Vec::new();
    let mut decisions: Vec<Decision> = Vec::new();
    let mut free = cfg.slots.max(1);
    let mut seq = 0u64;
    let (mut depth_max, mut depth_sum, mut ticks_observed) = (0usize, 0u64, 0u64);
    // Liveness bound for the drain loop: with ≥1 slot, everything
    // admitted finishes within its summed service time past the
    // arrival window.
    let mut admitted_service = 0u64;

    let mut t = 0u64;
    let makespan_ticks = loop {
        // 1. Completions release their slots (and quota headroom).
        running.retain(|&(finish, tenant)| {
            if finish <= t {
                outstanding[tenant] -= 1;
                free += 1;
                false
            } else {
                true
            }
        });

        // 2. Expiry sweep: queued requests that can no longer start by
        // their deadline are counted, not silently dropped.
        for req in intake.drain_expired(t) {
            outstanding[req.tenant] -= 1;
            decisions.push(Decision {
                request: req,
                disposition: Disposition::Expired,
            });
        }

        // 3. Open-loop arrivals, while inside the arrival window.
        if t < cfg.ticks {
            for (ti, spec) in cfg.tenants.iter().enumerate() {
                let n = spec.arrivals.arrivals(seeds[ti], t as usize).total;
                for _ in 0..n {
                    let req = Request {
                        seq,
                        tenant: ti,
                        arrival_tick: t,
                        deadline_tick: spec.deadline_ticks.map(|d| t + d),
                        priority: spec.priority,
                        service_ticks: (spec.steps as u64 * cfg.service_ticks_per_step).max(1),
                        steps: spec.steps,
                        // seq + 1: replicate 0 is the identity in
                        // derive_seed, and the plane seed itself should
                        // not double as a session seed.
                        seed: derive_seed(cfg.seed, seq + 1),
                    };
                    seq += 1;
                    match intake.offer(req, &spec.name, outstanding[ti], spec.quota) {
                        Ok(()) => outstanding[ti] += 1,
                        Err((req, e)) => {
                            let disposition = match e {
                                PallasError::Admission {
                                    reject: AdmissionReject::QueueFull,
                                    ..
                                } => Disposition::RejectedQueueFull,
                                _ => Disposition::RejectedQuota,
                            };
                            decisions.push(Decision {
                                request: req,
                                disposition,
                            });
                        }
                    }
                }
            }
        }

        // 4. Dispatch into free virtual slots.
        while free > 0 {
            let Some(req) = intake.take_next(&mut pass, &strides) else {
                break;
            };
            free -= 1;
            let finish_tick = t + req.service_ticks;
            admitted_service += req.service_ticks;
            running.push((finish_tick, req.tenant));
            decisions.push(Decision {
                request: req,
                disposition: Disposition::Completed {
                    start_tick: t,
                    finish_tick,
                },
            });
        }

        // 5. Gauges.
        depth_max = depth_max.max(intake.len());
        depth_sum += intake.len() as u64;
        ticks_observed += 1;

        if t >= cfg.ticks && intake.is_empty() && running.is_empty() {
            break t;
        }
        t += 1;
        assert!(
            t <= cfg.ticks + admitted_service + 2,
            "serve scheduler failed to drain by tick {t}"
        );
    };

    decisions.sort_by_key(|d| d.request.seq);
    Schedule {
        decisions,
        makespan_ticks,
        queue_depth_max: depth_max,
        queue_depth_sum: depth_sum,
        ticks_observed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(seq: u64, tenant: usize, priority: u8, deadline: Option<u64>) -> Request {
        Request {
            seq,
            tenant,
            arrival_tick: 0,
            deadline_tick: deadline,
            priority,
            service_ticks: 1,
            steps: 1,
            seed: seq,
        }
    }

    #[test]
    fn offer_rejects_are_typed() {
        let mut q = Intake::new(1);
        // Quota is checked first.
        let (_, e) = q.offer(req(0, 0, 0, None), "acme", 3, 3).unwrap_err();
        assert!(matches!(
            e,
            PallasError::Admission {
                reject: AdmissionReject::QuotaExceeded,
                limit: 3,
                ..
            }
        ));
        q.offer(req(1, 0, 0, None), "acme", 0, 8).unwrap();
        let (back, e) = q.offer(req(2, 0, 0, None), "acme", 1, 8).unwrap_err();
        assert_eq!(back.seq, 2);
        assert!(matches!(
            e,
            PallasError::Admission {
                reject: AdmissionReject::QueueFull,
                limit: 1,
                ..
            }
        ));
    }

    #[test]
    fn priority_classes_are_strict() {
        let mut q = Intake::new(16);
        q.offer(req(0, 0, 1, None), "low", 0, 99).unwrap();
        q.offer(req(1, 1, 0, None), "high", 0, 99).unwrap();
        q.offer(req(2, 1, 0, None), "high", 1, 99).unwrap();
        let mut pass = vec![1, 1];
        let strides = vec![1, 1];
        let order: Vec<u64> = std::iter::from_fn(|| q.take_next(&mut pass, &strides))
            .map(|r| r.seq)
            .collect();
        // Both class-0 requests drain before the class-1 one.
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn fair_share_follows_weights() {
        // Tenant 0 weight 3, tenant 1 weight 1, both priority 0 with a
        // deep backlog: dispatches converge to 3:1.
        let strides = vec![STRIDE_SCALE / 3, STRIDE_SCALE];
        let mut pass = strides.clone();
        let mut q = Intake::new(64);
        for s in 0..24u64 {
            q.offer(req(s, (s % 2) as usize, 0, None), "t", 0, 99).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..16 {
            let r = q.take_next(&mut pass, &strides).unwrap();
            counts[r.tenant] += 1;
        }
        assert_eq!(counts, [12, 4], "weight-3 tenant should get 3/4 of slots");
    }

    #[test]
    fn edf_breaks_ties_inside_a_tenant() {
        let mut q = Intake::new(16);
        q.offer(req(0, 0, 0, Some(50)), "t", 0, 99).unwrap();
        q.offer(req(1, 0, 0, Some(10)), "t", 1, 99).unwrap();
        q.offer(req(2, 0, 0, None), "t", 2, 99).unwrap();
        q.offer(req(3, 0, 0, Some(10)), "t", 3, 99).unwrap();
        let mut pass = vec![1];
        let strides = vec![1];
        let order: Vec<u64> = std::iter::from_fn(|| q.take_next(&mut pass, &strides))
            .map(|r| r.seq)
            .collect();
        // Earliest deadline first; equal deadlines by seq; None last.
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn drain_expired_is_exact() {
        let mut q = Intake::new(16);
        q.offer(req(0, 0, 0, Some(4)), "t", 0, 99).unwrap();
        q.offer(req(1, 0, 0, Some(5)), "t", 1, 99).unwrap();
        q.offer(req(2, 0, 0, None), "t", 2, 99).unwrap();
        let gone = q.drain_expired(5);
        assert_eq!(gone.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![0]);
        assert_eq!(q.len(), 2);
    }
}
