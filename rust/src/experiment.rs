//! The typed entry point to the engine: an [`Experiment`] builder.
//!
//! Replaces the loose `(cfg, opts)` call surface — every driver
//! (baselines, the sweep executor, the CLI, examples, benches) builds
//! one of these:
//!
//! ```no_run
//! use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
//! use flexmarl::experiment::Experiment;
//!
//! let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
//! let report = Experiment::new(cfg)
//!     .scenario("core_skew")
//!     .steps(2)
//!     .build()?
//!     .evaluate();
//! println!("e2e {:.1}s  {:.0} tok/s", report.e2e_s, report.throughput_tps());
//! # Ok::<(), flexmarl::error::PallasError>(())
//! ```
//!
//! `build()` resolves the workload exactly once (scenario shaping, or
//! trace replay with the authoritative header — the same
//! [`crate::orchestrator::resolve_workload`] contract as always) and
//! derives the framework's [`PolicyBundle`]; failures surface as
//! [`PallasError`], never a panic. `cfg.workload_mode` picks the
//! resolution shape: eager materialization, or the lazy streaming
//! plane (DESIGN.md §11) whose runs are byte-identical to eager ones.
//! A custom bundle passed via
//! [`ExperimentBuilder::policies`] registers a framework the capability
//! flags cannot express — without touching the engine (DESIGN.md §8).
//!
//! Execution is streaming-first (DESIGN.md §9): [`Experiment::session`]
//! opens the engine at the step boundary —
//! [`Session::step`] yields each step's report as it
//! completes, typed [`crate::orchestrator::EngineEvent`]s flow to any
//! attached [`EventSink`]s, and a sink can stop the run early.
//! [`Experiment::run`] and [`Experiment::evaluate`] are thin drains
//! over a session, bit-identical to stepping it by hand.

use crate::config::{ExperimentConfig, Framework, WorkloadMode};
use crate::dist::{DistPlan, DistSource};
use crate::error::PallasError;
use crate::metrics::StepReport;
use crate::orchestrator::{
    resolve_workload, resolve_workload_source, EventSink, Session, SimOptions, SimOutcome,
};
use crate::policy::PolicyBundle;
use crate::workload::{scenario, LenHint, StepWorkload, VecSource, WorkloadSource};

/// The resolved workload, in whichever shape `cfg.workload_mode`
/// selected: a materialized vector (eager — the golden reference) or a
/// streaming [`WorkloadSource`] (lazy, DESIGN.md §11). Both feed the
/// engine through the same source interface and produce byte-identical
/// runs.
enum WorkloadPlan {
    Eager(Vec<StepWorkload>),
    Lazy(Box<dyn WorkloadSource>),
    /// Distributed generation (DESIGN.md §14): the coordinator is the
    /// source; shard workers generate behind it, byte-identically to
    /// the single-process paths.
    Dist(Box<DistSource>),
}

impl WorkloadPlan {
    fn len_hint(&self) -> LenHint {
        match self {
            WorkloadPlan::Eager(v) => LenHint::Exact(v.len()),
            WorkloadPlan::Lazy(src) => src.len_hint(),
            WorkloadPlan::Dist(src) => src.len_hint(),
        }
    }
}

/// A fully-resolved experiment, ready to run: shaped config, workload
/// plan (eager vector or lazy source), engine options, attached event
/// sinks, and the policy bundle the engine will consult. Construct via
/// [`Experiment::new`].
pub struct Experiment {
    cfg: ExperimentConfig,
    opts: SimOptions,
    policies: PolicyBundle,
    plan: WorkloadPlan,
    sinks: Vec<Box<dyn EventSink>>,
}

/// Builder for [`Experiment`] — see the module docs for the flow.
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
    opts: SimOptions,
    policies: Option<PolicyBundle>,
    sinks: Vec<Box<dyn EventSink>>,
    dist: Option<DistPlan>,
}

impl Experiment {
    /// Start building from a base config. The builder's setters refine
    /// it; [`ExperimentBuilder::build`] resolves it.
    // `new` is the documented public spelling of the builder entry
    // (`Experiment::new(cfg).framework(..).build()?`), deliberately not
    // returning Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder {
            cfg,
            opts: SimOptions::default(),
            policies: None,
            sinks: Vec::new(),
            dist: None,
        }
    }

    /// The resolved config: scenario shaped, trace header applied
    /// (steps/scenario may differ from what was passed in).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Engine options this experiment will run with.
    pub fn options(&self) -> &SimOptions {
        &self.opts
    }

    /// The policy bundle the engine will consult.
    pub fn policies(&self) -> &PolicyBundle {
        &self.policies
    }

    /// The concrete per-step workloads (generated or replayed); one
    /// entry per resolved step. Under [`WorkloadMode::Lazy`] nothing is
    /// materialized and this returns the empty slice — use
    /// [`Experiment::into_workloads`] to drain a lazy plan into a
    /// vector.
    pub fn step_workloads(&self) -> &[StepWorkload] {
        match &self.plan {
            WorkloadPlan::Eager(v) => v,
            WorkloadPlan::Lazy(_) | WorkloadPlan::Dist(_) => &[],
        }
    }

    /// Consume the experiment into its resolved config and per-step
    /// workloads — the shape [`resolve_workload`] returns — for callers
    /// that drive the workloads themselves (e.g. the wall-clock serving
    /// example) and want ownership without cloning every trajectory.
    /// A lazy plan is drained to a vector here (sources are
    /// deterministic, so the result is identical to eager resolution).
    /// Attached sinks are dropped: there is no engine for them to
    /// observe.
    pub fn into_workloads(self) -> (ExperimentConfig, Vec<StepWorkload>) {
        fn drain(mut src: Box<dyn WorkloadSource>) -> Vec<StepWorkload> {
            let mut v = Vec::new();
            while let Some(w) = src.next_step() {
                v.push(w);
            }
            v
        }
        let wls = match self.plan {
            WorkloadPlan::Eager(v) => v,
            WorkloadPlan::Lazy(src) => drain(src),
            WorkloadPlan::Dist(src) => drain(src),
        };
        (self.cfg, wls)
    }

    /// Attach an observer ([`crate::orchestrator::EventSink`]) to the
    /// built experiment; it flows into the session/run. Sinks observe
    /// and may stop the run early — they cannot perturb it (DESIGN.md
    /// §9).
    pub fn with_sink(mut self, sink: Box<dyn EventSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Open the experiment as a resumable [`Session`]: incremental
    /// stepping ([`Session::step`] yields one finalized
    /// [`StepReport`] per MARL step), typed event observation, and
    /// early stop. [`Experiment::run`]/[`Experiment::evaluate`] are
    /// thin drains over this.
    pub fn session(self) -> Result<Session, PallasError> {
        // The builder guarantees this invariant (both resolvers produce
        // exactly one workload per resolved step); the typed check
        // replaces a construction assert for callers that assemble an
        // Experiment through future non-builder paths. Only an exact
        // hint is checkable up front — a lazy `AtLeast` feed that runs
        // dry instead fails at the engine's pull site.
        if let Some(n) = self.plan.len_hint().exact() {
            if n != self.cfg.steps {
                return Err(PallasError::InvalidConfig(format!(
                    "experiment has {n} step workloads for {} steps",
                    self.cfg.steps
                )));
            }
        }
        let source: Box<dyn WorkloadSource> = match self.plan {
            WorkloadPlan::Eager(v) => Box::new(VecSource::new(v)),
            WorkloadPlan::Lazy(src) => src,
            WorkloadPlan::Dist(src) => src,
        };
        let engine = crate::orchestrator::simloop::Engine::new(
            self.cfg,
            self.opts,
            source,
            self.policies,
            crate::orchestrator::events::SinkSet::from_sinks(self.sinks),
        );
        Ok(Session::from_engine(engine))
    }

    /// Resume a [`Session`] from a checkpoint payload
    /// ([`crate::orchestrator::Session::snapshot`], typically read back
    /// via [`crate::ckpt::read_file`]). The experiment must be built
    /// from the *same* config, seed, and options as the run that wrote
    /// the checkpoint — the payload's config fingerprint enforces this
    /// with a typed [`PallasError::Checkpoint`] on mismatch. `path`
    /// names the source file in errors (pass `""` for in-memory
    /// payloads).
    pub fn resume(self, payload: &crate::util::json::Json, path: &str) -> Result<Session, PallasError> {
        self.session()?.restore(payload, path)
    }

    /// [`Experiment::resume`] straight from a checkpoint file: read,
    /// validate (magic / format version / checksum — [`crate::ckpt`]),
    /// and restore.
    pub fn resume_file(self, path: &str) -> Result<Session, PallasError> {
        let payload = crate::ckpt::read_file(path)?;
        self.resume(&payload, path)
    }

    /// Run the discrete-event simulation to completion, consuming the
    /// experiment — a drain over [`Experiment::session`]. The one
    /// runtime failure the engine models — the run loop's livelock
    /// guard — surfaces as [`PallasError::EventBudget`].
    pub fn try_run(self) -> Result<SimOutcome, PallasError> {
        self.session().and_then(Session::run_to_end)
    }

    /// [`Experiment::try_run`] for callers that accept the panicking
    /// convenience contract.
    ///
    /// # Panics
    ///
    /// On a tripped run-loop event budget (livelock guard), with the
    /// budget error's `Display` text — it keeps the prefix the run
    /// loop always panicked with.
    pub fn run(self) -> SimOutcome {
        self.try_run().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Run and aggregate per-step reports into the per-sample averages
    /// the paper tables quote. For step-overlapping pipelines (MARTI's
    /// one-step-async) the E2E figure is amortized over the whole run,
    /// exactly as [`crate::baselines::try_evaluate`] reports it.
    ///
    /// Errors on a tripped event budget
    /// ([`PallasError::EventBudget`]), and with
    /// [`PallasError::EmptyRun`] on a run that produced no step
    /// reports — a zero-step experiment, or an attached stop sink
    /// cutting the run before the first step boundary (drive a session
    /// and use [`SimOutcome::evaluate`] to handle partial outcomes).
    pub fn try_evaluate(self) -> Result<StepReport, PallasError> {
        let overlaps = self.policies.pipeline.overlaps_steps();
        let out = self.try_run()?;
        out.evaluate(overlaps).ok_or(PallasError::EmptyRun)
    }

    /// [`Experiment::try_evaluate`] for callers that accept the
    /// panicking convenience contract.
    ///
    /// # Panics
    ///
    /// Where [`Experiment::try_evaluate`] errors.
    pub fn evaluate(self) -> StepReport {
        self.try_evaluate().unwrap_or_else(|e| panic!("{e}"))
    }
}

impl ExperimentBuilder {
    /// Select a named framework: sets the config's framework and (at
    /// build time) derives its canonical policy bundle. Clears any
    /// custom bundle set earlier — last selection wins.
    pub fn framework(mut self, fw: Framework) -> Self {
        self.cfg.framework = fw;
        self.policies = None;
        self
    }

    /// Run the engine under a custom policy bundle instead of the
    /// config framework's derived one — this is how a framework that
    /// does not decompose into [`Framework`]'s capability flags is
    /// registered. The bundle's name labels the reports.
    pub fn policies(mut self, bundle: PolicyBundle) -> Self {
        self.policies = Some(bundle);
        self
    }

    /// Select a workload scenario preset ([`crate::workload::scenario`]).
    pub fn scenario(mut self, name: impl Into<String>) -> Self {
        self.cfg.workload.scenario = name.into();
        self
    }

    /// Replay a recorded JSONL trace instead of generating (the trace
    /// header is authoritative for scenario and step count).
    pub fn trace(mut self, path: impl Into<String>) -> Self {
        self.cfg.workload.trace = Some(path.into());
        self
    }

    /// MARL steps to simulate (ignored under a trace, whose header
    /// wins).
    pub fn steps(mut self, steps: usize) -> Self {
        self.cfg.steps = steps;
        self
    }

    /// Generator seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Workload resolution mode: eager materialization (default) or
    /// the lazy streaming plane (`--workload-mode lazy`, DESIGN.md
    /// §11). Outcomes are byte-identical either way.
    pub fn workload_mode(mut self, mode: WorkloadMode) -> Self {
        self.cfg.workload_mode = mode;
        self
    }

    /// Distribute per-step workload generation over claim-based shard
    /// workers (DESIGN.md §14): a coordinator owns the canonical
    /// experience-store index and shard assignment; `plan.workers`
    /// workers generate query shards over `plan.transport`. Run output
    /// is byte-identical to the single-process paths for any worker
    /// count and either transport. Incompatible with trace replay
    /// (workers *generate*; a trace is already generated) and
    /// overrides `workload_mode`.
    pub fn dist(mut self, plan: DistPlan) -> Self {
        self.dist = Some(plan);
        self
    }

    /// Engine knobs (instance counts, poll period, queue backend, …).
    pub fn options(mut self, opts: SimOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Write a crash-consistent checkpoint after every `n` completed
    /// MARL steps (DESIGN.md §12). The file is
    /// `<checkpoint_dir>/ckpt.json`, atomically replaced each time; a
    /// run killed at any instant resumes from its last checkpoint via
    /// [`Experiment::resume_file`] with byte-identical remaining
    /// output.
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.cfg.checkpoint.every = Some(n);
        self
    }

    /// Directory the periodic checkpoint file is written into
    /// (defaults to the current directory).
    pub fn checkpoint_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.checkpoint.dir = Some(dir.into());
        self
    }

    /// Attach an observer ([`crate::orchestrator::EventSink`]) — e.g.
    /// a progress printer, a JSONL streamer, a trace recorder, or an
    /// early-stop budget. Sinks accumulate; they observe the run in
    /// attachment order.
    pub fn sink(mut self, sink: Box<dyn EventSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Resolve the workload (scenario shaping or trace replay, exactly
    /// once) and fix the policy bundle. All resolution failures —
    /// unknown scenario, unreadable/corrupt/mismatched trace — surface
    /// here as [`PallasError`]. Under [`WorkloadMode::Lazy`] nothing is
    /// materialized: the plan holds a streaming source and corrupt
    /// trace *steps* (the header is still validated here) surface
    /// mid-run instead.
    pub fn build(self) -> Result<Experiment, PallasError> {
        let (cfg, plan) = if let Some(dplan) = self.dist {
            dplan.validate()?;
            if self.cfg.workload.trace.is_some() {
                return Err(PallasError::InvalidConfig(
                    "dist generates workloads on workers; it cannot replay a trace \
                     (drop the trace or run single-process simulate)"
                        .to_string(),
                ));
            }
            // Same shaping as the single-process paths — the shaped
            // config is what byte-identity is defined against.
            let (shaped, scen) = scenario::resolve(&self.cfg.workload)?;
            let mut resolved = self.cfg.clone();
            resolved.workload = shaped;
            let src = DistSource::new(
                resolved.workload.clone(),
                scen,
                resolved.seed,
                resolved.steps,
                dplan,
            );
            (resolved, WorkloadPlan::Dist(Box::new(src)))
        } else {
            match self.cfg.workload_mode {
                WorkloadMode::Eager => {
                    let (cfg, wls) = resolve_workload(&self.cfg)?;
                    (cfg, WorkloadPlan::Eager(wls))
                }
                WorkloadMode::Lazy => {
                    let (cfg, src) = resolve_workload_source(&self.cfg)?;
                    (cfg, WorkloadPlan::Lazy(src))
                }
            }
        };
        let policies = self
            .policies
            .unwrap_or_else(|| cfg.framework.policies());
        Ok(Experiment {
            cfg,
            opts: self.opts,
            policies,
            plan,
            sinks: self.sinks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::policy::{
        AgentCentricAlloc, HierarchicalBalance, MicroBatchAsync, ParallelSampling, PolicyBundle,
    };

    fn small_cfg(fw: Framework) -> ExperimentConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 4;
        let mut cfg = ExperimentConfig::new(wl, fw);
        cfg.steps = 2;
        cfg
    }

    #[test]
    fn builder_matches_direct_try_simulate() {
        let cfg = small_cfg(Framework::flexmarl());
        let direct = crate::orchestrator::try_simulate(&cfg, &SimOptions::default()).unwrap();
        let built = Experiment::new(cfg).build().unwrap().run();
        assert_eq!(direct.total_s, built.total_s);
        assert_eq!(direct.reports.len(), built.reports.len());
        for (a, b) in direct.reports.iter().zip(&built.reports) {
            assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
        }
    }

    #[test]
    fn builder_setters_shape_the_resolved_config() {
        let exp = Experiment::new(small_cfg(Framework::mas_rl()))
            .framework(Framework::dist_rl())
            .scenario("Core-Skew") // alias spelling canonicalizes
            .steps(1)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(exp.config().framework.name, "DistRL");
        assert_eq!(exp.config().workload.scenario, "core_skew");
        assert_eq!(exp.config().steps, 1);
        assert_eq!(exp.config().seed, 7);
        assert_eq!(exp.step_workloads().len(), 1);
        assert_eq!(exp.policies().name, "DistRL");
        // Ownership hand-off mirrors resolve_workload's return shape.
        let (resolved, wls) = exp.into_workloads();
        assert_eq!(resolved.workload.scenario, "core_skew");
        assert_eq!(wls.len(), 1);
    }

    #[test]
    fn lazy_mode_runs_byte_identical_to_eager() {
        for fw in [Framework::mas_rl(), Framework::marti(), Framework::flexmarl()] {
            let cfg = small_cfg(fw);
            let eager = Experiment::new(cfg.clone()).build().unwrap().run();
            let lazy = Experiment::new(cfg)
                .workload_mode(crate::config::WorkloadMode::Lazy)
                .build()
                .unwrap()
                .run();
            assert_eq!(eager.total_s, lazy.total_s);
            assert_eq!(eager.reports.len(), lazy.reports.len());
            for (a, b) in eager.reports.iter().zip(&lazy.reports) {
                assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
            }
        }
    }

    #[test]
    fn lazy_plan_materializes_nothing_until_drained() {
        let exp = Experiment::new(small_cfg(Framework::flexmarl()))
            .workload_mode(crate::config::WorkloadMode::Lazy)
            .build()
            .unwrap();
        assert!(exp.step_workloads().is_empty(), "lazy plan must stay unmaterialized");
        let (cfg, wls) = exp.into_workloads();
        assert_eq!(wls.len(), cfg.steps, "draining a lazy plan yields every step");
        let eager = Experiment::new(cfg)
            .workload_mode(crate::config::WorkloadMode::Eager)
            .build()
            .unwrap();
        assert_eq!(eager.step_workloads(), &wls[..], "drained lazy == eager materialization");
    }

    #[test]
    fn dist_runs_byte_identical_to_eager_for_any_worker_count() {
        // The tentpole contract at the engine level: full runs through
        // the distributed plane produce the same report bytes as eager
        // single-process resolution.
        let cfg = small_cfg(Framework::flexmarl());
        let eager = Experiment::new(cfg.clone()).build().unwrap().run();
        for workers in [1usize, 3] {
            let dist = Experiment::new(cfg.clone())
                .dist(DistPlan::channel(workers))
                .build()
                .unwrap()
                .run();
            assert_eq!(eager.total_s, dist.total_s, "{workers} workers");
            assert_eq!(eager.reports.len(), dist.reports.len());
            for (a, b) in eager.reports.iter().zip(&dist.reports) {
                assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
            }
        }
    }

    #[test]
    fn dist_refuses_traces_and_zero_workers() {
        let err = Experiment::new(small_cfg(Framework::flexmarl()))
            .trace("whatever.jsonl")
            .dist(DistPlan::channel(2))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("cannot replay a trace"), "{err}");
        let err = Experiment::new(small_cfg(Framework::flexmarl()))
            .dist(DistPlan::channel(0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("at least one worker"), "{err}");
    }

    #[test]
    fn build_surfaces_unknown_scenario_as_typed_error() {
        let err = Experiment::new(small_cfg(Framework::flexmarl()))
            .scenario("gibberish")
            .build()
            .unwrap_err();
        assert_eq!(err, PallasError::UnknownScenario("gibberish".into()));
        assert!(err.to_string().contains("gibberish"));
    }

    #[test]
    fn evaluate_matches_baselines_try_evaluate() {
        for fw in [Framework::flexmarl(), Framework::marti()] {
            let cfg = small_cfg(fw);
            let opts = SimOptions::default();
            let via_baselines = crate::baselines::try_evaluate(&cfg, &opts).unwrap();
            let via_builder = Experiment::new(cfg)
                .options(opts)
                .build()
                .unwrap()
                .evaluate();
            assert_eq!(via_baselines.e2e_s, via_builder.e2e_s, "{}", fw.name);
            assert_eq!(via_baselines.tokens, via_builder.tokens, "{}", fw.name);
            assert_eq!(
                via_baselines.to_json().to_pretty(),
                via_builder.to_json().to_pretty(),
                "{}",
                fw.name
            );
        }
    }

    #[test]
    fn custom_bundle_labels_reports_and_runs() {
        let bundle = PolicyBundle::new(
            "CustomRL",
            Box::new(MicroBatchAsync),
            Box::new(HierarchicalBalance),
            Box::new(AgentCentricAlloc),
            Box::new(ParallelSampling),
        );
        let out = Experiment::new(small_cfg(Framework::flexmarl()))
            .policies(bundle)
            .build()
            .unwrap()
            .run();
        assert_eq!(out.reports.len(), 2);
        assert!(out.total_s > 0.0);
        for r in &out.reports {
            assert_eq!(r.framework, "CustomRL");
        }
    }

    #[test]
    fn framework_setter_clears_a_custom_bundle() {
        let bundle = PolicyBundle::new(
            "CustomRL",
            Box::new(MicroBatchAsync),
            Box::new(HierarchicalBalance),
            Box::new(AgentCentricAlloc),
            Box::new(ParallelSampling),
        );
        let exp = Experiment::new(small_cfg(Framework::flexmarl()))
            .policies(bundle)
            .framework(Framework::mas_rl()) // last selection wins
            .build()
            .unwrap();
        assert_eq!(exp.policies().name, "MAS-RL");
    }

    #[test]
    fn trace_setter_replays_bit_identically() {
        let mut cfg = small_cfg(Framework::flexmarl());
        cfg.workload.scenario = "bursty".into();
        let generated = Experiment::new(cfg.clone()).build().unwrap().run();
        let tr = crate::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
        let path = std::env::temp_dir().join("flexmarl_experiment_trace.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        let mut replay_cfg = small_cfg(Framework::flexmarl());
        replay_cfg.workload.scenario = "baseline".into(); // header wins
        let exp = Experiment::new(replay_cfg).trace(&path).build().unwrap();
        assert_eq!(exp.config().workload.scenario, "bursty");
        let replayed = exp.run();
        let _ = std::fs::remove_file(&path);
        assert_eq!(generated.total_s, replayed.total_s);
    }
}
