//! FlexMARL leader CLI.
//!
//! Subcommands:
//!   simulate   — run one framework/workload on the cluster simulator
//!   table2     — overall performance sweep (Table 2 + Fig. 7 breakdown)
//!   table3     — ablation study (load balancing / async pipeline)
//!   table4     — heterogeneous scalability (5×32B, 3×32B+7×14B, 15×14B)
//!   fig1       — preliminary observations (latency CDF, queue series)
//!   fig8       — per-agent processed rollout load series (Figs. 8/9)
//!   fig10      — resource-utilization comparison
//!   fig11      — training-state swap overhead across model sizes
//!   sweep      — framework × scenario × seed grid on the deterministic
//!                parallel executor; one JSON report, byte-identical
//!                for any --jobs
//!   scenarios  — list the workload scenario presets (--run executes
//!                the scenario sweep through the executor)
//!   record     — capture a scenario's workload stream to a JSONL trace
//!   replay     — re-run a recorded trace (bit-identical workloads)
//!   serve      — multi-tenant Rollout-as-a-Service plane: admission
//!                control, priority/fair/EDF queueing, per-session
//!                JSONL streams; byte-identical for any --workers
//!                (DESIGN.md §13)
//!   dist       — distributed run: a coordinator assigns query shards
//!                to claim-based workers over an in-process channel or
//!                localhost sockets; stdout/--json/--emit jsonl are
//!                byte-identical to `simulate` for any --workers and
//!                either --transport (DESIGN.md §14)
//!   dist-worker — internal: shard worker spawned by `dist
//!                --transport socket`; connects back with --connect
//!   inspect    — summarize the AOT artifact manifest
//!   train      — real end-to-end MARL training via PJRT (see also
//!                rust/examples/marl_train.rs)
//!
//! Config overrides: --workload MA|CA --framework <name> --steps N
//! --seed N --micro-batch N --delta N --instances N --json <path>
//! --scenario <preset> --trace <path> --faults off|<preset>
//! --jobs N (or PALLAS_JOBS)
//!
//! Streaming (DESIGN.md §9): `simulate`/`sweep` accept `--progress`
//! (live progress on stderr; stdout and --json stay byte-identical)
//! and `--emit jsonl` (per-step / per-cell report lines streamed to
//! stdout); `simulate` additionally takes `--max-wall-s N` (stop the
//! run after N real seconds with a well-formed partial result) and
//! `--emit jsonl-batch` (the same lines from a monolithic run — the
//! CI reference the streamed variant is byte-diffed against).

use flexmarl::baselines::{sweep, Framework};
use flexmarl::config::{framework_by_name, ExperimentConfig, ModelScale, WorkloadConfig};
use flexmarl::experiment::Experiment;
use flexmarl::metrics::{render_table2, table_rows, StepReport};
use flexmarl::orchestrator::{JsonlSink, ProgressSink, SimOptions, WallClockSink};
use flexmarl::training::{swap_in_cost, swap_out_cost};
use flexmarl::util::cli::Args;
use flexmarl::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "simulate" => cmd_simulate(&args),
        "table2" => cmd_table2(&args),
        "table3" => cmd_table3(&args),
        "table4" => cmd_table4(&args),
        "fig1" => cmd_fig1(&args),
        "fig8" => cmd_fig8(&args),
        "fig10" => cmd_fig10(&args),
        "fig11" => cmd_fig11(&args),
        "sweep" => cmd_sweep(&args),
        "scenarios" => cmd_scenarios(&args),
        "record" => cmd_record(&args),
        "replay" => cmd_replay(&args),
        "serve" => cmd_serve(&args),
        "dist" => cmd_dist(&args),
        "dist-worker" => cmd_dist_worker(&args),
        "inspect" => cmd_inspect(&args),
        "train" => cmd_train(&args),
        _ => {
            eprintln!("{}", HELP);
            if cmd != "help" {
                std::process::exit(2);
            }
        }
    }
}

const HELP: &str = "flexmarl — rollout-training co-design for LLM-based MARL
usage: flexmarl <simulate|table2|table3|table4|fig1|fig8|fig10|fig11|sweep|scenarios|record|replay|serve|dist|inspect|train> [options]
options: --workload MA|CA  --framework <name>  --steps N  --seed N
         --micro-batch N  --delta N  --instances N  --json <path>  --quiet
         --scenario <preset>  (see `flexmarl scenarios`)
         --trace <path>       (replay a recorded JSONL trace)
         --faults off|<preset> (fault-injection plan; `flexmarl simulate
                               --faults chaos`; see DESIGN.md §10)
         --workload-mode eager|lazy (lazy streams steps on demand —
                               byte-identical output; DESIGN.md §11)
         --progress           (live progress on stderr; stdout unchanged)
simulate: --emit jsonl        (stream one StepReport JSON line per step)
         --emit jsonl-batch   (same lines from a monolithic run)
         --max-wall-s N       (stop after N real seconds, partial result)
         --checkpoint-every N (atomic snapshot every N steps; DESIGN.md §12)
         --checkpoint-dir D   (where ckpt.json lands; default cwd)
         --resume <path>      (resume from a checkpoint — metrics and
                               --emit jsonl output stay byte-identical
                               to the uninterrupted run)
sweep:   framework × scenario × seed grid on the parallel executor;
         --jobs N (default PALLAS_JOBS or all cores) --replicates N
         --framework/--scenario restrict an axis; --json is
         byte-identical for any --jobs; --emit jsonl streams one line
         per completed cell (completion order)
scenarios: list presets; --run executes the scenario sweep [--jobs N]
record:  --scenario <preset> --steps N --seed N --out <path>
replay:  --trace <path> [--framework <name>]  (`--trace -` reads the
         recorded stream from stdin via `simulate`)
serve:   multi-tenant serving plane (DESIGN.md §13):
         --mix steady|mixed|flash  --ticks N  --slots N  --queue-cap N
         --seed N  --workers N     (workers change wall time only)
         --out-dir D               (one session-<seq>.jsonl per session)
         --json <path>             (deterministic load report —
                                    byte-identical for any --workers)
dist:    distributed coordinator/worker run (DESIGN.md §14):
         --workers N               (shard workers; default 2)
         --transport channel|socket (in-process threads, or child
                                    processes over localhost TCP)
         accepts simulate's config flags plus --emit jsonl/--progress;
         stdout, --json and --emit jsonl are byte-identical to
         `simulate` for any --workers and either --transport
         (worker bookkeeping goes to stderr only)";

fn build_cfg(args: &Args) -> ExperimentConfig {
    let wl = match args.get_or("workload", "MA").to_ascii_uppercase().as_str() {
        "CA" => WorkloadConfig::ca(),
        _ => WorkloadConfig::ma(),
    };
    let fw = framework_by_name(&args.get_or("framework", "FlexMARL"))
        .unwrap_or_else(|| {
            eprintln!("unknown framework");
            std::process::exit(2)
        });
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = args.get_usize("steps", 3);
    cfg.seed = args.get_u64("seed", 2048);
    cfg.pipeline.micro_batch = args.get_usize("micro-batch", cfg.pipeline.micro_batch);
    cfg.pipeline.delta_threshold = args.get_usize("delta", cfg.pipeline.delta_threshold);
    if let Some(s) = args.get("scenario") {
        cfg.workload.scenario = s.to_string();
    }
    if let Some(t) = args.get("trace") {
        cfg.workload.trace = Some(t.to_string());
    }
    if let Some(m) = args.get("workload-mode") {
        cfg.workload_mode = flexmarl::config::WorkloadMode::from_name(m).unwrap_or_else(|| {
            eprintln!("unknown workload mode '{m}' (want eager or lazy)");
            std::process::exit(2)
        });
    }
    // `--faults off` is an explicit no-plan spelling: it must leave the
    // config bit-identical to never passing the flag (CI byte-diffs the
    // two), so it simply keeps the default empty FaultConfig.
    if let Some(f) = args.get("faults") {
        if f != "off" {
            cfg.faults = flexmarl::fault::preset(f).unwrap_or_else(|| {
                eprintln!(
                    "unknown fault preset '{f}' (valid: off, {})",
                    flexmarl::fault::preset_names().join(", ")
                );
                std::process::exit(2)
            });
        }
    }
    cfg.validate().unwrap_or_else(|e| {
        eprintln!("invalid config: {e}");
        std::process::exit(2)
    });
    cfg
}

/// Build the [`Experiment`] for a CLI config, exiting cleanly on
/// workload-resolution failure (bad `--trace`, unknown trace scenario)
/// instead of panicking, with no redundant pre-flight parse (`replay`
/// still reads the header separately to reconstruct the recording
/// config).
fn build_experiment(cfg: &ExperimentConfig, opts: &SimOptions) -> Experiment {
    Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .unwrap_or_else(|e| {
            eprintln!("invalid workload: {e}");
            std::process::exit(2)
        })
}

// Typed failure path: a fail-fast recovery abort (`--faults
// preemption_failfast`) or a tripped event budget exits 1 with the
// error's message, never a panic.
fn run_eval(cfg: &ExperimentConfig, opts: &SimOptions) -> StepReport {
    build_experiment(cfg, opts).try_evaluate().unwrap_or_else(|e| {
        eprintln!("simulation failed: {e}");
        std::process::exit(1)
    })
}

fn run_sim(cfg: &ExperimentConfig, opts: &SimOptions) -> flexmarl::orchestrator::SimOutcome {
    build_experiment(cfg, opts).try_run().unwrap_or_else(|e| {
        eprintln!("simulation failed: {e}");
        std::process::exit(1)
    })
}

fn build_opts(args: &Args) -> SimOptions {
    SimOptions {
        instances_per_agent: args.get_usize("instances", 2),
        track_agents: vec![0, 1, 2],
        ..SimOptions::default()
    }
}

fn emit_json(args: &Args, j: &Json) {
    if let Some(path) = args.get("json") {
        // Typed failure, not a panic: an unwritable --json path (missing
        // directory, permissions, full disk) exits 1 like every other
        // runtime I/O failure.
        if let Err(e) = std::fs::write(path, j.to_pretty()) {
            let err = flexmarl::error::PallasError::File {
                path: path.to_string(),
                error: e.to_string(),
            };
            eprintln!("failed to write --json: {err}");
            std::process::exit(1);
        }
        eprintln!("wrote {path}");
    }
}

fn cmd_simulate(args: &Args) {
    let mut cfg = build_cfg(args);
    if let Some(v) = args.get("checkpoint-every") {
        let n = v.parse::<usize>().ok().filter(|&n| n > 0).unwrap_or_else(|| {
            eprintln!("--checkpoint-every needs a positive step count (got '{v}')");
            std::process::exit(2)
        });
        cfg.checkpoint.every = Some(n);
    }
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint.dir = Some(d.to_string());
    }
    let resume = args.get("resume");
    let opts = build_opts(args);
    let emit = args.get("emit");
    let progress = args.has_flag("progress");
    let max_wall = args.get("max-wall-s").map(|v| {
        let s = v.parse::<f64>().ok().filter(|s| s.is_finite() && *s >= 0.0);
        s.unwrap_or_else(|| {
            eprintln!("--max-wall-s needs a non-negative number of seconds (got '{v}')");
            std::process::exit(2)
        })
    });
    if emit.is_none() && !progress && max_wall.is_none() && resume.is_none() {
        // Classic run-to-completion path — stdout stays byte-for-byte
        // what it always was (periodic checkpoints, if enabled, are
        // written inside the drain).
        let rep = run_eval(&cfg, &opts);
        print_report(&rep);
        emit_json(args, &rep.to_json());
        return;
    }
    match emit {
        None | Some("jsonl") | Some("jsonl-batch") => {}
        Some(other) => {
            eprintln!("unknown --emit mode '{other}' (jsonl | jsonl-batch)");
            std::process::exit(2);
        }
    }
    let exp = build_experiment(&cfg, &opts);
    let total_steps = exp.config().steps;
    let overlaps = exp.policies().pipeline.overlaps_steps();
    let mut session = match resume {
        // Resume from a checkpoint file (DESIGN.md §12): format
        // violations (corrupt/truncated/stale-version) and config
        // fingerprint mismatches are typed errors, exit 1.
        Some(path) => exp.resume_file(path).unwrap_or_else(|e| {
            eprintln!("resume failed: {e}");
            std::process::exit(1)
        }),
        None => exp.session().unwrap_or_else(|e| {
            eprintln!("invalid workload: {e}");
            std::process::exit(2)
        }),
    };
    if progress {
        session.add_sink(Box::new(ProgressSink::stderr(total_steps)));
    }
    if let Some(s) = max_wall {
        session.add_sink(Box::new(WallClockSink::after(Duration::from_secs_f64(s))));
    }
    if emit == Some("jsonl") {
        // Streamed: one line per step, written the moment it completes.
        // A resumed run first re-emits the restored steps' lines, so
        // its stdout is the full stream from step 0 — byte-identical
        // to the uninterrupted run's.
        for r in session.reports() {
            println!("{}", r.to_json().to_string());
        }
        session.add_sink(Box::new(JsonlSink::stdout()));
    }
    loop {
        match session.step() {
            Ok(Some(_)) => {}
            Ok(None) => break,
            Err(e) => {
                eprintln!("simulation failed: {e}");
                std::process::exit(1);
            }
        }
    }
    let out = session.finish();
    if emit == Some("jsonl-batch") {
        // Reference batch path: the identical lines, printed after the
        // run — CI byte-diffs this against the streamed variant.
        for r in &out.reports {
            println!("{}", r.to_json().to_string());
        }
    }
    if let Some(stop) = &out.stop {
        eprintln!(
            "stopped early at t={:.1}s after {}/{} steps",
            stop.t, stop.steps_completed, total_steps
        );
    }
    match out.evaluate(overlaps) {
        Some(rep) => {
            if emit.is_none() {
                // jsonl modes keep stdout pure report lines.
                print_report(&rep);
            }
            emit_json(args, &rep.to_json());
        }
        None => {
            // Nothing completed: exit non-zero so a consumer waiting
            // on stdout/--json (never written) can tell — a stale
            // r.json from a previous run must not read as success.
            eprintln!("no steps completed before the stop");
            std::process::exit(1);
        }
    }
}

fn print_report(r: &StepReport) {
    println!(
        "{:<24} {:>8} e2e {:>8.1}s  rollout {:>8.1}s  train {:>7.1}s  other {:>6.1}s  \
         {:>8.1} tps  util {:>5.1}%  scale_ops {}",
        r.framework,
        r.workload,
        r.e2e_s,
        r.rollout_s,
        r.train_s,
        r.other_s,
        r.throughput_tps(),
        r.utilization() * 100.0,
        r.scale_ops
    );
}

fn cmd_table2(args: &Args) {
    let mut all = Vec::new();
    for wl in ["MA", "CA"] {
        let mut a2 = Args::parse(std::iter::empty::<String>());
        a2.options = args.options.clone();
        a2.options.insert("workload".into(), wl.into());
        let cfg = build_cfg(&a2);
        let opts = build_opts(args);
        let reports = sweep(&cfg, &opts);
        println!("\n== {} dataset ==", wl);
        for r in &reports {
            print_report(r);
        }
        println!("\n{}", render_table2(wl, &table_rows(&reports)));
        all.extend(reports);
    }
    emit_json(args, &Json::arr(all.iter().map(|r| r.to_json())));
}

fn cmd_table3(args: &Args) {
    for wl in ["MA", "CA"] {
        println!("\n== Ablation on {} ==", wl);
        let mut a2 = Args::parse(std::iter::empty::<String>());
        a2.options = args.options.clone();
        a2.options.insert("workload".into(), wl.into());
        let base = build_cfg(&a2);
        let opts = build_opts(args);
        let mas = {
            let mut c = base.clone();
            c.framework = Framework::mas_rl();
            run_eval(&c, &opts)
        };
        for fw in [
            Framework::flexmarl_no_balancing(),
            Framework::flexmarl_no_async(),
            Framework::flexmarl(),
        ] {
            let mut c = base.clone();
            c.framework = fw;
            let r = run_eval(&c, &opts);
            println!(
                "{:<26} E2E {:>7.1}s  speedup {:>4.1}x  throughput {:>7.1}tps",
                fw.name,
                r.e2e_s,
                mas.e2e_s / r.e2e_s,
                r.throughput_tps()
            );
        }
    }
}

fn cmd_table4(args: &Args) {
    println!("== Large-scale heterogeneous deployments (Table 4) ==");
    for spec in [
        vec![(5usize, ModelScale::B32)],
        vec![(3, ModelScale::B32), (7, ModelScale::B14)],
        vec![(15, ModelScale::B14)],
    ] {
        let wl = WorkloadConfig::scale_config(&spec);
        let name = wl.name.clone();
        let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
        cfg.steps = args.get_usize("steps", 3);
        cfg.seed = args.get_u64("seed", 2048);
        let opts = build_opts(args);
        let r = run_eval(&cfg, &opts);
        println!(
            "{:<16} rollout {:>7.1}s  training {:>6.1}s  E2E {:>7.1}s  throughput {:>7.1}tps",
            name,
            r.rollout_s,
            r.train_s,
            r.e2e_s,
            r.throughput_tps()
        );
    }
}

fn cmd_fig1(args: &Args) {
    let mut cfg = build_cfg(args);
    cfg.framework = Framework::dist_rl(); // preliminary setup: no co-design
    cfg.steps = 1;
    let opts = build_opts(args);
    let out = run_sim(&cfg, &opts);
    let r = &out.reports[0];
    println!("== Fig 1(a): interaction latency distribution ==");
    let mut lats = r.trajectory_latencies.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.5, 0.9, 0.99, 1.0] {
        let idx = ((lats.len() - 1) as f64 * q) as usize;
        println!("  p{:<4} {:>8.1}s", (q * 100.0) as u32, lats[idx]);
    }
    println!("== Fig 1(b): queued requests over time (agents 0..3) ==");
    for (a, series) in &out.series.queued {
        let peak = series.iter().map(|&(_, q)| q).max().unwrap_or(0);
        println!("  agent {a}: peak queue {peak}, samples {}", series.len());
    }
    emit_json(args, &r.to_json());
}

fn cmd_fig8(args: &Args) {
    let cfg = build_cfg(args);
    let opts = build_opts(args);
    let out = run_sim(&cfg, &opts);
    let r = &out.reports[0];
    println!(
        "== Figs 8/9: processed rollout load over time ({}, {}) ==",
        cfg.framework.name, cfg.workload.name
    );
    for (a, series) in &out.series.processed {
        let total = series.last().map(|&(_, c)| c).unwrap_or(0);
        let t_done = series
            .iter()
            .find(|&&(_, c)| c == total && total > 0)
            .map(|&(t, _)| t)
            .unwrap_or(0.0);
        println!("  agent {a}: {total} requests, finished at {t_done:.0}s");
    }
    emit_json(args, &r.to_json());
}

fn cmd_fig10(args: &Args) {
    for wl in ["MA", "CA"] {
        println!("== Fig 10: utilization on {} ==", wl);
        let mut a2 = Args::parse(std::iter::empty::<String>());
        a2.options = args.options.clone();
        a2.options.insert("workload".into(), wl.into());
        let base = build_cfg(&a2);
        let opts = build_opts(args);
        for r in sweep(&base, &opts) {
            println!("  {:<12} {:>5.1}%", r.framework, r.utilization() * 100.0);
        }
    }
}

fn cmd_fig11(_args: &Args) {
    println!("== Fig 11: state swap overhead vs model size ==");
    let cfg = flexmarl::config::ClusterConfig::default();
    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>10}",
        "model", "suspend", "offload", "resume", "onload"
    );
    for m in [ModelScale::B3, ModelScale::B7, ModelScale::B14, ModelScale::B32] {
        let out = swap_out_cost(m, &cfg);
        let inn = swap_in_cost(m, &cfg, true);
        println!(
            "{:<6} {:>9.2}s {:>9.2}s {:>9.2}s {:>9.2}s",
            format!("{}B", m.params_b as u32),
            out.control_s,
            out.transfer_s,
            inn.control_s,
            inn.transfer_s
        );
    }
}

/// Grid sweep on the deterministic parallel executor: frameworks ×
/// scenarios × seed replicates. `--framework`/`--scenario` restrict an
/// axis to one value; the default grid is all baselines × all presets.
/// The JSON report is byte-identical for any `--jobs` (CI diffs it).
fn cmd_sweep(args: &Args) {
    let cfg = build_cfg(args);
    // Every grid cell generates its workload fresh (a trace header is
    // authoritative and would silently override the scenario axis) —
    // refuse rather than quietly ignore the flag.
    if args.get("trace").is_some() {
        eprintln!(
            "sweep generates every cell fresh; --trace is not supported \
             (use `simulate --trace` or `replay` for a single recorded run)"
        );
        std::process::exit(2);
    }
    let opts = build_opts(args);
    let frameworks = if args.get("framework").is_some() {
        vec![cfg.framework]
    } else {
        Framework::all_baselines()
    };
    // build_cfg validated --scenario; canonicalize alias spellings
    // ("Core-Skew") so the restricted axis carries the registry name.
    let scenarios = if args.get("scenario").is_some() {
        // build_cfg validated the name; a clean exit beats a panic if
        // that invariant ever drifts.
        let scen = flexmarl::workload::scenario::by_name(&cfg.workload.scenario)
            .unwrap_or_else(|| {
                eprintln!("unknown scenario '{}'", cfg.workload.scenario);
                std::process::exit(2)
            });
        vec![scen.name().to_string()]
    } else {
        flexmarl::workload::scenario::owned_names()
    };
    let grid = flexmarl::exec::RunGrid {
        frameworks,
        scenarios,
        replicates: args.get_usize("replicates", 1),
        overrides: flexmarl::exec::Overrides::default(),
    };
    let emit = args.get("emit");
    match emit {
        None | Some("jsonl") => {}
        Some(other) => {
            eprintln!("unknown --emit mode '{other}' for sweep (jsonl)");
            std::process::exit(2);
        }
    }
    let progress = args.has_flag("progress");
    let specs = grid.specs(&cfg);
    let jobs = args.get_usize("jobs", flexmarl::util::pool::default_jobs());
    // Worker count goes to stderr only: stdout/JSON must not depend
    // on --jobs.
    eprintln!("sweep: {} runs, jobs={jobs}", specs.len());
    // Per-cell completion stream: progress lines on stderr, `--emit
    // jsonl` cell lines on stdout. Cells stream in completion order
    // (jobs-dependent); each line's content — and the final grid JSON,
    // which is built from the input-ordered results below — is
    // byte-identical for any --jobs.
    let done = AtomicUsize::new(0);
    let n_cells = specs.len();
    let results = flexmarl::exec::run_specs_streamed(&cfg, &opts, &specs, jobs, |i, res| {
        let k = done.fetch_add(1, Ordering::Relaxed) + 1;
        let spec = &specs[i];
        match res {
            Ok(r) => {
                if progress {
                    eprintln!(
                        "sweep: cell {k}/{n_cells} done  {} × {} (seed {})  e2e {:.1}s",
                        spec.framework.name, r.scenario, spec.seed, r.e2e_s
                    );
                }
                if emit == Some("jsonl") {
                    let line = Json::obj(vec![
                        ("cell", Json::num(i as f64)),
                        ("framework", Json::str(spec.framework.name)),
                        ("scenario", Json::str(r.scenario.clone())),
                        ("seed", Json::str(spec.seed.to_string())),
                        ("report", r.to_json()),
                    ]);
                    println!("{}", line.to_string());
                }
            }
            Err(e) => {
                if progress {
                    eprintln!("sweep: cell {k}/{n_cells} failed: {e}");
                }
            }
        }
    });
    let mut reports = Vec::with_capacity(specs.len());
    for res in results {
        match res {
            Ok(r) => reports.push(r),
            Err(e) => {
                eprintln!("invalid workload: {e}");
                std::process::exit(2);
            }
        }
    }
    if emit.is_none() {
        // The table shares stdout with the jsonl stream — suppress it
        // there so stdout stays pure cell lines.
        println!(
            "{:<26} {:<13} {:>10} {:>9} {:>10} {:>7} {:>6}",
            "framework", "scenario", "seed", "e2e", "tps", "util%", "scale"
        );
        for (s, r) in specs.iter().zip(&reports) {
            println!(
                "{:<26} {:<13} {:>10} {:>8.1}s {:>10.1} {:>7.1} {:>6}",
                s.framework.name,
                r.scenario,
                s.seed,
                r.e2e_s,
                r.throughput_tps(),
                r.utilization() * 100.0,
                r.scale_ops
            );
        }
    }
    emit_json(args, &flexmarl::exec::grid_report(&cfg, &specs, &reports));
}

fn cmd_scenarios(args: &Args) {
    println!("== Workload scenario presets (DESIGN.md §2 catalogue) ==");
    println!("{:<14} stresses", "scenario");
    for s in flexmarl::workload::scenario::all() {
        println!("{:<14} {}", s.name(), s.stresses());
    }
    if args.has_flag("run") {
        // Execute the scenario sweep through the parallel executor —
        // the same rows the CI matrix and paper_benches check. Like
        // `sweep`, every preset row generates fresh, so a --trace
        // would be silently dropped — refuse it instead.
        if args.get("trace").is_some() {
            eprintln!(
                "scenarios --run generates every preset fresh; --trace is not \
                 supported (use `simulate --trace` or `replay`)"
            );
            std::process::exit(2);
        }
        // The preset axis here is always "all seven" — a flag that
        // would restrict or replicate it belongs to `sweep`, and
        // dropping it silently is the hazard.
        if args.get("scenario").is_some() || args.get("replicates").is_some() {
            eprintln!(
                "scenarios --run always sweeps every preset; use \
                 `sweep --scenario <name> [--replicates N]` for a restricted grid"
            );
            std::process::exit(2);
        }
        let cfg = build_cfg(args);
        let opts = build_opts(args);
        let jobs = args.get_usize("jobs", flexmarl::util::pool::default_jobs());
        eprintln!("scenario sweep: jobs={jobs}");
        println!(
            "\n{:<14} {:>9} {:>10} {:>7} {:>6}",
            "scenario", "e2e", "tps", "util%", "scale"
        );
        for r in flexmarl::baselines::scenario_sweep_jobs(&cfg, &opts, jobs) {
            println!(
                "{:<14} {:>8.1}s {:>10.1} {:>7.1} {:>6}",
                r.scenario,
                r.e2e_s,
                r.throughput_tps(),
                r.utilization() * 100.0,
                r.scale_ops
            );
        }
        return;
    }
    println!("\nuse: flexmarl simulate --scenario <name>");
    println!("     flexmarl scenarios --run             (sweep all presets)");
    println!("     flexmarl sweep --jobs 4 --json g.json (full grid)");
    println!("     flexmarl record --scenario <name> --out t.jsonl");
    println!("     flexmarl replay --trace t.jsonl");
}

fn cmd_record(args: &Args) {
    let cfg = build_cfg(args);
    let out = args.get_or("out", "trace.jsonl");
    let tr = flexmarl::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps)
        .unwrap_or_else(|e| {
            eprintln!("record failed: {e}");
            std::process::exit(1)
        });
    tr.write_file(&out).unwrap_or_else(|e| {
        eprintln!("record failed: {e}");
        std::process::exit(1)
    });
    println!(
        "recorded {} steps of scenario '{}' on {} (seed {}): {} trajectories, {} calls → {out}",
        tr.steps.len(),
        tr.scenario,
        tr.workload,
        tr.seed,
        tr.steps.iter().map(|s| s.trajectories.len()).sum::<usize>(),
        tr.total_calls(),
    );
}

fn cmd_replay(args: &Args) {
    let path = args.get("trace").unwrap_or_else(|| {
        eprintln!("replay needs --trace <path>");
        std::process::exit(2)
    });
    // `replay` reads the trace twice (header here, stream in the
    // engine), which a pipe cannot replay — route stdin users to the
    // single-read `simulate --trace -` path instead.
    if path == "-" {
        eprintln!(
            "replay re-reads the trace and cannot consume stdin; \
             use `flexmarl simulate --trace -` for piped streams"
        );
        std::process::exit(2);
    }
    let tr = flexmarl::workload::Trace::read_path(path).unwrap_or_else(|e| {
        eprintln!("replay failed: {e}");
        std::process::exit(1)
    });
    // Reconstruct the recording config from the trace header, so a
    // replayed run is metric-identical to the generating run. Only the
    // named presets are reconstructable; traces recorded from custom
    // configs must be replayed via `simulate --trace` under that config.
    let mut wl = match tr.workload.to_ascii_uppercase().as_str() {
        "CA" => WorkloadConfig::ca(),
        "MA" => WorkloadConfig::ma(),
        other => {
            eprintln!(
                "replay: trace was recorded on workload '{other}', which is not a \
                 named preset (MA/CA) — rebuild that config and use `simulate --trace`"
            );
            std::process::exit(2)
        }
    };
    wl.scenario = tr.scenario.clone();
    wl.trace = Some(path.to_string());
    let fw = framework_by_name(&args.get_or("framework", "FlexMARL")).unwrap_or_else(|| {
        eprintln!("unknown framework");
        std::process::exit(2)
    });
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = tr.steps.len();
    cfg.seed = tr.seed;
    // Steps/seed are provenance (trace header wins); engine knobs must
    // still honor the same flags `simulate` does, or a replayed run
    // with --micro-batch/--delta silently diverges from its recording.
    cfg.pipeline.micro_batch = args.get_usize("micro-batch", cfg.pipeline.micro_batch);
    cfg.pipeline.delta_threshold = args.get_usize("delta", cfg.pipeline.delta_threshold);
    if let Some(m) = args.get("workload-mode") {
        cfg.workload_mode = flexmarl::config::WorkloadMode::from_name(m).unwrap_or_else(|| {
            eprintln!("unknown workload mode '{m}' (want eager or lazy)");
            std::process::exit(2)
        });
    }
    let rep = run_eval(&cfg, &build_opts(args));
    print_report(&rep);
    emit_json(args, &rep.to_json());
}

/// Rollout-as-a-Service front-end (DESIGN.md §13). Everything on
/// stdout, in `--json` and under `--out-dir` is a pure function of
/// (mix, seed, ticks, slots, queue-cap): CI runs two `--workers`
/// counts and byte-diffs all three. Wall-clock numbers go to stderr.
fn cmd_serve(args: &Args) {
    let mix = args.get_or("mix", "mixed");
    let seed = args.get_u64("seed", 2048);
    let mut cfg = flexmarl::serve::ServeConfig::mix(&mix, seed).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    cfg.ticks = args.get_u64("ticks", cfg.ticks);
    cfg.slots = args.get_usize("slots", cfg.slots);
    cfg.queue_cap = args.get_usize("queue-cap", cfg.queue_cap);
    if let Some(t) = args.get("trace") {
        // Every session replays the same recording; a pipe can only be
        // read once, so stdin cannot back a multi-session plane.
        if t == "-" {
            eprintln!(
                "serve replays the trace once per session; stdin ('-') cannot be \
                 re-read — pass a file path"
            );
            std::process::exit(2);
        }
        cfg.trace = Some(t.to_string());
    }
    let workers = args.get_usize("workers", flexmarl::util::pool::default_jobs());
    let plane = flexmarl::serve::ServePlane::new(cfg, workers).unwrap_or_else(|e| {
        eprintln!("invalid serve config: {e}");
        std::process::exit(2)
    });
    // Worker count is wall-clock-only state — stderr, like sweep's jobs.
    eprintln!("serve: mix={mix} seed={seed} workers={workers}");
    let out = plane.run().unwrap_or_else(|e| {
        eprintln!("serve failed: {e}");
        std::process::exit(1)
    });
    let r = &out.report;
    if !args.has_flag("quiet") {
        println!(
            "serve[{}] seed {}: {} submitted | {} admitted | {} rejected \
             (queue_full {}, quota {}) | {} expired | {} completed",
            r.mix,
            r.seed,
            r.submitted,
            r.admitted,
            r.rejected_queue_full + r.rejected_quota,
            r.rejected_queue_full,
            r.rejected_quota,
            r.expired,
            r.completed
        );
        println!(
            "  makespan {} ticks  {:.2} sessions/kilotick  queue depth max {} mean {:.2}",
            r.makespan_ticks, r.sessions_per_kilotick, r.queue_depth_max, r.queue_depth_mean
        );
        println!(
            "  wait p50 {:.0} p90 {:.0} p99 {:.0} ticks  step latency p50 {:.1}s p99 {:.1}s",
            r.wait_ticks.p50(),
            r.wait_ticks.p90(),
            r.wait_ticks.p99(),
            r.step_latency_s.p50(),
            r.step_latency_s.p99()
        );
        for t in &r.tenants {
            println!(
                "  tenant {:<12} {:>5} submitted {:>5} completed {:>4} rejected \
                 {:>4} expired  wait p99 {:.0}",
                t.name,
                t.submitted,
                t.completed,
                t.rejected_queue_full + t.rejected_quota,
                t.expired,
                t.wait_ticks.p99()
            );
        }
    }
    // Real throughput depends on --workers: stderr only.
    eprintln!(
        "serve: {} sessions in {:.2}s wall ({:.0} sessions/s)",
        r.completed,
        out.wall_s,
        r.completed as f64 / out.wall_s.max(1e-9)
    );
    if let Some(dir) = args.get("out-dir") {
        fn fail(path: &str, e: std::io::Error) -> ! {
            let err = flexmarl::error::PallasError::File {
                path: path.to_string(),
                error: e.to_string(),
            };
            eprintln!("failed to write --out-dir: {err}");
            std::process::exit(1)
        }
        if let Err(e) = std::fs::create_dir_all(dir) {
            fail(dir, e);
        }
        for s in &out.sessions {
            let path = format!("{dir}/session-{:05}.jsonl", s.seq);
            if let Err(e) = std::fs::write(&path, &s.jsonl) {
                fail(&path, e);
            }
        }
        eprintln!("wrote {} session streams to {dir}/", out.sessions.len());
    }
    emit_json(args, &r.to_json());
}

/// Distributed run (DESIGN.md §14): per-step workload generation is
/// spread over claim-based shard workers behind a coordinator; the
/// engine itself runs here, pulling byte-identical steps. Everything on
/// stdout, in `--json` and under `--emit jsonl` is a pure function of
/// the config — CI byte-diffs it against `simulate` across worker
/// counts and transports. Worker bookkeeping goes to stderr.
fn cmd_dist(args: &Args) {
    use flexmarl::dist::{DistPlan, TransportKind, WorkerFault};
    // These planes assume single-process resolution; refusing beats
    // silently diverging from the `simulate` reference bytes.
    for flag in ["trace", "workload-mode", "resume", "checkpoint-every", "checkpoint-dir"] {
        if args.get(flag).is_some() {
            eprintln!("dist does not support --{flag}; run single-process `simulate` for that");
            std::process::exit(2);
        }
    }
    let cfg = build_cfg(args);
    let transport_name = args.get_or("transport", "channel");
    let transport = TransportKind::parse(&transport_name).unwrap_or_else(|| {
        eprintln!("unknown --transport '{transport_name}' (channel | socket)");
        std::process::exit(2)
    });
    let mut plan = DistPlan {
        workers: args.get_usize("workers", 2),
        transport,
        fail: None,
    };
    // Undocumented fault hook for the chaos CI smoke: worker W dies
    // silently on its K-th (0-based) shard assignment.
    if let Some(spec) = args.get("worker-fail") {
        plan.fail = spec
            .split_once(':')
            .and_then(|(w, k)| {
                Some(WorkerFault {
                    worker: w.parse().ok()?,
                    after_assigns: k.parse().ok()?,
                })
            })
            .map(Some)
            .unwrap_or_else(|| {
                eprintln!("--worker-fail needs W:K (worker index, assign ordinal); got '{spec}'");
                std::process::exit(2)
            });
    }
    if let Err(e) = plan.validate() {
        eprintln!("invalid dist plan: {e}");
        std::process::exit(2);
    }
    let emit = args.get("emit");
    match emit {
        None | Some("jsonl") => {}
        Some(other) => {
            eprintln!("unknown --emit mode '{other}' for dist (jsonl)");
            std::process::exit(2);
        }
    }
    // Worker count and transport are wall-clock-only state — stderr,
    // like sweep's jobs and serve's workers.
    eprintln!(
        "dist: {} workers over {} transport",
        plan.workers,
        plan.transport.name()
    );
    let exp = Experiment::new(cfg)
        .options(build_opts(args))
        .dist(plan)
        .build()
        .unwrap_or_else(|e| {
            eprintln!("invalid workload: {e}");
            std::process::exit(2)
        });
    let total_steps = exp.config().steps;
    let overlaps = exp.policies().pipeline.overlaps_steps();
    let mut session = exp.session().unwrap_or_else(|e| {
        eprintln!("invalid workload: {e}");
        std::process::exit(2)
    });
    if args.has_flag("progress") {
        session.add_sink(Box::new(ProgressSink::stderr(total_steps)));
    }
    if emit == Some("jsonl") {
        session.add_sink(Box::new(JsonlSink::stdout()));
    }
    loop {
        match session.step() {
            Ok(Some(_)) => {}
            Ok(None) => break,
            // Typed runtime failures — every worker gone, a corrupt
            // frame, a protocol violation — exit 1, never a panic.
            Err(e) => {
                eprintln!("simulation failed: {e}");
                std::process::exit(1);
            }
        }
    }
    let out = session.finish();
    match out.evaluate(overlaps) {
        Some(rep) => {
            if emit.is_none() {
                print_report(&rep);
            }
            emit_json(args, &rep.to_json());
        }
        None => {
            eprintln!("no steps completed before the stop");
            std::process::exit(1);
        }
    }
}

/// Internal: the child-process end of `dist --transport socket`. Exits
/// 0 on shutdown or coordinator disconnect, 1 with the typed error on
/// protocol violations or corrupt frames.
fn cmd_dist_worker(args: &Args) {
    let addr = args.get("connect").unwrap_or_else(|| {
        eprintln!("dist-worker needs --connect <addr> (spawned by `dist --transport socket`)");
        std::process::exit(2)
    });
    if let Err(e) = flexmarl::dist::socket::run_connected(addr) {
        eprintln!("worker failed: {e}");
        std::process::exit(1);
    }
}

fn cmd_inspect(args: &Args) {
    let path = args.get_or("manifest", "artifacts/manifest.json");
    match flexmarl::runtime::Manifest::load(&path) {
        Ok(m) => {
            println!("{}", m.summary());
        }
        Err(e) => {
            eprintln!("failed to load {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_train(args: &Args) {
    let steps = args.get_usize("steps", 20);
    let agents = args.get_usize("agents", 3);
    let dir = args.get_or("artifacts", "artifacts");
    let seed = args.get_u64("seed", 2048);
    let lr = args.get_f64("lr", 3e-4) as f32;
    match flexmarl::runtime::marl::train_e2e(&dir, agents, steps, seed, lr, !args.has_flag("quiet"))
    {
        Ok(summary) => println!("{summary}"),
        Err(e) => {
            eprintln!("training failed: {e}");
            std::process::exit(1);
        }
    }
}
