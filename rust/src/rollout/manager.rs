//! Intra-agent rollout manager (§5.2): per-agent inference-instance
//! pools with min-heap least-loaded dispatch, per-instance continuous-
//! batching slots, and fault tolerance (completion removal, timeout
//! cancellation, re-queue of unfinished requests).
//!
//! The manager is pure scheduling state — no clocks, no I/O — so the
//! discrete-event simulator and the real PJRT mini-cluster drive the
//! same code (DESIGN.md §5).

use super::heap::IndexedMinHeap;
use crate::ckpt::{as_ju64, ju64};
use crate::util::hash::FastMap;
use crate::util::json::Json;
use std::collections::VecDeque;

pub type RequestId = u64;
pub type InstanceId = usize;
pub type AgentId = usize;

/// Where a submitted request ended up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Started immediately on the instance (a free batching slot).
    Started(InstanceId),
    /// Enqueued on the least-loaded instance.
    Enqueued(InstanceId),
    /// Agent currently has no instances (mid-migration) — parked.
    Parked,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqState {
    Parked,
    Queued(InstanceId),
    Active(InstanceId),
}

#[derive(Debug)]
struct Instance {
    agent: AgentId,
    max_concurrency: usize,
    active: Vec<RequestId>,
    queue: VecDeque<RequestId>,
    /// Draining: finishes active work, accepts nothing new (migration).
    draining: bool,
}

impl Instance {
    fn load(&self) -> u64 {
        (self.active.len() + self.queue.len()) as u64
    }
}

#[derive(Debug, Default)]
pub struct RolloutManager {
    instances: Vec<Option<Instance>>,
    /// Per-agent min-heap over instance loads.
    heaps: Vec<IndexedMinHeap>,
    /// Request table on the submit/complete hot path: O(1) fast-hash
    /// map (request ids are trusted, in-process, mostly sequential).
    requests: FastMap<RequestId, (AgentId, ReqState)>,
    /// Requests waiting for an agent with zero instances.
    parked: Vec<VecDeque<RequestId>>,
    /// Monotone counters for metrics.
    pub completed_per_agent: Vec<u64>,
}

impl RolloutManager {
    pub fn new(n_agents: usize) -> Self {
        RolloutManager {
            instances: Vec::new(),
            heaps: (0..n_agents).map(|_| IndexedMinHeap::new()).collect(),
            requests: FastMap::default(),
            parked: (0..n_agents).map(|_| VecDeque::new()).collect(),
            completed_per_agent: vec![0; n_agents],
        }
    }

    pub fn n_agents(&self) -> usize {
        self.heaps.len()
    }

    // ---- instance lifecycle ------------------------------------------------

    pub fn add_instance(&mut self, agent: AgentId, max_concurrency: usize) -> (InstanceId, Vec<RequestId>) {
        let id = self.instances.len();
        self.instances.push(Some(Instance {
            agent,
            max_concurrency,
            active: Vec::new(),
            queue: VecDeque::new(),
            draining: false,
        }));
        self.heaps[agent].insert(id, 0);
        // Un-park any waiting requests: they start/queue on the new instance.
        let mut started = Vec::new();
        while let Some(rid) = self.parked[agent].pop_front() {
            match self.place(rid, agent) {
                Dispatch::Started(_) => started.push(rid),
                Dispatch::Enqueued(_) => {}
                Dispatch::Parked => unreachable!("instance just added"),
            }
        }
        (id, started)
    }

    /// Begin removing an instance (inter-agent migration). Its queued
    /// requests are returned for re-submission; active requests keep
    /// running — the instance detaches once drained (`is_drained`).
    pub fn drain_instance(&mut self, iid: InstanceId) -> Vec<RequestId> {
        let inst = self.instances[iid].as_mut().expect("no such instance");
        inst.draining = true;
        let agent = inst.agent;
        let displaced: Vec<RequestId> = inst.queue.drain(..).collect();
        for rid in &displaced {
            self.requests.remove(rid);
        }
        self.heaps[agent].remove(iid);
        displaced
    }

    pub fn is_drained(&self, iid: InstanceId) -> bool {
        self.instances[iid]
            .as_ref()
            .map(|i| i.draining && i.active.is_empty())
            .unwrap_or(true)
    }

    /// Finalize removal of a drained instance.
    pub fn remove_instance(&mut self, iid: InstanceId) {
        assert!(self.is_drained(iid), "instance {iid} still has active work");
        self.instances[iid] = None;
    }

    /// Hard failure (fault injection): the instance dies *now*. Returns
    /// its `(active, queued)` requests for the caller's recovery policy
    /// to re-dispatch or discard; nothing keeps running. Unlike
    /// [`RolloutManager::drain_instance`], the slot is gone immediately.
    pub fn fail_instance(&mut self, iid: InstanceId) -> (Vec<RequestId>, Vec<RequestId>) {
        let inst = self.instances[iid].as_mut().expect("no such instance");
        let agent = inst.agent;
        let was_draining = inst.draining;
        let active: Vec<RequestId> = inst.active.drain(..).collect();
        let queued: Vec<RequestId> = inst.queue.drain(..).collect();
        for rid in active.iter().chain(queued.iter()) {
            self.requests.remove(rid);
        }
        if !was_draining {
            self.heaps[agent].remove(iid);
        }
        self.instances[iid] = None;
        (active, queued)
    }

    pub fn instances_of(&self, agent: AgentId) -> Vec<InstanceId> {
        self.heaps[agent].ids().collect()
    }

    /// Instances sorted by current load (idlest first) — migration picks
    /// donors from the front so draining strands minimal active work.
    pub fn instances_by_load(&self, agent: AgentId) -> Vec<InstanceId> {
        let mut ids: Vec<InstanceId> = self.heaps[agent].ids().collect();
        ids.sort_by_key(|&i| {
            let inst = self.instances[i].as_ref().unwrap();
            (inst.active.len() + inst.queue.len(), i)
        });
        ids
    }

    pub fn instance_count(&self, agent: AgentId) -> usize {
        self.heaps[agent].len()
    }

    // ---- request lifecycle ---------------------------------------------------

    /// Least-loaded dispatch (min-heap, §5.2).
    pub fn submit(&mut self, rid: RequestId, agent: AgentId) -> Dispatch {
        assert!(
            !self.requests.contains_key(&rid),
            "request {rid} already submitted"
        );
        self.place(rid, agent)
    }

    fn place(&mut self, rid: RequestId, agent: AgentId) -> Dispatch {
        let Some(iid) = self.heaps[agent].peek_min() else {
            self.parked[agent].push_back(rid);
            self.requests.insert(rid, (agent, ReqState::Parked));
            return Dispatch::Parked;
        };
        let inst = self.instances[iid].as_mut().unwrap();
        let d = if inst.active.len() < inst.max_concurrency {
            inst.active.push(rid);
            self.requests.insert(rid, (agent, ReqState::Active(iid)));
            Dispatch::Started(iid)
        } else {
            inst.queue.push_back(rid);
            self.requests.insert(rid, (agent, ReqState::Queued(iid)));
            Dispatch::Enqueued(iid)
        };
        self.heaps[agent].update(iid, self.instances[iid].as_ref().unwrap().load());
        d
    }

    /// A request finished generating. Returns the next request that
    /// starts on the freed slot (if any).
    pub fn complete(&mut self, rid: RequestId) -> Option<RequestId> {
        let (agent, state) = self.requests.remove(&rid).expect("unknown request");
        let ReqState::Active(iid) = state else {
            panic!("request {rid} completed but not active");
        };
        self.completed_per_agent[agent] += 1;
        let inst = self.instances[iid].as_mut().unwrap();
        inst.active.retain(|&r| r != rid);
        let next = inst.queue.pop_front();
        if let Some(nrid) = next {
            inst.active.push(nrid);
            self.requests.insert(nrid, (agent, ReqState::Active(iid)));
        }
        if !inst.draining {
            self.heaps[agent].update(iid, self.instances[iid].as_ref().unwrap().load());
        }
        next
    }

    /// Fault tolerance: cancel a timed-out or failed request wherever it
    /// is. Returns the request that starts on the freed slot, if the
    /// cancelled one was active.
    pub fn cancel(&mut self, rid: RequestId) -> Option<RequestId> {
        let (agent, state) = self.requests.remove(&rid)?;
        match state {
            ReqState::Parked => {
                self.parked[agent].retain(|&r| r != rid);
                None
            }
            ReqState::Queued(iid) => {
                let inst = self.instances[iid].as_mut().unwrap();
                inst.queue.retain(|&r| r != rid);
                if !inst.draining {
                    self.heaps[agent].update(iid, inst.load());
                }
                None
            }
            ReqState::Active(iid) => {
                let inst = self.instances[iid].as_mut().unwrap();
                inst.active.retain(|&r| r != rid);
                let next = inst.queue.pop_front();
                if let Some(nrid) = next {
                    inst.active.push(nrid);
                    self.requests.insert(nrid, (agent, ReqState::Active(iid)));
                }
                if !inst.draining {
                    self.heaps[agent].update(iid, self.instances[iid].as_ref().unwrap().load());
                }
                next
            }
        }
    }

    // ---- load metrics (polled by the inter-agent scaler) --------------------

    /// Waiting requests for an agent: queued on instances + parked.
    pub fn queue_len(&self, agent: AgentId) -> usize {
        let queued: usize = self.heaps[agent]
            .ids()
            .map(|iid| self.instances[iid].as_ref().unwrap().queue.len())
            .sum();
        queued + self.parked[agent].len()
    }

    /// Active + queued (total outstanding).
    pub fn outstanding(&self, agent: AgentId) -> usize {
        let inflight: usize = self.heaps[agent]
            .ids()
            .map(|iid| {
                let i = self.instances[iid].as_ref().unwrap();
                i.active.len() + i.queue.len()
            })
            .sum();
        inflight + self.parked[agent].len()
    }

    pub fn queue_lens(&self) -> Vec<usize> {
        (0..self.n_agents()).map(|a| self.queue_len(a)).collect()
    }

    pub fn instance_counts(&self) -> Vec<usize> {
        (0..self.n_agents()).map(|a| self.instance_count(a)).collect()
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: instance slots (including tombstones —
    /// `InstanceId`s are slot indices, so holes must survive), per-agent
    /// heap layouts, parked queues, and completion counters. The
    /// request table is *not* serialized: every request's state is
    /// fully determined by which instance list or parked queue holds
    /// it, so restore rebuilds the table from those.
    pub fn snapshot(&self) -> Json {
        let rid_arr = |rids: &mut dyn Iterator<Item = &RequestId>| -> Json {
            Json::arr(rids.map(|&r| ju64(r)))
        };
        Json::obj(vec![
            (
                "instances",
                Json::arr(self.instances.iter().map(|slot| match slot {
                    None => Json::Null,
                    Some(i) => Json::obj(vec![
                        ("agent", Json::num(i.agent as f64)),
                        ("max_concurrency", Json::num(i.max_concurrency as f64)),
                        ("active", rid_arr(&mut i.active.iter())),
                        ("queue", rid_arr(&mut i.queue.iter())),
                        ("draining", Json::Bool(i.draining)),
                    ]),
                })),
            ),
            (
                "heaps",
                Json::arr(self.heaps.iter().map(|h| {
                    Json::arr(h.snapshot_pairs().into_iter().map(|(id, key)| {
                        Json::arr([Json::num(id as f64), ju64(key)])
                    }))
                })),
            ),
            (
                "parked",
                Json::arr(self.parked.iter().map(|q| rid_arr(&mut q.iter()))),
            ),
            (
                "completed_per_agent",
                Json::arr(self.completed_per_agent.iter().map(|&c| ju64(c))),
            ),
        ])
    }

    /// Rebuild a manager from [`RolloutManager::snapshot`]. The agent
    /// count must match the config the engine was rebuilt from.
    pub fn restore_from(j: &Json, n_agents: usize) -> Result<RolloutManager, String> {
        let rids = |j: &Json, what: &str| -> Result<Vec<RequestId>, String> {
            j.as_arr()
                .ok_or(format!("bad {what} list"))?
                .iter()
                .map(|r| as_ju64(r).ok_or(format!("bad request id in {what}")))
                .collect()
        };
        let mut m = RolloutManager::new(n_agents);
        let insts = j
            .get("instances")
            .and_then(Json::as_arr)
            .ok_or("manager missing 'instances'")?;
        for (iid, slot) in insts.iter().enumerate() {
            if matches!(slot, Json::Null) {
                m.instances.push(None);
                continue;
            }
            let agent = slot
                .get("agent")
                .and_then(Json::as_usize)
                .ok_or("instance missing 'agent'")?;
            if agent >= n_agents {
                return Err(format!("instance {iid} names agent {agent} of {n_agents}"));
            }
            let active = rids(slot.get("active").unwrap_or(&Json::Null), "active")?;
            let queue = rids(slot.get("queue").unwrap_or(&Json::Null), "queue")?;
            for &rid in &active {
                m.requests.insert(rid, (agent, ReqState::Active(iid)));
            }
            for &rid in &queue {
                m.requests.insert(rid, (agent, ReqState::Queued(iid)));
            }
            m.instances.push(Some(Instance {
                agent,
                max_concurrency: slot
                    .get("max_concurrency")
                    .and_then(Json::as_usize)
                    .ok_or("instance missing 'max_concurrency'")?,
                active,
                queue: queue.into(),
                draining: slot
                    .get("draining")
                    .and_then(Json::as_bool)
                    .ok_or("instance missing 'draining'")?,
            }));
        }
        let heaps = j
            .get("heaps")
            .and_then(Json::as_arr)
            .ok_or("manager missing 'heaps'")?;
        if heaps.len() != n_agents {
            return Err(format!("checkpoint has {} heaps for {n_agents} agents", heaps.len()));
        }
        for (a, hj) in heaps.iter().enumerate() {
            let pairs = hj
                .as_arr()
                .ok_or("bad heap")?
                .iter()
                .map(|p| {
                    let p = p.as_arr().filter(|p| p.len() == 2).ok_or("bad heap pair")?;
                    let id = p[0].as_usize().ok_or("bad heap id")?;
                    let key = as_ju64(&p[1]).ok_or("bad heap key")?;
                    Ok::<(usize, u64), String>((id, key))
                })
                .collect::<Result<Vec<_>, _>>()?;
            if pairs.iter().any(|&(id, _)| {
                id >= m.instances.len() || m.instances[id].is_none()
            }) {
                return Err(format!("heap {a} references a missing instance"));
            }
            m.heaps[a] = IndexedMinHeap::restore_pairs(&pairs);
        }
        let parked = j
            .get("parked")
            .and_then(Json::as_arr)
            .ok_or("manager missing 'parked'")?;
        if parked.len() != n_agents {
            return Err("parked queue count mismatch".to_string());
        }
        for (a, pj) in parked.iter().enumerate() {
            let q = rids(pj, "parked")?;
            for &rid in &q {
                m.requests.insert(rid, (a, ReqState::Parked));
            }
            m.parked[a] = q.into();
        }
        let completed = j
            .get("completed_per_agent")
            .and_then(Json::as_arr)
            .ok_or("manager missing 'completed_per_agent'")?;
        if completed.len() != n_agents {
            return Err("completed_per_agent count mismatch".to_string());
        }
        m.completed_per_agent = completed
            .iter()
            .map(|c| as_ju64(c).ok_or("bad completion counter".to_string()))
            .collect::<Result<_, _>>()?;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn least_loaded_dispatch() {
        let mut m = RolloutManager::new(1);
        let (i0, _) = m.add_instance(0, 1);
        let (i1, _) = m.add_instance(0, 1);
        assert_eq!(m.submit(1, 0), Dispatch::Started(i0));
        assert_eq!(m.submit(2, 0), Dispatch::Started(i1));
        // Both full: next goes to queue of the (tie-break lowest id).
        assert_eq!(m.submit(3, 0), Dispatch::Enqueued(i0));
        assert_eq!(m.submit(4, 0), Dispatch::Enqueued(i1));
        assert_eq!(m.queue_len(0), 2);
    }

    #[test]
    fn completion_starts_queued_fifo() {
        let mut m = RolloutManager::new(1);
        let (i0, _) = m.add_instance(0, 1);
        m.submit(1, 0);
        m.submit(2, 0);
        m.submit(3, 0);
        assert_eq!(m.complete(1), Some(2));
        assert_eq!(m.queue_len(0), 1);
        assert_eq!(m.complete(2), Some(3));
        assert_eq!(m.complete(3), None);
        assert_eq!(m.completed_per_agent[0], 3);
        assert_eq!(m.queue_len(0), 0);
        let _ = i0;
    }

    #[test]
    fn concurrency_slots_respected() {
        let mut m = RolloutManager::new(1);
        m.add_instance(0, 4);
        for r in 0..6 {
            m.submit(r, 0);
        }
        assert_eq!(m.queue_len(0), 2); // 4 active, 2 queued
        assert_eq!(m.outstanding(0), 6);
    }

    #[test]
    fn parked_requests_start_when_instance_arrives() {
        let mut m = RolloutManager::new(2);
        assert_eq!(m.submit(1, 1), Dispatch::Parked);
        assert_eq!(m.submit(2, 1), Dispatch::Parked);
        assert_eq!(m.queue_len(1), 2);
        let (_, started) = m.add_instance(1, 1);
        assert_eq!(started, vec![1]); // 1 starts, 2 queues
        assert_eq!(m.queue_len(1), 1);
    }

    #[test]
    fn cancel_in_all_states() {
        let mut m = RolloutManager::new(2);
        m.add_instance(0, 1);
        m.submit(1, 0); // active
        m.submit(2, 0); // queued
        m.submit(3, 1); // parked
        assert_eq!(m.cancel(2), None);
        assert_eq!(m.cancel(3), None);
        assert_eq!(m.cancel(1), None); // frees slot; queue empty now
        assert_eq!(m.outstanding(0), 0);
        assert_eq!(m.cancel(99), None); // unknown: no-op
    }

    #[test]
    fn cancel_active_promotes_queued() {
        let mut m = RolloutManager::new(1);
        m.add_instance(0, 1);
        m.submit(1, 0);
        m.submit(2, 0);
        assert_eq!(m.cancel(1), Some(2));
        assert_eq!(m.queue_len(0), 0);
        assert_eq!(m.outstanding(0), 1);
    }

    #[test]
    fn drain_displaces_queue_keeps_active() {
        let mut m = RolloutManager::new(2);
        let (i0, _) = m.add_instance(0, 1);
        m.add_instance(0, 1);
        m.submit(1, 0);
        m.submit(2, 0);
        m.submit(3, 0); // queued on i0
        let displaced = m.drain_instance(i0);
        assert_eq!(displaced, vec![3]);
        assert!(!m.is_drained(i0)); // request 1 still active
        // Displaced request re-submits to the surviving instance.
        m.submit(3, 0);
        assert_eq!(m.complete(1), None); // drained instance starts nothing new
        assert!(m.is_drained(i0));
        m.remove_instance(i0);
        assert_eq!(m.instance_count(0), 1);
    }

    #[test]
    fn fail_instance_surrenders_all_work_immediately() {
        let mut m = RolloutManager::new(1);
        let (i0, _) = m.add_instance(0, 1);
        let (i1, _) = m.add_instance(0, 1);
        m.submit(1, 0); // active on i0
        m.submit(2, 0); // active on i1
        m.submit(3, 0); // queued on i0
        let (active, queued) = m.fail_instance(i0);
        assert_eq!(active, vec![1]);
        assert_eq!(queued, vec![3]);
        // The slot is gone now — not draining, gone: dispatch only sees
        // the survivor, and the displaced rids can immediately re-submit.
        assert_eq!(m.instance_count(0), 1);
        assert_eq!(m.outstanding(0), 1); // request 2 on i1
        assert_eq!(m.submit(1, 0), Dispatch::Enqueued(i1));
        assert_eq!(m.submit(3, 0), Dispatch::Enqueued(i1));
        assert_eq!(m.complete(2), Some(1));
        assert_eq!(m.complete(1), Some(3));
        assert_eq!(m.complete(3), None);
        assert_eq!(m.completed_per_agent[0], 3);
    }

    #[test]
    fn fail_instance_on_draining_instance_is_clean() {
        // A fault can hit an instance mid-migration (already off the
        // heap); failing it must not double-remove the heap entry.
        let mut m = RolloutManager::new(1);
        let (i0, _) = m.add_instance(0, 1);
        m.add_instance(0, 1);
        m.submit(1, 0);
        m.submit(2, 0);
        m.submit(3, 0); // queued on i0
        let displaced = m.drain_instance(i0);
        assert_eq!(displaced, vec![3]);
        let (active, queued) = m.fail_instance(i0);
        assert_eq!(active, vec![1]);
        assert!(queued.is_empty());
        assert_eq!(m.instance_count(0), 1);
        assert_eq!(m.complete(2), None);
    }

    #[test]
    fn prop_no_lost_requests_and_balanced() {
        forall("manager conserves requests; load stays balanced", 60, |rng| {
            let mut m = RolloutManager::new(3);
            for a in 0..3 {
                for _ in 0..(rng.below(3) + 1) {
                    m.add_instance(a, 2);
                }
            }
            let mut outstanding = vec![0usize; 3];
            // Only *active* requests can complete (the simulator only
            // fires completion events for started generation).
            let mut active: Vec<(RequestId, usize)> = Vec::new();
            let mut next_rid = 0;
            for _ in 0..300 {
                if rng.f64() < 0.6 {
                    let a = rng.below(3) as usize;
                    match m.submit(next_rid, a) {
                        Dispatch::Started(_) => active.push((next_rid, a)),
                        Dispatch::Enqueued(_) => {}
                        Dispatch::Parked => panic!("instances exist"),
                    }
                    outstanding[a] += 1;
                    next_rid += 1;
                } else if !active.is_empty() {
                    let i = rng.below(active.len() as u64) as usize;
                    let (rid, a) = active.swap_remove(i);
                    if let Some(promoted) = m.complete(rid) {
                        active.push((promoted, a));
                    }
                    outstanding[a] -= 1;
                }
                for a in 0..3 {
                    assert_eq!(m.outstanding(a), outstanding[a], "agent {a}");
                }
            }
            // Drain everything: no request may be lost.
            while let Some((rid, a)) = active.pop() {
                if let Some(promoted) = m.complete(rid) {
                    active.push((promoted, a));
                }
                outstanding[a] -= 1;
            }
            assert_eq!(outstanding, vec![0, 0, 0]);
            for a in 0..3 {
                assert_eq!(m.outstanding(a), 0);
            }
        });
    }
}
