//! Indexed binary min-heap with update-key (substrate).
//!
//! §5.2: "A dedicated rollout manager employs a min-heap data structure
//! to track the instantaneous load of backend inference instances."
//! Instance loads change on every dispatch/completion, so we need
//! decrease/increase-key — `std::collections::BinaryHeap` has neither.
//! Keys are (load, id) so equal loads break ties deterministically.

#[derive(Debug, Clone)]
pub struct IndexedMinHeap {
    /// heap[i] = item id; ordered by key.
    heap: Vec<usize>,
    /// pos[id] = Some(index in heap) for members.
    pos: Vec<Option<usize>>,
    /// key[id] = current load.
    key: Vec<u64>,
}

impl IndexedMinHeap {
    pub fn new() -> Self {
        IndexedMinHeap {
            heap: Vec::new(),
            pos: Vec::new(),
            key: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn contains(&self, id: usize) -> bool {
        self.pos.get(id).copied().flatten().is_some()
    }

    pub fn key_of(&self, id: usize) -> Option<u64> {
        if self.contains(id) {
            Some(self.key[id])
        } else {
            None
        }
    }

    fn less(&self, a: usize, b: usize) -> bool {
        let (ia, ib) = (self.heap[a], self.heap[b]);
        (self.key[ia], ia) < (self.key[ib], ib)
    }

    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a]] = Some(a);
        self.pos[self.heap[b]] = Some(b);
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(i, parent) {
                self.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < self.heap.len() && self.less(l, smallest) {
                smallest = l;
            }
            if r < self.heap.len() && self.less(r, smallest) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.swap(i, smallest);
            i = smallest;
        }
    }

    /// Insert `id` with `key`; **panics** if `id` is already present.
    ///
    /// The panic is load-bearing: without it a duplicate insert would
    /// push a second heap entry for the same id, and since `pos[id]`
    /// can only track one position, every later `update`/`remove`
    /// would sift the wrong entry — silent position-tracking
    /// corruption. Callers that want upsert semantics use
    /// [`IndexedMinHeap::insert_or_update`].
    pub fn insert(&mut self, id: usize, key: u64) {
        assert!(!self.contains(id), "id {id} already in heap");
        if id >= self.pos.len() {
            self.pos.resize(id + 1, None);
            self.key.resize(id + 1, 0);
        }
        self.key[id] = key;
        self.pos[id] = Some(self.heap.len());
        self.heap.push(id);
        self.sift_up(self.heap.len() - 1);
    }

    /// Upsert: [`IndexedMinHeap::update`] if `id` is present,
    /// [`IndexedMinHeap::insert`] otherwise.
    pub fn insert_or_update(&mut self, id: usize, key: u64) {
        if self.contains(id) {
            self.update(id, key);
        } else {
            self.insert(id, key);
        }
    }

    /// The id with the minimum (key, id).
    pub fn peek_min(&self) -> Option<usize> {
        self.heap.first().copied()
    }

    /// Change `id`'s key, restoring heap order either direction.
    pub fn update(&mut self, id: usize, key: u64) {
        let i = self.pos[id].expect("id not in heap");
        let old = self.key[id];
        self.key[id] = key;
        if key < old {
            self.sift_up(i);
        } else {
            self.sift_down(i);
        }
    }

    pub fn remove(&mut self, id: usize) {
        let i = self.pos[id].expect("id not in heap");
        let last = self.heap.len() - 1;
        self.swap(i, last);
        self.heap.pop();
        self.pos[id] = None;
        if i < self.heap.len() {
            self.sift_up(i);
            self.sift_down(i);
        }
    }

    /// All member ids (arbitrary order).
    pub fn ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.heap.iter().copied()
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: `(id, key)` pairs in the exact internal
    /// array order. The layout is captured (not just the membership)
    /// because [`IndexedMinHeap::ids`] iterates it, and a resumed run
    /// must walk instances in the same order as the uninterrupted one.
    pub fn snapshot_pairs(&self) -> Vec<(usize, u64)> {
        self.heap.iter().map(|&id| (id, self.key[id])).collect()
    }

    /// Rebuild from [`IndexedMinHeap::snapshot_pairs`]: the array is
    /// restored verbatim (it is a valid heap by construction — it was
    /// one when captured) and `pos` is re-derived.
    pub fn restore_pairs(pairs: &[(usize, u64)]) -> IndexedMinHeap {
        let max_id = pairs.iter().map(|&(id, _)| id).max();
        let cap = max_id.map(|m| m + 1).unwrap_or(0);
        let mut h = IndexedMinHeap {
            heap: Vec::with_capacity(pairs.len()),
            pos: vec![None; cap],
            key: vec![0; cap],
        };
        for (i, &(id, key)) in pairs.iter().enumerate() {
            h.heap.push(id);
            h.pos[id] = Some(i);
            h.key[id] = key;
        }
        h
    }
}

impl Default for IndexedMinHeap {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn min_tracks_updates() {
        let mut h = IndexedMinHeap::new();
        h.insert(0, 5);
        h.insert(1, 3);
        h.insert(2, 7);
        assert_eq!(h.peek_min(), Some(1));
        h.update(1, 10);
        assert_eq!(h.peek_min(), Some(0));
        h.update(2, 1);
        assert_eq!(h.peek_min(), Some(2));
        h.remove(2);
        assert_eq!(h.peek_min(), Some(0));
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn equal_keys_tie_break_by_id() {
        let mut h = IndexedMinHeap::new();
        h.insert(5, 2);
        h.insert(3, 2);
        h.insert(9, 2);
        assert_eq!(h.peek_min(), Some(3));
    }

    #[test]
    #[should_panic(expected = "already in heap")]
    fn duplicate_insert_panics_instead_of_corrupting() {
        // Regression: a duplicate insert must never create a second
        // heap entry (which would desync `pos` and corrupt later
        // update/remove calls) — it panics instead.
        let mut h = IndexedMinHeap::new();
        h.insert(3, 5);
        h.insert(3, 1);
    }

    #[test]
    fn insert_or_update_is_safe_on_duplicates() {
        let mut h = IndexedMinHeap::new();
        h.insert_or_update(3, 5);
        h.insert_or_update(7, 2);
        h.insert_or_update(3, 1); // duplicate id → update, not corrupt
        assert_eq!(h.len(), 2);
        assert_eq!(h.peek_min(), Some(3));
        assert_eq!(h.key_of(3), Some(1));
        // The structure is still consistent: remove + re-insert works.
        h.remove(3);
        assert_eq!(h.peek_min(), Some(7));
        h.insert_or_update(3, 0);
        assert_eq!(h.peek_min(), Some(3));
    }

    #[test]
    fn prop_matches_linear_scan() {
        forall("heap min == linear-scan min", 100, |rng| {
            let mut h = IndexedMinHeap::new();
            let n = 12usize;
            let mut model: Vec<Option<u64>> = vec![None; n];
            for _ in 0..200 {
                let id = rng.below(n as u64) as usize;
                match (model[id].is_some(), rng.below(3)) {
                    (false, _) => {
                        let k = rng.below(50);
                        h.insert(id, k);
                        model[id] = Some(k);
                    }
                    (true, 0) => {
                        h.remove(id);
                        model[id] = None;
                    }
                    (true, _) => {
                        let k = rng.below(50);
                        h.update(id, k);
                        model[id] = Some(k);
                    }
                }
                let expect = model
                    .iter()
                    .enumerate()
                    .filter_map(|(i, k)| k.map(|k| (k, i)))
                    .min()
                    .map(|(_, i)| i);
                assert_eq!(h.peek_min(), expect);
            }
        });
    }
}
