//! Parallel sampling (§5.1): dependency-driven scheduling of multi-agent
//! trajectory generation.
//!
//! Sequential baseline: the next user query starts only after the whole
//! rollout of the current query finishes, and turns proceed in lockstep.
//! FlexMARL restructures this into a concurrent execution model with
//!  * inter-query parallelism — up to `inter_query` queries in flight;
//!  * intra-query parallelism — a query's GRPO candidates progress
//!    independently; a call is ready the moment its upstream (previous
//!    call of the same candidate chain) completes.

use crate::util::json::Json;
use crate::workload::StepWorkload;

/// Identifies one call: (trajectory index in the workload, call index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct CallRef {
    pub traj: usize,
    pub call: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Queries serial; per turn, all candidates batch then barrier
    /// (the MAS-RL execution model).
    SerialQueries,
    /// Dependency-driven: candidates independent, `inter_query` queries
    /// concurrently admitted.
    Parallel { inter_query: usize },
}

#[derive(Debug)]
pub struct TrajectoryScheduler {
    mode: Mode,
    /// Per trajectory: number of calls and next-call cursor.
    n_calls: Vec<usize>,
    next_call: Vec<usize>,
    query_of: Vec<usize>,
    /// Queries grouped: query -> trajectory indices.
    members: Vec<Vec<usize>>,
    /// Number of queries currently admitted. Queries are admitted in
    /// index order and each leaves admission exactly once (when its
    /// last call completes), so a counter replaces the old `BTreeSet`
    /// membership scans — admission checks are O(1) on the ready-pop
    /// hot path.
    admitted: usize,
    next_query: usize,
    /// Serial mode: per query, outstanding completions in current turn.
    turn_pending: Vec<usize>,
    completed_trajs: usize,
}

impl TrajectoryScheduler {
    pub fn new(wl: &StepWorkload, mode: Mode) -> Self {
        let n = wl.trajectories.len();
        let n_queries = wl
            .trajectories
            .iter()
            .map(|t| t.query)
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        let mut members = vec![Vec::new(); n_queries];
        for (i, t) in wl.trajectories.iter().enumerate() {
            members[t.query].push(i);
        }
        TrajectoryScheduler {
            mode,
            n_calls: wl.trajectories.iter().map(|t| t.calls.len()).collect(),
            next_call: vec![0; n],
            query_of: wl.trajectories.iter().map(|t| t.query).collect(),
            members,
            admitted: 0,
            next_query: 0,
            turn_pending: vec![0; n_queries],
            completed_trajs: 0,
        }
    }

    pub fn n_queries(&self) -> usize {
        self.members.len()
    }

    pub fn is_done(&self) -> bool {
        self.completed_trajs == self.n_calls.len()
    }

    pub fn completed_trajectories(&self) -> usize {
        self.completed_trajs
    }

    /// Initial ready calls (admits queries up to the concurrency limit).
    pub fn start(&mut self) -> Vec<CallRef> {
        let mut ready = Vec::new();
        let limit = match self.mode {
            Mode::SerialQueries => 1,
            Mode::Parallel { inter_query } => inter_query.max(1),
        };
        while self.next_query < self.members.len() && self.admitted < limit {
            ready.extend(self.admit_next_query());
        }
        ready
    }

    fn admit_next_query(&mut self) -> Vec<CallRef> {
        let q = self.next_query;
        self.next_query += 1;
        self.admitted += 1;
        let mut out = Vec::new();
        for &t in &self.members[q] {
            if self.n_calls[t] > 0 {
                out.push(CallRef { traj: t, call: 0 });
            } else {
                self.completed_trajs += 1; // degenerate empty chain
            }
        }
        self.turn_pending[q] = out.len();
        out
    }

    /// Mark a call complete; returns the calls that become ready.
    pub fn complete(&mut self, c: CallRef) -> Vec<CallRef> {
        debug_assert_eq!(self.next_call[c.traj], c.call, "out-of-order completion");
        self.next_call[c.traj] = c.call + 1;
        let q = self.query_of[c.traj];
        let traj_done = self.next_call[c.traj] == self.n_calls[c.traj];
        if traj_done {
            self.completed_trajs += 1;
        }

        let mut ready = Vec::new();
        match self.mode {
            Mode::Parallel { inter_query } => {
                if !traj_done {
                    ready.push(CallRef {
                        traj: c.traj,
                        call: c.call + 1,
                    });
                }
                // Query fully done → admit the next one.
                if self.query_done(q) {
                    self.admitted -= 1;
                    let limit = inter_query.max(1);
                    while self.next_query < self.members.len() && self.admitted < limit {
                        ready.extend(self.admit_next_query());
                    }
                }
            }
            Mode::SerialQueries => {
                self.turn_pending[q] -= 1;
                if self.turn_pending[q] == 0 {
                    // Turn barrier reached: issue next turn for all
                    // still-unfinished candidates.
                    let next: Vec<CallRef> = self.members[q]
                        .iter()
                        .filter(|&&t| self.next_call[t] < self.n_calls[t])
                        .map(|&t| CallRef {
                            traj: t,
                            call: self.next_call[t],
                        })
                        .collect();
                    if next.is_empty() {
                        // Query complete → start the next query.
                        self.admitted -= 1;
                        if self.next_query < self.members.len() {
                            ready.extend(self.admit_next_query());
                        }
                    } else {
                        self.turn_pending[q] = next.len();
                        ready.extend(next);
                    }
                }
            }
        }
        ready
    }

    fn query_done(&self, q: usize) -> bool {
        self.members[q]
            .iter()
            .all(|&t| self.next_call[t] == self.n_calls[t])
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: the mutable cursors only. `n_calls`,
    /// `query_of`, and `members` are pure functions of the step's
    /// workload, which the resumed engine regenerates — so restore is
    /// "rebuild from workload, then overlay cursors".
    pub fn snapshot(&self) -> Json {
        let nums = |v: &[usize]| Json::arr(v.iter().map(|&x| Json::num(x as f64)));
        Json::obj(vec![
            ("next_call", nums(&self.next_call)),
            ("admitted", Json::num(self.admitted as f64)),
            ("next_query", Json::num(self.next_query as f64)),
            ("turn_pending", nums(&self.turn_pending)),
            ("completed_trajs", Json::num(self.completed_trajs as f64)),
        ])
    }

    /// Overlay cursors captured by [`TrajectoryScheduler::snapshot`]
    /// onto a scheduler freshly built from the same step workload.
    pub fn restore_from(&mut self, j: &Json) -> Result<(), String> {
        let nums = |j: &Json, what: &str, want: usize| -> Result<Vec<usize>, String> {
            let v = j
                .as_arr()
                .ok_or(format!("scheduler missing '{what}'"))?
                .iter()
                .map(|x| x.as_usize().ok_or(format!("bad '{what}' entry")))
                .collect::<Result<Vec<_>, _>>()?;
            if v.len() != want {
                return Err(format!("'{what}' has {} entries, want {want}", v.len()));
            }
            Ok(v)
        };
        self.next_call = nums(
            j.get("next_call").unwrap_or(&Json::Null),
            "next_call",
            self.n_calls.len(),
        )?;
        self.turn_pending = nums(
            j.get("turn_pending").unwrap_or(&Json::Null),
            "turn_pending",
            self.members.len(),
        )?;
        self.admitted = j
            .get("admitted")
            .and_then(Json::as_usize)
            .ok_or("scheduler missing 'admitted'")?;
        self.next_query = j
            .get("next_query")
            .and_then(Json::as_usize)
            .ok_or("scheduler missing 'next_query'")?;
        self.completed_trajs = j
            .get("completed_trajs")
            .and_then(Json::as_usize)
            .ok_or("scheduler missing 'completed_trajs'")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::Generator;

    fn workload() -> StepWorkload {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 3;
        wl.group_size = 4;
        Generator::new(&wl, 7).step(0)
    }

    fn drain(mut sched: TrajectoryScheduler, wl: &StepWorkload) -> (usize, usize) {
        // Execute everything, tracking max concurrently-ready calls.
        let mut frontier = sched.start();
        let mut max_width = frontier.len();
        let mut total = 0;
        while let Some(c) = frontier.pop() {
            total += 1;
            frontier.extend(sched.complete(c));
            max_width = max_width.max(frontier.len() + 1);
        }
        assert!(sched.is_done());
        assert_eq!(total, wl.total_calls());
        (total, max_width)
    }

    #[test]
    fn parallel_executes_all_calls() {
        let wl = workload();
        let sched = TrajectoryScheduler::new(&wl, Mode::Parallel { inter_query: 4 });
        let (total, width) = drain(sched, &wl);
        assert!(total > 0);
        // With 3 queries × 4 candidates admitted concurrently, width
        // must exceed one query's group.
        assert!(width > 4, "width {width}");
    }

    #[test]
    fn serial_never_overlaps_queries() {
        let wl = workload();
        let mut sched = TrajectoryScheduler::new(&wl, Mode::SerialQueries);
        let mut frontier = sched.start();
        // All initially-ready calls belong to query 0.
        assert!(frontier.iter().all(|c| wl.trajectories[c.traj].query == 0));
        // At every point, ready calls span exactly one query.
        while let Some(c) = frontier.pop() {
            let ready = sched.complete(c);
            let queries: std::collections::BTreeSet<usize> = frontier
                .iter()
                .chain(&ready)
                .map(|c| wl.trajectories[c.traj].query)
                .collect();
            assert!(queries.len() <= 1, "{queries:?}");
            frontier.extend(ready);
        }
        assert!(sched.is_done());
    }

    #[test]
    fn serial_has_turn_barriers() {
        let wl = workload();
        let mut sched = TrajectoryScheduler::new(&wl, Mode::SerialQueries);
        let frontier = sched.start();
        // Complete all but one call of turn 0 — no new calls released.
        let mut released = Vec::new();
        for &c in &frontier[..frontier.len() - 1] {
            released.extend(sched.complete(c));
        }
        assert!(released.is_empty(), "barrier leaked {released:?}");
        // Completing the last one releases the whole next turn.
        let next = sched.complete(*frontier.last().unwrap());
        assert!(!next.is_empty());
        assert!(next.iter().all(|c| c.call == 1));
    }

    #[test]
    fn inter_query_limit_respected() {
        let wl = workload();
        let mut sched = TrajectoryScheduler::new(&wl, Mode::Parallel { inter_query: 2 });
        let frontier = sched.start();
        let queries: std::collections::BTreeSet<usize> = frontier
            .iter()
            .map(|c| wl.trajectories[c.traj].query)
            .collect();
        assert_eq!(queries.len(), 2); // only 2 of 3 admitted
    }

    #[test]
    fn parallel_chains_stay_ordered() {
        let wl = workload();
        let mut sched = TrajectoryScheduler::new(&wl, Mode::Parallel { inter_query: 8 });
        let mut frontier = sched.start();
        let mut seen_call = vec![0usize; wl.trajectories.len()];
        while let Some(c) = frontier.pop() {
            assert_eq!(c.call, seen_call[c.traj], "dependency violated");
            seen_call[c.traj] += 1;
            frontier.extend(sched.complete(c));
        }
    }
}
