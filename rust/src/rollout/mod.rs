//! Rollout engine (§5): parallel sampling + hierarchical load balancing.
//!
//! * [`parallel`] — dependency-driven trajectory scheduling (inter-query
//!   and intra-query parallelism vs the serial baseline model);
//! * [`manager`] — intra-agent min-heap least-loaded dispatch over
//!   inference instances, with fault tolerance;
//! * [`scaler`] — inter-agent elastic instance migration on queue-length
//!   disparity > Δ, weights moved via the Set/Get store;
//! * [`heap`] — the indexed min-heap substrate the manager uses.

pub mod heap;
pub mod manager;
pub mod parallel;
pub mod scaler;

pub use manager::{AgentId, Dispatch, InstanceId, RequestId, RolloutManager};
pub use parallel::{CallRef, Mode, TrajectoryScheduler};
pub use scaler::{migration_latency, plan_migration, MigrationPlan};
