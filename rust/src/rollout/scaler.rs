//! Inter-agent load balancing (§5.2, Fig. 5): the rollout manager polls
//! per-agent queue lengths; when the disparity between the most- and
//! least-loaded agents exceeds the threshold Δ, inference capacity
//! migrates from the underutilized agent to the overloaded one.
//!
//! Conservative policy (paper): the migrated instance count follows the
//! queue-length difference, but every agent retains ≥ 1 active instance
//! (liveness), and migrations to/from an agent already mid-scaling are
//! suppressed to prevent oscillation.

use crate::config::ModelScale;
use crate::memstore::{Location, TransferModel};

#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPlan {
    pub donor: usize,
    pub target: usize,
    pub n_instances: usize,
    /// Queue disparity that triggered the op (for logs/metrics).
    pub disparity: usize,
}

/// Decide whether to scale, given polled queue lengths and current
/// instance counts. Pure function — trivially testable.
pub fn plan_migration(
    queue_lens: &[usize],
    instance_counts: &[usize],
    delta_threshold: usize,
    busy_agents: &[bool],
) -> Option<MigrationPlan> {
    assert_eq!(queue_lens.len(), instance_counts.len());
    let n = queue_lens.len();
    if n < 2 {
        return None;
    }
    // Most-loaded agent not already scaling.
    let target = (0..n)
        .filter(|&a| !busy_agents[a] && instance_counts[a] > 0)
        .max_by_key(|&a| (queue_lens[a], a))?;
    // Least-loaded agent that can donate (> 1 instance).
    let donor = (0..n)
        .filter(|&a| a != target && !busy_agents[a] && instance_counts[a] > 1)
        .min_by_key(|&a| (queue_lens[a], a))?;
    let disparity = queue_lens[target].saturating_sub(queue_lens[donor]);
    if disparity <= delta_threshold {
        return None;
    }
    // Paper: migrate in proportion to the queue-length difference, but
    // conservatively: never below one instance on the donor, and at most
    // half the donor's pool per scaling op — "the conservative policy
    // prevents transient load oscillation" (§5.2). Donors are upstream /
    // downstream agents of the same workflow chains, so stripping them
    // bare just moves the bottleneck.
    let want = (disparity / delta_threshold.max(1)).max(1);
    // Donor has ≥ 2 instances (filter above), so the cap is ≥ 1 = floor.
    let cap = (instance_counts[donor] - 1).min((instance_counts[donor] / 2).max(1));
    let n_instances = want.clamp(1, cap);
    Some(MigrationPlan {
        donor,
        target,
        n_instances,
        disparity,
    })
}

/// Latency of one instance migration: the target agent's weights are
/// published via `Set` and pulled by the re-assigned devices via `Get`
/// (D2D), plus engine re-init on the instance.
pub fn migration_latency(
    model: ModelScale,
    transfer: &TransferModel,
    src_device: usize,
    dst_device: usize,
    reinit_s: f64,
) -> f64 {
    // Weights move as ONE contiguous buffer (§9 lesson) per TP shard;
    // shards transfer in parallel across the instance's devices, so one
    // shard's latency bounds the op.
    let shard_bytes = model.weight_bytes() / model.instance_devices() as f64;
    let plan = transfer.plan(
        Location::Device(src_device),
        Location::Device(dst_device),
        shard_bytes,
    );
    plan.seconds + reinit_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn no_migration_below_threshold() {
        let q = [3, 1, 2];
        let inst = [2, 2, 2];
        assert_eq!(plan_migration(&q, &inst, 5, &[false; 3]), None);
    }

    #[test]
    fn migrates_from_idle_to_overloaded() {
        let q = [30, 0, 4];
        let inst = [2, 3, 2];
        let p = plan_migration(&q, &inst, 5, &[false; 3]).unwrap();
        assert_eq!(p.target, 0);
        assert_eq!(p.donor, 1);
        assert!(p.n_instances >= 1);
        // Donor keeps ≥ 1.
        assert!(p.n_instances < inst[p.donor]);
    }

    #[test]
    fn liveness_donor_must_keep_one() {
        let q = [30, 0];
        let inst = [1, 1];
        // Only possible donor has a single instance → no migration.
        assert_eq!(plan_migration(&q, &inst, 5, &[false; 2]), None);
    }

    #[test]
    fn busy_agents_skipped() {
        let q = [30, 0, 1];
        let inst = [2, 4, 4];
        let p = plan_migration(&q, &inst, 5, &[false, true, false]).unwrap();
        assert_eq!(p.donor, 2); // agent 1 is mid-scaling
        let none = plan_migration(&q, &inst, 5, &[true, false, false]);
        // target busy → next-highest queue is agent 2 (len 1) vs donor 1 (0):
        // disparity 1 ≤ Δ → no op.
        assert_eq!(none, None);
    }

    #[test]
    fn migration_magnitude_scales_with_disparity() {
        let inst = [8, 8];
        let small = plan_migration(&[8, 0], &inst, 5, &[false; 2]).unwrap();
        let large = plan_migration(&[40, 0], &inst, 5, &[false; 2]).unwrap();
        assert!(large.n_instances >= small.n_instances);
        // Anti-oscillation cap: at most half the donor pool.
        assert!(large.n_instances <= 4);
    }

    #[test]
    fn empty_pool_no_migration() {
        // Zero agents: nothing to balance, and no index panics.
        assert_eq!(plan_migration(&[], &[], 5, &[]), None);
        assert_eq!(plan_migration(&[], &[], 0, &[]), None);
    }

    #[test]
    fn single_agent_no_migration() {
        // One agent can be arbitrarily overloaded — there is no peer to
        // donate, whatever Δ is.
        assert_eq!(plan_migration(&[100], &[4], 0, &[false]), None);
    }

    #[test]
    fn single_instance_agents_cannot_donate() {
        // Every candidate donor is at the liveness floor (1 instance).
        assert_eq!(plan_migration(&[40, 0, 0], &[2, 1, 1], 5, &[false; 3]), None);
    }

    #[test]
    fn already_balanced_no_migration() {
        // Equal queues: disparity 0 never exceeds any Δ ≥ 0.
        let q = [7, 7, 7];
        let inst = [2, 2, 2];
        assert_eq!(plan_migration(&q, &inst, 0, &[false; 3]), None);
        assert_eq!(plan_migration(&q, &inst, 5, &[false; 3]), None);
    }

    #[test]
    fn zero_instance_agent_never_targeted() {
        // An agent with no instances (mid-teardown) must not be picked
        // as the migration target even with the longest queue.
        let p = plan_migration(&[9, 0, 4], &[0, 4, 2], 1, &[false; 3]);
        if let Some(plan) = p {
            assert_ne!(plan.target, 0);
        }
    }

    #[test]
    fn all_peers_busy_no_migration() {
        // Target found, but every possible donor is mid-scaling.
        assert_eq!(
            plan_migration(&[40, 0, 0], &[2, 4, 4], 5, &[false, true, true]),
            None
        );
    }

    #[test]
    fn migration_latency_reasonable() {
        // 14B bf16 = 28 GB over 4 shards = 7 GB per shard; HCCS 160 GB/s
        // → ~44 ms + reinit. Cross-node RDMA slower but < 1 s.
        let t = TransferModel::new(ClusterConfig::default());
        let intra = migration_latency(ModelScale::B14, &t, 0, 1, 0.5);
        let cross = migration_latency(ModelScale::B14, &t, 0, 16, 0.5);
        assert!(intra > 0.5 && intra < 1.0, "{intra}");
        assert!(cross > intra && cross < 3.0, "{cross}");
    }
}
