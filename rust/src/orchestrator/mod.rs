//! Joint orchestrator (§4): rollout-training disaggregation, the
//! experience store ([`crate::store`]), and the micro-batch asynchronous
//! pipeline that decouples gradient computation from parameter updates
//! while preserving synchronous on-policy semantics.
//!
//! [`simloop`] drives the coordinator components under virtual time for
//! the paper-scale experiments; the real PJRT-backed loop lives in
//! [`crate::runtime::marl`] and `examples/marl_train.rs` — both share
//! the same store / manager / scaler / allocator code paths.

pub mod simloop;

#[allow(deprecated)] // re-exported for back-compat until the panicking wrapper is removed
pub use simloop::simulate;
pub use simloop::{resolve_workload, try_simulate, SimOptions, SimOutcome};
