//! Joint orchestrator (§4): rollout-training disaggregation, the
//! experience store ([`crate::store`]), and the micro-batch asynchronous
//! pipeline that decouples gradient computation from parameter updates
//! while preserving synchronous on-policy semantics.
//!
//! [`simloop`] drives the coordinator components under virtual time for
//! the paper-scale experiments; the real PJRT-backed loop lives in
//! [`crate::runtime::marl`] and `examples/marl_train.rs` — both share
//! the same store / manager / scaler / allocator code paths.
//!
//! Execution is streaming-first (DESIGN.md §9): a [`Session`] advances
//! the engine one MARL step at a time, typed [`EngineEvent`]s flow to
//! attached [`EventSink`]s, and a sink can stop the run early with a
//! well-formed partial [`SimOutcome`]. The run-to-completion entries
//! ([`try_simulate`], [`crate::experiment::Experiment::run`]) are thin
//! drains over a session.
//!
//! Workload input is streaming too (DESIGN.md §11): the engine pulls
//! one step at a time from a [`crate::workload::WorkloadSource`] —
//! [`resolve_workload_source`] is the lazy counterpart of
//! [`resolve_workload`] — and retires each step's control block as its
//! report finalizes, so peak memory is O(live steps) regardless of run
//! length. Lazy and eager runs are byte-identical.

pub mod events;
pub mod session;
pub mod simloop;

pub use events::{
    BudgetSink, CaptureBuffer, ControlFlow, EngineEvent, EventSink, JsonlSink, NullSink,
    ProgressSink, TraceHandle, TraceSink, WallClockSink,
};
pub use session::Session;
#[allow(deprecated)] // re-exported for back-compat until the panicking wrapper is removed
pub use simloop::simulate;
pub use simloop::{
    resolve_workload, resolve_workload_source, try_simulate, SimOptions, SimOutcome, StopInfo,
};
