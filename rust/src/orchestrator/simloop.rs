//! The MARL step engine: drives the *real* coordinator components
//! (experience store, rollout manager/scheduler/scaler, process groups,
//! allocators, swap and transfer models) under virtual time to reproduce
//! the paper's cluster-scale experiments (§8) for every framework
//! variant of Table 1/§8.1.
//!
//! Framework behaviour comes from a [`PolicyBundle`]
//! ([`crate::policy`], DESIGN.md §8): every decision the engine used to
//! read off `config::Framework` capability booleans is a call into one
//! of the four policy objects — [`crate::policy::PipelinePolicy`]
//! (micro-batch admission, step overlap),
//! [`crate::policy::BalancePolicy`] (poll-tick migration),
//! [`crate::policy::AllocPolicy`] (pool layout, binding, colocation
//! contention), [`crate::policy::SamplePolicy`] (scheduling mode,
//! instance provisioning). The canonical bundles reproduce the Table 1
//! baselines:
//!  * MAS-RL    — colocated pool, serial query processing, full-batch
//!                sync training, onload/offload phase switches;
//!  * DistRL    — disaggregated pools, parallel sampling, sync training,
//!                static training partitions;
//!  * MARTI     — colocated, parallel sampling, one-step-async rollouts
//!                (step s+1 generates with stale params while step s
//!                trains), static partitions;
//!  * FlexMARL  — disaggregated, parallel sampling, hierarchical load
//!                balancing, micro-batch async pipeline, agent-centric
//!                allocation with state swap.
//!
//! New frameworks plug in as bundles through
//! [`crate::experiment::Experiment`] — this file needs no edits.
//!
//! The run loop is cut at the MARL-step boundary: the crate-internal
//! engine advances events until the next step completes and yields its
//! finalized [`StepReport`] (every report input freezes at step
//! completion — DESIGN.md §9). [`super::session::Session`] exposes that
//! incrementally; the run-to-completion entries drain it, so streamed
//! and monolithic runs are bit-identical by construction. Typed
//! [`super::events::EngineEvent`]s fan out to attached sinks at every
//! named decision point.

use super::events::{EngineEvent, SinkSet};
use crate::ckpt::{as_ju64, ju64};
use crate::cluster::DevicePool;
use crate::config::ExperimentConfig;
use crate::error::PallasError;
use crate::fault::{FaultKind, FaultSpec};
use crate::memstore::TransferModel;
use crate::metrics::{Counters, MetricId, RunSeries, StepReport};
use crate::policy::{LoadSnapshot, PolicyBundle, RecoveryAction};
use crate::rollout::{CallRef, Dispatch, Mode, RequestId, RolloutManager, TrajectoryScheduler};
use crate::sim::{EventQueue, QueueKind};
use crate::util::json::Json;
use crate::store::{ColumnType, ExperienceStore, Field, PutRow, SampleId, Value};
use crate::training::{
    apply_update_s, grad_compute_s, swap_in_cost, swap_out_cost, AgentCentricAllocator,
};
use crate::workload::{
    scenario, LenHint, ScenarioSource, StepWorkload, Trace, TraceReader, TraceSource,
    WorkloadSource,
};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Engine knobs not fixed by the paper (documented in DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Initial inference instances per agent (uniform — the static
    /// baseline allocation FlexMARL's scaler then reshapes).
    pub instances_per_agent: usize,
    /// Continuous-batching slots per instance.
    pub concurrency: usize,
    /// Rollout-manager poll period for load metrics / scaling (§5.2).
    pub scaler_poll_s: f64,
    /// Inference-engine re-init after a weight migration.
    pub reinit_s: f64,
    /// Colocated phase-switch cost, each direction (onload/offload).
    pub switch_s: f64,
    /// Extra context tokens per training sample (prompt + history).
    pub context_tokens: f64,
    /// Post-update weight broadcast to inference instances.
    pub sync_s: f64,
    /// Agents whose queue/processed series are recorded (Figs. 1b/8/9).
    pub track_agents: Vec<usize>,
    /// Event-queue backend. `Calendar` is the O(1) bucketed queue tuned
    /// for the simloop's dense near-future events; `BinaryHeap` is the
    /// reference fallback. Both produce bit-identical simulations.
    pub event_queue: QueueKind,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            instances_per_agent: 2,
            concurrency: 4,
            scaler_poll_s: 2.0,
            reinit_s: 1.0,
            switch_s: 14.0,
            context_tokens: 256.0,
            sync_s: 1.5,
            track_agents: vec![],
            event_queue: QueueKind::Calendar,
        }
    }
}

#[derive(Debug, Clone)]
enum Ev {
    StartStep(usize),
    CallDone(RequestId),
    Poll,
    /// Weight transfer for a migration arrived; instances can re-register
    /// once drained.
    MigrationArrive {
        donor_insts: Vec<usize>,
        target: usize,
    },
    SwitchToTrainDone(usize),
    SwitchToRolloutDone(usize),
    SwapInDone { agent: usize, step: usize },
    GradDone { agent: usize, step: usize, n: usize },
    ApplyDone { agent: usize, step: usize },
    SwapOutDone { agent: usize },
    /// Fault `fault_plan[i]` strikes (DESIGN.md §10). Plan events are
    /// queued at construction, so fault ordering follows the queue's
    /// `(time, seq)` rule like every other event — bit-identical for
    /// any `--jobs N`.
    FaultStrike(usize),
    /// Backoff expired for `retry_parked[i]`: re-dispatch it.
    RetryDue(usize),
    /// Degrade recovery: re-provision a replacement instance.
    Recover { agent: usize },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum AgentTrain {
    Idle,
    SwappingIn,
    Computing,
    Applying,
    SwappingOut,
}

/// `Clone` so fault recovery can re-dispatch a displaced request as a
/// fresh slab entry (the dead entry is tombstoned until its stale
/// completion event drains). `decode_s` is *not* re-priced on retry:
/// the re-dispatch costs what the original dispatch cost, keeping
/// faulted runs deterministic.
#[derive(Clone)]
struct ReqInfo {
    step: usize,
    call: CallRef,
    /// Pure decode seconds (device-busy part).
    decode_s: f64,
    /// Env/tool seconds appended after decode.
    env_s: f64,
    agent: usize,
    /// Times this logical call was re-dispatched after an instance
    /// loss (fault plane; 0 on first dispatch).
    attempt: u32,
}

/// Slab of in-flight request metadata: `RequestId`s are slot indices
/// and freed slots recycle through a free-list, so steady-state
/// stepping allocates nothing per request.
#[derive(Default)]
struct ReqSlab {
    slots: Vec<Option<ReqInfo>>,
    free: Vec<u32>,
}

impl ReqSlab {
    fn alloc(&mut self, info: ReqInfo) -> RequestId {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Some(info);
                i as RequestId
            }
            None => {
                self.slots.push(Some(info));
                (self.slots.len() - 1) as RequestId
            }
        }
    }

    fn get(&self, rid: RequestId) -> &ReqInfo {
        self.slots[rid as usize].as_ref().expect("unknown request")
    }

    fn remove(&mut self, rid: RequestId) -> ReqInfo {
        let info = self.slots[rid as usize].take().expect("unknown request");
        self.free.push(rid as u32);
        info
    }
}

struct StepCtl {
    workload: StepWorkload,
    sched: TrajectoryScheduler,
    started: bool,
    rollout_done: bool,
    start_t: f64,
    rollout_end_t: f64,
    end_t: f64,
    /// Samples each agent must grad-process this step.
    expected: Vec<usize>,
    grads_done: Vec<usize>,
    applied: Vec<bool>,
    traj_remaining: usize,
    traj_start: Vec<f64>,
    traj_end: Vec<f64>,
    /// (query, turn) → (outstanding candidates, completed-call tokens).
    /// GRPO groups become ready together: advantages need the whole
    /// group's rewards, so samples enter the store at group completion.
    group_pending: BTreeMap<(usize, usize), (usize, Vec<f64>)>,
    /// Device-busy seconds charged to this step (rollout + training).
    busy_s: f64,
    /// Phase-switch seconds charged to this step.
    switch_s_total: f64,
}

/// Where and why a run was cut short by an
/// [`EventSink`](super::events::EventSink) requesting
/// [`ControlFlow::Stop`](super::events::ControlFlow::Stop).
#[derive(Debug, Clone, PartialEq)]
pub struct StopInfo {
    /// Virtual time at which the stop took effect (the last handled
    /// event's timestamp).
    pub t: f64,
    /// MARL steps that fully completed — and therefore have reports —
    /// before the stop.
    pub steps_completed: usize,
}

/// Outcome of a simulation — complete, or partial when a sink stopped
/// it early (`stop` is `Some` and `reports` covers only the completed
/// steps; every completed step's report is bit-identical to the full
/// run's).
pub struct SimOutcome {
    pub reports: Vec<StepReport>,
    /// Overall wall time of the simulated run (virtual seconds; on an
    /// early stop, the time the run was cut).
    pub total_s: f64,
    /// Run-wide poll-sampled time series (Figs. 1b/8/9/10) — these span
    /// step boundaries, so they live here rather than on any one
    /// [`StepReport`].
    pub series: RunSeries,
    /// `Some` when a sink requested an early stop.
    pub stop: Option<StopInfo>,
}

impl SimOutcome {
    /// Aggregate per-step reports into the per-sample averages the
    /// paper tables quote ([`crate::metrics::aggregate`]); for
    /// step-overlapping pipelines pass `overlaps = true` so E2E is
    /// amortized over the run — `other_s` is then recomputed against
    /// the amortized figure so the breakdown stays coherent
    /// (`e2e ≈ rollout + train + other`; per-step reports carry actual
    /// spans, DESIGN.md §9). `None` when no step completed (an early
    /// stop before the first step boundary).
    pub fn evaluate(&self, overlaps: bool) -> Option<StepReport> {
        if self.reports.is_empty() {
            return None;
        }
        let mut rep = crate::metrics::aggregate(&self.reports);
        if overlaps {
            rep.e2e_s = self.total_s / self.reports.len() as f64;
            rep.other_s = (rep.e2e_s - rep.rollout_s - rep.train_s).max(0.0);
        }
        Some(rep)
    }
}

/// Run the discrete-event simulation.
///
/// # Panics
///
/// Panics if the config's scenario name is unknown or its trace path
/// is unreadable/invalid — callers that need a clean error (the CLI
/// does) use [`try_simulate`], which resolves exactly once — and on a
/// tripped run-loop event budget (with the budget error's `Display`
/// text, which keeps the old panic's message prefix).
#[deprecated(
    since = "0.3.0",
    note = "panics on workload-resolution failure; use `try_simulate` or \
            `experiment::Experiment::new(cfg).build()?.run()`"
)]
pub fn simulate(cfg: &ExperimentConfig, opts: &SimOptions) -> SimOutcome {
    try_simulate(cfg, opts).unwrap_or_else(|e| match e {
        PallasError::EventBudget { .. } => panic!("{e}"),
        e => panic!("workload resolution failed: {e}"),
    })
}

/// [`simulate`], but failures surface as [`PallasError`] instead of a
/// panic: workload resolution (unknown scenario, unreadable/corrupt/
/// mismatched trace) and the run loop's livelock guard
/// ([`PallasError::EventBudget`]).
///
/// Honors `cfg.workload_mode`: `eager` materializes every step up
/// front ([`resolve_workload`]); `lazy` streams steps through a
/// [`WorkloadSource`] ([`resolve_workload_source`]). The two are
/// byte-identical end to end — this is a routing choice, not a
/// semantic one.
pub fn try_simulate(cfg: &ExperimentConfig, opts: &SimOptions) -> Result<SimOutcome, PallasError> {
    crate::experiment::Experiment::new(cfg.clone()).options(opts.clone()).build()?.try_run()
}

/// Resolve the config's scenario/trace into concrete per-step
/// workloads: the scenario preset shapes the config, then either the
/// generator produces `cfg.steps` workloads or — when
/// `workload.trace` is set — a recorded trace is replayed verbatim
/// (and `steps` follows the trace).
///
/// A trace is authoritative about what it recorded: its header's
/// scenario overrides `workload.scenario`, so the config is shaped
/// exactly as at record time (e.g. a `hetero_scale` trace replays
/// against the mixed 7B/14B/32B ensemble, whatever the caller's
/// scenario field says). Everything downstream of the returned pair is
/// deterministic, so a replayed trace reproduces a generated run's
/// metrics bit-for-bit.
pub fn resolve_workload(
    cfg: &ExperimentConfig,
) -> Result<(ExperimentConfig, Vec<StepWorkload>), PallasError> {
    let mut base = cfg.workload.clone();
    // "-" reads the trace from stdin (the CLI's piped-feed convention).
    let trace = match &base.trace {
        Some(path) => Some((path.clone(), Trace::read_path(path)?)),
        None => None,
    };
    if let Some((_, tr)) = &trace {
        base.scenario = tr.scenario.clone();
    }
    let (shaped, scen) = scenario::resolve(&base)?;
    let mut resolved = cfg.clone();
    resolved.workload = shaped;
    let step_workloads = if let Some((path, tr)) = trace {
        if tr.n_agents != resolved.workload.agents.len() {
            return Err(PallasError::TraceAgentMismatch {
                path,
                trace_agents: tr.n_agents,
                config_agents: resolved.workload.agents.len(),
            });
        }
        resolved.steps = tr.steps.len();
        tr.steps
    } else {
        (0..resolved.steps)
            .map(|s| scen.step(&resolved.workload, resolved.seed, s))
            .collect()
    };
    Ok((resolved, step_workloads))
}

/// [`resolve_workload`], but lazy (DESIGN.md §11): the same shaping and
/// validation, returning a streaming [`WorkloadSource`] instead of a
/// materialized `Vec`. Scenario steps generate on demand; traces stream
/// through a [`TraceReader`] — the header is read and validated here,
/// step lines parse one at a time as the engine pulls them. Peak
/// memory becomes O(one step), not O(steps).
///
/// The source yields exactly the sequence [`resolve_workload`] would
/// materialize, so lazy and eager runs are byte-identical end to end
/// (the lazy-equivalence contract, enforced in CI). The one observable
/// difference is *when* a corrupt trace fails: eager resolution rejects
/// the file up front, while the streaming reader surfaces the same
/// typed [`PallasError`] mid-run, at the first bad step line.
pub fn resolve_workload_source(
    cfg: &ExperimentConfig,
) -> Result<(ExperimentConfig, Box<dyn WorkloadSource>), PallasError> {
    let mut base = cfg.workload.clone();
    let trace_path = base.trace.clone();
    if let Some(path) = trace_path {
        // "-" streams step lines from stdin as they arrive: the lazy
        // plane driven by a live feed (a blocking pipe paces the run).
        let reader = TraceReader::open_path(&path)?;
        // The trace is authoritative about what it recorded (see
        // `resolve_workload`): shape from its header's scenario.
        base.scenario = reader.scenario().to_string();
        let (shaped, _scen) = scenario::resolve(&base)?;
        let mut resolved = cfg.clone();
        resolved.workload = shaped;
        if reader.n_agents() != resolved.workload.agents.len() {
            return Err(PallasError::TraceAgentMismatch {
                path,
                trace_agents: reader.n_agents(),
                config_agents: resolved.workload.agents.len(),
            });
        }
        resolved.steps = reader.steps();
        Ok((resolved, Box::new(TraceSource::new(reader))))
    } else {
        let (shaped, scen) = scenario::resolve(&base)?;
        let mut resolved = cfg.clone();
        resolved.workload = shaped;
        let src =
            ScenarioSource::new(resolved.workload.clone(), scen, resolved.seed, resolved.steps);
        Ok((resolved, Box::new(src)))
    }
}

/// The step engine. Owns its resolved inputs (so a
/// [`Session`](super::session::Session) can hold it across calls) and
/// advances through [`Engine::pump_step`] — the run-to-completion
/// entries ([`try_simulate`], [`crate::experiment::Experiment::run`])
/// are thin drains over it.
pub(crate) struct Engine {
    cfg: ExperimentConfig,
    opts: SimOptions,
    /// Framework behaviour — every former capability-flag branch is a
    /// call into one of these four policy objects.
    policies: PolicyBundle,
    /// Observers ([`super::events`]); empty on the no-sink fast path.
    sinks: SinkSet,
    q: EventQueue<Ev>,
    man: RolloutManager,
    store: ExperienceStore,
    transfer: TransferModel,
    /// The *live window* of step control blocks (DESIGN.md §11):
    /// `steps[i]` is MARL step `window_base + i`. Steps materialize
    /// from `source` when their `StartStep` fires and retire as their
    /// report is finalized, so the window holds only in-flight steps —
    /// peak memory is O(overlap depth), independent of `total_steps`.
    steps: VecDeque<StepCtl>,
    /// Index of the first step still in the window (== `next_report`;
    /// both advance in lockstep in `collect_completed`).
    window_base: usize,
    /// Run length (`cfg.steps`); the former `steps.len()`.
    total_steps: usize,
    /// Pull-based workload feed; `ensure_step` draws from it.
    source: Box<dyn WorkloadSource>,
    /// Scheduling mode (from the sample policy), applied to each step's
    /// trajectory scheduler as it materializes.
    sched_mode: Mode,
    reqs: ReqSlab,
    /// Training state machine per agent.
    tstate: Vec<AgentTrain>,
    alloc: AgentCentricAllocator,
    /// Static mode: placements held forever (None entries if agent idle).
    static_mode: bool,
    agent_busy_scaling: Vec<bool>,
    /// Devices per agent instance (cache).
    inst_dev: Vec<usize>,
    /// instance id → agent it belongs to now.
    inst_agent: BTreeMap<usize, usize>,
    pool_devices: usize,
    sample_seq: u64,
    // metrics — allocation-free on the event path (DESIGN.md §4):
    // store table keys are rendered once at construction, scalar
    // counters are interned ids into `counters`, and per-step series
    // are step-indexed Vecs.
    /// Per-agent store table keys, rendered once (never per event).
    agent_keys: Vec<String>,
    /// Interned scalar counters; frozen before the event loop starts.
    counters: Counters,
    m_scale_ops: MetricId,
    m_swap_s: MetricId,
    processed_series: BTreeMap<usize, Vec<(f64, usize)>>,
    queued_series: BTreeMap<usize, Vec<(f64, usize)>>,
    busy_series: Vec<(f64, usize)>,
    // ---- run-loop state (was locals of the retired monolithic run) --
    /// Event-budget guard (livelock detector), cumulative over the run.
    guard: u64,
    /// Budget the guard trips at: scaled to the run length so long
    /// streamed runs don't hit the old fixed 1M-event ceiling.
    event_budget: u64,
    /// Event histogram by discriminant index — names are only attached
    /// if the budget error fires.
    histo: [u64; EV_KINDS],
    /// Timestamp of the last handled event (== total wall time once the
    /// run completes).
    now: f64,
    /// Every step completed and reported.
    done: bool,
    /// The event budget tripped; the engine is poisoned (steps return
    /// `None` after the error was yielded once).
    failed: bool,
    /// A sink requested an early stop.
    stop: Option<StopInfo>,
    /// First step index not yet finalized into a report.
    next_report: usize,
    /// Finalized reports not yet handed to the caller (normally ≤ 1;
    /// degenerate workloads can complete several steps on one event).
    pending: VecDeque<StepReport>,
    /// Counter snapshots at the last finalized step — per-step reports
    /// carry deltas, so they are complete the moment the step is.
    prev_scale_ops: f64,
    prev_swap_s: f64,
    // ---- fault plane (DESIGN.md §10) --------------------------------
    /// Resolved fault plan, indexed by `Ev::FaultStrike`. Empty on
    /// fault-free runs (no events queued, no per-event overhead).
    fault_plan: Vec<FaultSpec>,
    /// Requests whose instance died with the completion event already
    /// in flight: the stale `CallDone` is swallowed when it lands (the
    /// slab slot stays allocated until then, so ids cannot collide).
    dead_reqs: BTreeSet<RequestId>,
    /// Displaced requests waiting out a retry backoff, indexed by
    /// `Ev::RetryDue`.
    retry_parked: Vec<Option<ReqInfo>>,
    /// Straggler windows: calls submitted to `agent` before
    /// `slow_until[agent]` decode `slow_mult[agent]`× slower.
    slow_until: Vec<f64>,
    slow_mult: Vec<f64>,
    /// Swap-link flap window: swaps started before `flap_until` pay
    /// `flap_added_s` extra (zero-cost guard when no flap: `t < 0.0`).
    flap_until: f64,
    flap_added_s: f64,
    /// Fail-fast recovery latched an abort; surfaced (once) by
    /// `pump_step` after the current event finishes handling, exactly
    /// like the event-budget guard.
    pending_error: Option<PallasError>,
    m_retries: MetricId,
    m_lost_tokens: MetricId,
    m_recovery_s: MetricId,
    m_degraded_s: MetricId,
    prev_retries: f64,
    prev_lost_tokens: f64,
    prev_recovery_s: f64,
    prev_degraded_s: f64,
}

impl Engine {
    pub(crate) fn new(
        cfg: ExperimentConfig,
        opts: SimOptions,
        source: Box<dyn WorkloadSource>,
        mut policies: PolicyBundle,
        sinks: SinkSet,
    ) -> Self {
        let n_agents = cfg.workload.agents.len();
        // Config-level recovery override (`faults.recovery`): applied
        // here so every entry point — CLI, Experiment builder, exec
        // sweeps — honours it identically. Names are validated by
        // `ExperimentConfig::validate`; a hand-built config with a bad
        // name fails loudly.
        if let Some(name) = &cfg.faults.recovery {
            policies.recovery = crate::policy::recovery_by_name(name)
                .unwrap_or_else(|| panic!("unknown recovery policy '{name}'"));
        }
        // The fault plan resolves purely from (config, seed) before the
        // event loop exists — nothing about fault timing can observe
        // engine state (the determinism contract, DESIGN.md §10).
        let fault_plan = cfg.faults.resolve(cfg.seed, n_agents);
        // The source must cover exactly the configured run. Only an
        // exact hint is checkable up front (every in-repo source is
        // Exact); an `AtLeast` feed that runs dry mid-run fails at the
        // pull site in `ensure_step` instead.
        if let LenHint::Exact(n) = source.len_hint() {
            assert_eq!(n, cfg.steps, "engine needs one workload per step");
        }
        let mode = policies.sample.mode(cfg.workload.inter_query);
        // Livelock guard budget: ~100k events per step is ~35× the MA
        // default's actual event count; the 1M floor preserves the
        // historical fixed budget for short runs.
        let event_budget = 1_000_000u64.max((cfg.steps as u64).saturating_mul(100_000));

        // ---- pools -------------------------------------------------------
        let inst_dev: Vec<usize> = cfg
            .workload
            .agents
            .iter()
            .map(|a| a.model.instance_devices())
            .collect();
        // MAS-RL's serial policy pins one engine per agent; parallel
        // policies deploy the uniform static pool the scaler reshapes.
        let static_instances = policies.sample.instances_per_agent(opts.instances_per_agent);
        let rollout_devices: usize = inst_dev.iter().map(|d| d * static_instances).sum();
        let train_devices: usize = cfg
            .workload
            .agents
            .iter()
            .map(|a| a.model.train_group_devices())
            .sum();
        let dpn = cfg.cluster.devices_per_node;
        let rollout_nodes = rollout_devices.div_ceil(dpn).max(1);
        let train_nodes = train_devices.div_ceil(dpn).max(1);
        // Pool accounting (utilization denominator): disaggregated runs
        // provision both pools; a colocated one-step-async system (MARTI)
        // must also hold inference instances and training groups alive
        // simultaneously; only strict alternation (MAS-RL) can truly
        // time-multiplex one pool.
        let overlap = policies.alloc.dedicated_pools() || policies.pipeline.overlaps_steps();
        let pool_devices = if overlap {
            (rollout_nodes + train_nodes) * dpn
        } else {
            rollout_nodes.max(train_nodes) * dpn
        };
        let train_pool = DevicePool::new(
            cfg.cluster,
            0,
            train_nodes.min(cfg.cluster.nodes),
        );
        let models: Vec<_> = cfg.workload.agents.iter().map(|a| a.model).collect();
        let alloc = AgentCentricAllocator::new(train_pool, &models, &cfg.cluster);

        // MAS-RL is the naive single-agent-RL port: one inference engine
        // per agent (no replication); the others deploy a uniform static
        // pool that FlexMARL's scaler then reshapes.
        let mut man = RolloutManager::new(n_agents);
        for a in 0..n_agents {
            for _ in 0..static_instances {
                man.add_instance(a, opts.concurrency);
            }
        }
        let mut inst_agent = BTreeMap::new();
        for a in 0..n_agents {
            for iid in man.instances_of(a) {
                inst_agent.insert(iid, a);
            }
        }

        // Intern agent table keys and metric counter keys now: the
        // event loop records by index/id only (no per-event `format!`
        // or `to_string` — the debug-asserted freeze below enforces it
        // for counters).
        let agent_keys: Vec<String> = (0..n_agents).map(|a| format!("agent{a}")).collect();
        let store = ExperienceStore::new();
        for key in &agent_keys {
            store.create_table(
                key,
                &[("tokens", ColumnType::Float), ("reward", ColumnType::Float)],
            );
        }
        let mut counters = Counters::new();
        let m_scale_ops = counters.register("scale_ops");
        let m_swap_s = counters.register("swap_s");
        let m_retries = counters.register("retries");
        let m_lost_tokens = counters.register("lost_tokens");
        let m_recovery_s = counters.register("recovery_s");
        let m_degraded_s = counters.register("degraded_s");

        // Recording phase begins: no counter key may be constructed
        // past this point (debug-asserted by the interner).
        counters.freeze();
        let mut engine = Engine {
            q: EventQueue::with_kind(opts.event_queue),
            man,
            store,
            transfer: TransferModel::new(cfg.cluster),
            steps: VecDeque::new(),
            window_base: 0,
            total_steps: cfg.steps,
            source,
            sched_mode: mode,
            reqs: ReqSlab::default(),
            tstate: vec![AgentTrain::Idle; n_agents],
            alloc,
            static_mode: !policies.alloc.on_demand_binding(),
            agent_busy_scaling: vec![false; n_agents],
            inst_dev,
            inst_agent,
            pool_devices,
            sample_seq: 0,
            agent_keys,
            counters,
            m_scale_ops,
            m_swap_s,
            processed_series: opts.track_agents.iter().map(|&a| (a, vec![])).collect(),
            queued_series: opts.track_agents.iter().map(|&a| (a, vec![])).collect(),
            busy_series: Vec::new(),
            guard: 0,
            event_budget,
            histo: [0u64; EV_KINDS],
            now: 0.0,
            done: false,
            failed: false,
            stop: None,
            next_report: 0,
            pending: VecDeque::new(),
            prev_scale_ops: 0.0,
            prev_swap_s: 0.0,
            fault_plan,
            dead_reqs: BTreeSet::new(),
            retry_parked: Vec::new(),
            slow_until: vec![0.0; n_agents],
            slow_mult: vec![1.0; n_agents],
            flap_until: 0.0,
            flap_added_s: 0.0,
            pending_error: None,
            m_retries,
            m_lost_tokens,
            m_recovery_s,
            m_degraded_s,
            prev_retries: 0.0,
            prev_lost_tokens: 0.0,
            prev_recovery_s: 0.0,
            prev_degraded_s: 0.0,
            cfg,
            opts,
            policies,
            sinks,
        };
        // A zero-step experiment has nothing to schedule: leaving the
        // queue empty makes the first pump report the run as done
        // (instead of the old StartStep(0) index panic).
        if engine.total_steps > 0 {
            engine.q.push_at(0.0, Ev::StartStep(0));
            engine.q.push_at(engine.opts.scaler_poll_s, Ev::Poll);
            // Inject the fault plan as first-class events. Plan order
            // (time-sorted, stable) becomes push order, so equal-time
            // faults strike in plan order via the queue's FIFO
            // tie-break; strikes beyond the run's end are abandoned
            // with the rest of the queue.
            for i in 0..engine.fault_plan.len() {
                let strike_t = engine.fault_plan[i].t;
                engine.q.push_at(strike_t, Ev::FaultStrike(i));
            }
        }
        engine
    }

    fn n_agents(&self) -> usize {
        self.cfg.workload.agents.len()
    }

    /// Live-window accessor: step `s` must be materialized and not yet
    /// retired. Every handler upholds this — events only ever reference
    /// steps between `window_base` and the newest started step.
    fn st(&self, s: usize) -> &StepCtl {
        &self.steps[s - self.window_base]
    }

    fn st_mut(&mut self, s: usize) -> &mut StepCtl {
        let i = s - self.window_base;
        &mut self.steps[i]
    }

    /// Materialize step `s` (and any unpulled predecessors) from the
    /// workload source. Returns `false` — with `pending_error` set, so
    /// `pump_step` poisons the run after the current event — if the
    /// source fails or runs dry before `total_steps`.
    fn ensure_step(&mut self, s: usize) -> bool {
        debug_assert!(s >= self.window_base, "step {s} already retired");
        while self.window_base + self.steps.len() <= s {
            let pulled = self.window_base + self.steps.len();
            match self.source.next_step() {
                Some(w) => {
                    debug_assert_eq!(w.step, pulled, "source yielded steps out of order");
                    let ctl = Self::build_ctl(w, self.sched_mode, self.n_agents());
                    self.steps.push_back(ctl);
                }
                None => {
                    let e = self.source.take_error().unwrap_or_else(|| {
                        PallasError::InvalidConfig(format!(
                            "workload source exhausted at step {pulled} (config says {} steps)",
                            self.total_steps
                        ))
                    });
                    if self.pending_error.is_none() {
                        self.pending_error = Some(e);
                    }
                    return false;
                }
            }
        }
        true
    }

    /// Build the control block for a freshly pulled step workload —
    /// exactly the per-step construction the eager path used to run
    /// over the whole `Vec` up front.
    fn build_ctl(workload: StepWorkload, mode: Mode, n_agents: usize) -> StepCtl {
        let sched = TrajectoryScheduler::new(&workload, mode);
        let expected = workload.calls_per_agent(n_agents);
        let traj_remaining = workload.trajectories.len();
        let mut group_pending: BTreeMap<(usize, usize), (usize, Vec<f64>)> = BTreeMap::new();
        for t in &workload.trajectories {
            for (ci, _) in t.calls.iter().enumerate() {
                group_pending.entry((t.query, ci)).or_insert_with(|| (0, Vec::new())).0 += 1;
            }
        }
        StepCtl {
            traj_start: vec![0.0; workload.trajectories.len()],
            traj_end: vec![0.0; workload.trajectories.len()],
            workload,
            sched,
            started: false,
            rollout_done: false,
            start_t: 0.0,
            rollout_end_t: 0.0,
            end_t: 0.0,
            expected,
            grads_done: vec![0; n_agents],
            applied: vec![false; n_agents],
            traj_remaining,
            group_pending,
            busy_s: 0.0,
            switch_s_total: 0.0,
        }
    }

    pub(crate) fn add_sink(&mut self, sink: Box<dyn super::events::EventSink>) {
        self.sinks.push(sink);
    }

    pub(crate) fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub(crate) fn now(&self) -> f64 {
        self.now
    }

    pub(crate) fn is_done(&self) -> bool {
        self.done || self.failed || self.stop.is_some()
    }

    pub(crate) fn stop_info(&self) -> Option<&StopInfo> {
        self.stop.as_ref()
    }

    /// Advance the event loop until the next MARL step completes and
    /// return its finalized report; `Ok(None)` once the run is over —
    /// all steps reported, a sink stopped it, or (after the error was
    /// yielded once) the event budget tripped.
    ///
    /// This is the run loop, re-cut at the step boundary: a monolithic
    /// run is exactly `while pump_step()? is Some {}` — same events,
    /// same order, same floats.
    pub(crate) fn pump_step(&mut self) -> Result<Option<StepReport>, PallasError> {
        loop {
            if let Some(r) = self.pending.pop_front() {
                return Ok(Some(r));
            }
            if self.is_done() {
                return Ok(None);
            }
            let Some((t, ev)) = self.q.pop() else {
                // Queue exhausted without completion — nothing more can
                // happen; treat the run as over.
                self.done = true;
                return Ok(None);
            };
            self.now = t;
            self.guard += 1;
            self.histo[ev_idx(&ev)] += 1;
            if self.guard >= self.event_budget {
                self.failed = true;
                return Err(PallasError::EventBudget {
                    t,
                    histogram: EV_NAMES.iter().copied().zip(self.histo).collect(),
                });
            }
            self.handle(t, ev);
            if let Some(e) = self.pending_error.take() {
                // Fail-fast recovery latched an abort during handling:
                // poison the engine like the event-budget guard does
                // (the error is yielded once, then the run is over).
                self.failed = true;
                return Err(e);
            }
            self.collect_completed(t);
            if self.all_done() {
                self.done = true;
            } else if self.sinks.stop_requested() && self.stop.is_none() {
                // Stop takes effect after the event was fully handled:
                // reports already finalized still drain to the caller,
                // unprocessed queue events are abandoned.
                self.stop = Some(StopInfo { t, steps_completed: self.next_report });
            }
        }
    }

    /// Finalize every newly-completed step, in step order, into
    /// `pending`. Completion is monotonic in the step index (an agent
    /// only trains step *s+1* after applying *s*), so a single forward
    /// cursor suffices; the loop handles degenerate workloads where one
    /// event completes several steps at once.
    fn collect_completed(&mut self, t: f64) {
        while self.next_report < self.total_steps && self.step_complete(self.next_report) {
            let s = self.next_report;
            self.next_report += 1;
            let report = self.finalize_step(s);
            self.sinks.emit(t, &EngineEvent::StepFinished { step: s, report: &report });
            self.pending.push_back(report);
            // Retire the finalized control block: every report input
            // froze at completion and no handler touches a completed
            // step again, so the window slides forward and memory stays
            // O(live steps) regardless of run length.
            debug_assert_eq!(s, self.window_base);
            self.steps.pop_front();
            self.window_base += 1;
        }
    }

    fn step_complete(&self, s: usize) -> bool {
        if s < self.window_base {
            // Retired: the step finalized and left the window.
            return true;
        }
        match self.steps.get(s - self.window_base) {
            Some(st) => st.started && st.rollout_done && st.applied.iter().all(|&x| x),
            // Not yet materialized ⇒ not yet started.
            None => false,
        }
    }

    /// Build step `s`'s report from per-step state — every input is
    /// frozen by the time the step completes (decode busy lands before
    /// `rollout_done`, grad/apply busy at dispatch, and the to-rollout
    /// phase switch is charged at schedule time in
    /// [`Engine::check_step_complete`]), so streaming a report per step
    /// is bit-identical to batch reporting. Counter-backed fields
    /// (`scale_ops`, `swap_s`) are deltas since the previous step's
    /// completion.
    fn finalize_step(&mut self, s: usize) -> StepReport {
        let n_agents = self.n_agents();
        let st = self.st(s);
        let e2e = st.end_t - st.start_t;
        let rollout_s = st.rollout_end_t - st.start_t;
        let train_s = (st.end_t - st.rollout_end_t - st.switch_s_total).max(0.0);
        let latencies: Vec<f64> = (0..st.workload.trajectories.len())
            .map(|i| (st.traj_end[i] - st.traj_start[i]).max(0.0))
            .collect();
        let scale_now = self.counters.get(self.m_scale_ops);
        let swap_now = self.counters.get(self.m_swap_s);
        let retries_now = self.counters.get(self.m_retries);
        let lost_now = self.counters.get(self.m_lost_tokens);
        let recovery_now = self.counters.get(self.m_recovery_s);
        let degraded_now = self.counters.get(self.m_degraded_s);
        let report = StepReport {
            framework: self.policies.name.clone(),
            workload: self.cfg.workload.name.clone(),
            scenario: self.cfg.workload.scenario.clone(),
            e2e_s: e2e,
            rollout_s,
            train_s,
            other_s: (e2e - rollout_s - train_s).max(0.0),
            tokens: st.workload.total_tokens(),
            busy_device_s: st.busy_s,
            pool_devices: self.pool_devices,
            agent_calls: st.workload.calls_per_agent(n_agents),
            trajectory_latencies: latencies,
            scale_ops: (scale_now - self.prev_scale_ops) as usize,
            swap_s: swap_now - self.prev_swap_s,
            retries: (retries_now - self.prev_retries) as usize,
            lost_tokens: lost_now - self.prev_lost_tokens,
            recovery_s: recovery_now - self.prev_recovery_s,
            degraded_s: degraded_now - self.prev_degraded_s,
        };
        self.prev_scale_ops = scale_now;
        self.prev_swap_s = swap_now;
        self.prev_retries = retries_now;
        self.prev_lost_tokens = lost_now;
        self.prev_recovery_s = recovery_now;
        self.prev_degraded_s = degraded_now;
        report
    }

    /// Consume the engine into an outcome over the reports the caller
    /// drained from it.
    pub(crate) fn into_outcome(self, reports: Vec<StepReport>) -> SimOutcome {
        SimOutcome {
            reports,
            total_s: self.now,
            series: RunSeries {
                processed: self.processed_series,
                queued: self.queued_series,
                busy: self.busy_series,
            },
            stop: self.stop,
        }
    }

    fn all_done(&self) -> bool {
        // Completion is monotone in the step index and the report
        // cursor advances the moment a step completes (every caller
        // runs after `collect_completed`, and polls never complete
        // steps), so "every step reported" == the old full scan.
        self.next_report == self.total_steps
    }

    // -----------------------------------------------------------------------
    // Event handling
    // -----------------------------------------------------------------------

    fn handle(&mut self, t: f64, ev: Ev) {
        match ev {
            Ev::StartStep(s) => self.start_step(t, s),
            Ev::CallDone(rid) => self.call_done(t, rid),
            Ev::Poll => self.poll(t),
            Ev::MigrationArrive { donor_insts, target } => {
                self.migration_arrive(t, donor_insts, target)
            }
            Ev::SwitchToTrainDone(s) => {
                let sw = self.opts.switch_s;
                self.st_mut(s).switch_s_total += sw;
                for a in 0..self.n_agents() {
                    self.maybe_train(t, a);
                }
            }
            Ev::SwitchToRolloutDone(s) => {
                // The switch cost was charged at schedule time
                // (check_step_complete): it belongs to step `s`'s
                // budget, whose report freezes at step completion —
                // before this event lands (step `s` has already left
                // the window; this arm must not touch its ctl block).
                if s + 1 < self.total_steps {
                    self.q.push_at(t, Ev::StartStep(s + 1));
                }
            }
            Ev::SwapInDone { agent, step } => {
                debug_assert_eq!(self.tstate[agent], AgentTrain::SwappingIn);
                self.tstate[agent] = AgentTrain::Computing;
                self.dispatch_grad(t, agent, step);
            }
            Ev::GradDone { agent, step, n } => self.grad_done(t, agent, step, n),
            Ev::ApplyDone { agent, step } => self.apply_done(t, agent, step),
            Ev::SwapOutDone { agent } => {
                debug_assert_eq!(self.tstate[agent], AgentTrain::SwappingOut);
                self.tstate[agent] = AgentTrain::Idle;
                // Devices freed — maybe a queued agent can bind now.
                if !self.static_mode {
                    if let Some(next) = self.alloc.next_waiter() {
                        self.maybe_train(t, next);
                    }
                }
                // New work may have arrived while this agent was swapping
                // out (e.g., the rollout finished meanwhile).
                self.maybe_train(t, agent);
            }
            Ev::FaultStrike(i) => self.fault_strike(t, i),
            Ev::RetryDue(i) => self.retry_due(t, i),
            Ev::Recover { agent } => self.recover(t, agent),
        }
    }

    fn start_step(&mut self, t: f64, s: usize) {
        if !self.ensure_step(s) {
            // The source failed or ran dry: `pending_error` is set and
            // `pump_step` poisons the run after this event.
            return;
        }
        let n_agents = self.n_agents();
        {
            let st = self.st_mut(s);
            debug_assert!(!st.started);
            st.started = true;
            st.start_t = t;
            // Agents with zero calls this step are trivially applied.
            for a in 0..n_agents {
                if st.expected[a] == 0 {
                    st.applied[a] = true;
                }
            }
        }
        // Direct window indexing: the borrow must stay on the `steps`
        // field alone so `sinks.emit` (&mut self.sinks) can run.
        let ev = EngineEvent::StepStarted {
            step: s,
            workload: &self.steps[s - self.window_base].workload,
        };
        self.sinks.emit(t, &ev);
        let ready = self.st_mut(s).sched.start();
        for c in ready {
            self.submit_call(t, s, c);
        }
        // Degenerate workload (no trajectories).
        if self.st(s).traj_remaining == 0 {
            self.rollout_finished(t, s);
        }
    }

    fn submit_call(&mut self, t: f64, step: usize, c: CallRef) {
        let spec = self.st(step).workload.trajectories[c.traj].calls[c.call].clone();
        if c.call == 0 {
            self.st_mut(step).traj_start[c.traj] = t;
        }
        let mut decode_s = spec.tokens / self.cfg.workload.agents[spec.agent].model.decode_tps();
        // Straggler fault window: calls submitted while the agent is
        // degraded decode slower (no-fault guard is `t < 0.0` — free).
        if t < self.slow_until[spec.agent] {
            decode_s *= self.slow_mult[spec.agent];
        }
        // Colocated architectures share HBM/compute between phases: when
        // training overlaps generation on the same pool (MARTI's one-step
        // async), decode pays a memory-contention penalty (§4.1).
        let contention = self.policies.alloc.decode_contention_mult();
        if contention != 1.0
            && self
                .tstate
                .iter()
                .any(|s| matches!(s, AgentTrain::Computing | AgentTrain::Applying))
        {
            decode_s *= contention;
        }
        let rid = self.reqs.alloc(ReqInfo {
            step,
            call: c,
            decode_s,
            env_s: spec.env_s,
            agent: spec.agent,
            attempt: 0,
        });
        match self.man.submit(rid, spec.agent) {
            Dispatch::Started(_) => {
                let info = self.reqs.get(rid);
                self.q.push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
            }
            Dispatch::Enqueued(_) | Dispatch::Parked => {}
        }
    }

    fn call_done(&mut self, t: f64, rid: RequestId) {
        // Stale completion of a request whose instance died mid-decode:
        // the work was already re-dispatched (or discarded) by the
        // recovery policy — free the tombstoned slab slot and move on.
        if self.dead_reqs.remove(&rid) {
            self.reqs.remove(rid);
            return;
        }
        let info = self.reqs.remove(rid);
        // Device-busy: decode seconds × the slot's device share.
        let dev = self.inst_dev[info.agent] as f64;
        let busy = info.decode_s * dev / self.opts.concurrency as f64;
        self.st_mut(info.step).busy_s += busy;

        if let Some(promoted) = self.man.complete(rid) {
            let p = self.reqs.get(promoted);
            self.q.push_in(p.decode_s + p.env_s, Ev::CallDone(promoted));
        }

        // Record the call's sample; GRPO groups become ready together
        // (the advantage of each candidate needs the group's rewards).
        let step = info.step;
        let tokens =
            self.st(step).workload.trajectories[info.call.traj].calls[info.call.call].tokens;
        let key = (self.st(step).workload.trajectories[info.call.traj].query, info.call.call);
        let ready_group = {
            let entry =
                self.st_mut(step).group_pending.get_mut(&key).expect("group bookkeeping");
            entry.0 -= 1;
            entry.1.push(tokens);
            if entry.0 == 0 {
                Some(std::mem::take(&mut entry.1))
            } else {
                None
            }
        };
        if let Some(group_tokens) = ready_group {
            // Group complete → all its samples are fully generated.
            // One batched write amortizes the table lock over the group.
            let version = step as u64;
            let rows: Vec<PutRow> = group_tokens
                .into_iter()
                .map(|tok| {
                    let id = SampleId::new(self.sample_seq, 1, 0);
                    self.sample_seq += 1;
                    PutRow {
                        version,
                        id,
                        fields: vec![
                            ("tokens", Field::Value(Value::Float(tok))),
                            ("reward", Field::Value(Value::Float(1.0))),
                        ],
                    }
                })
                .collect();
            self.store.put_rows(&self.agent_keys[info.agent], rows).unwrap();
            if self.policies.pipeline.admits_during_rollout() {
                self.maybe_train(t, info.agent);
            }
        }

        // Per-trajectory completion time (Fig. 1a interaction latency).
        if info.call.call + 1 == self.st(step).workload.trajectories[info.call.traj].calls.len() {
            self.st_mut(step).traj_end[info.call.traj] = t;
        }

        // Advance the dependency DAG.
        let ready = self.st_mut(step).sched.complete(info.call);
        for c in ready {
            self.submit_call(t, step, c);
        }

        // Trajectory / rollout completion bookkeeping.
        let st = self.st(step);
        if st.sched.is_done() && !st.rollout_done {
            self.rollout_finished(t, step);
        }
    }

    fn rollout_finished(&mut self, t: f64, s: usize) {
        {
            let st = self.st_mut(s);
            st.rollout_done = true;
            st.rollout_end_t = t;
        }
        if self.strict_alternation() {
            // MAS-RL: offload inference, onload training states.
            self.sinks.emit(t, &EngineEvent::PhaseSwitch { step: s, to_train: true });
            self.q.push_in(self.opts.switch_s, Ev::SwitchToTrainDone(s));
        } else {
            for a in 0..self.n_agents() {
                self.maybe_train(t, a);
            }
        }
        if let Some(frac) = self.policies.pipeline.next_step_prefetch() {
            // MARTI: next step's rollout starts now with stale params
            // (a pipelined partial switch to restore instance weights).
            if s + 1 < self.total_steps {
                let charge = self.opts.switch_s * frac;
                self.q.push_in(charge, Ev::StartStep(s + 1));
                self.st_mut(s).switch_s_total += charge;
            }
        }
    }

    /// Strict phase alternation (MAS-RL): one colocated pool whose
    /// rollout and training phases never coexist — every transition
    /// pays the onload/offload switch.
    fn strict_alternation(&self) -> bool {
        !self.policies.alloc.dedicated_pools() && !self.policies.pipeline.overlaps_steps()
    }

    // -----------------------------------------------------------------------
    // Training pipeline (§4.3 + §6)
    // -----------------------------------------------------------------------

    /// Can `agent` begin (or continue) training work right now?
    fn maybe_train(&mut self, t: f64, agent: usize) {
        if self.tstate[agent] != AgentTrain::Idle {
            return;
        }
        let Some(step) = self.train_step_for(agent) else {
            return;
        };
        // Sync pipelines only train after the step's rollout concluded
        // (and for colocated MAS-RL, after the phase switch — gated by
        // the SwitchToTrainDone event calling back into here).
        if !self.policies.pipeline.admits_during_rollout() && !self.st(step).rollout_done {
            return;
        }
        if self.strict_alternation() && !self.st(step).rollout_done {
            // MAS-RL: must be past the switch (switch event re-triggers).
            return;
        }
        let ready = self.store.count_ready(&self.agent_keys[agent], Some(step as u64));
        let micro = self.cfg.pipeline.micro_batch;
        let st = self.st(step);
        let all_in = st.rollout_done;
        let have_work = ready >= micro || (all_in && ready > 0);
        let need_apply = all_in
            && ready == 0
            && st.grads_done[agent] == st.expected[agent]
            && !st.applied[agent];
        if !have_work && !need_apply {
            return;
        }

        // Bind resources.
        let model = self.cfg.workload.agents[agent].model;
        if self.static_mode {
            // Static partition always bound; no swap cost.
            self.tstate[agent] = AgentTrain::Computing;
            if need_apply {
                self.begin_apply(t, agent, step);
            } else {
                self.dispatch_grad(t, agent, step);
            }
        } else {
            match self.alloc.activate(agent) {
                Some((_p, local)) => {
                    let cost = swap_in_cost(model, &self.cfg.cluster, local);
                    // Swap-link flap window: transfers started while the
                    // link is congested pay the added latency.
                    let mut cost_s = cost.total();
                    if t < self.flap_until {
                        cost_s += self.flap_added_s;
                    }
                    self.counters.add(self.m_swap_s, cost_s);
                    let ev = EngineEvent::SwapIn { agent, step, cost_s };
                    self.sinks.emit(t, &ev);
                    self.tstate[agent] = AgentTrain::SwappingIn;
                    if need_apply {
                        // Rare: resources were released before apply.
                        self.tstate[agent] = AgentTrain::Computing;
                        self.q.push_in(cost_s, Ev::GradDone { agent, step, n: 0 });
                    } else {
                        self.q.push_in(cost_s, Ev::SwapInDone { agent, step });
                    }
                }
                None => { /* queued on the allocator; retried on release */ }
            }
        }
    }

    /// Earliest step with outstanding training work for `agent`.
    /// Scanning the live window is equivalent to the old scan from
    /// step 0: retired steps are started *and* fully applied, so they
    /// could neither match nor break the loop early.
    fn train_step_for(&self, agent: usize) -> Option<usize> {
        for (i, st) in self.steps.iter().enumerate() {
            if !st.started {
                break;
            }
            if !st.applied[agent] {
                return Some(self.window_base + i);
            }
        }
        None
    }

    fn dispatch_grad(&mut self, t: f64, agent: usize, step: usize) {
        let micro = self.cfg.pipeline.micro_batch;
        // Fused dispatch+consume: the micro-batch is gradient-processed
        // unconditionally, so take it in one store-lock acquisition.
        let fetched = self.store.take_batch(&self.agent_keys[agent], Some(step as u64), micro);
        if fetched.is_empty() {
            // Nothing to compute: either apply or release.
            let st = self.st(step);
            if st.rollout_done
                && st.grads_done[agent] == st.expected[agent]
                && !st.applied[agent]
            {
                self.begin_apply(t, agent, step);
            } else {
                self.release_training(t, agent);
            }
            return;
        }
        let n = fetched.len();
        self.sinks.emit(t, &EngineEvent::MicroBatchAdmitted { step, agent, n });
        let tokens: f64 = fetched
            .iter()
            .map(|f| {
                f.value("tokens").and_then(|v| v.as_f64()).unwrap_or(0.0)
                    + self.opts.context_tokens
            })
            .sum();
        let model = self.cfg.workload.agents[agent].model;
        let dur = grad_compute_s(model, tokens);
        let gdev = model.train_group_devices() as f64;
        self.st_mut(step).busy_s += dur * gdev;
        self.q.push_in(dur, Ev::GradDone { agent, step, n });
    }

    fn grad_done(&mut self, t: f64, agent: usize, step: usize, n: usize) {
        self.st_mut(step).grads_done[agent] += n;
        debug_assert!(
            self.st(step).grads_done[agent] <= self.st(step).expected[agent],
            "agent {agent} over-trained"
        );
        // Continue: more micro batches, apply, or release.
        let ready = self.store.count_ready(&self.agent_keys[agent], Some(step as u64));
        let st = self.st(step);
        let micro = self.cfg.pipeline.micro_batch;
        if ready >= micro || (st.rollout_done && ready > 0) {
            self.dispatch_grad(t, agent, step);
        } else if st.rollout_done && st.grads_done[agent] == st.expected[agent] {
            self.begin_apply(t, agent, step);
        } else {
            // §6.1: no new experiences → suspend-to-destroy.
            self.release_training(t, agent);
        }
    }

    fn begin_apply(&mut self, t: f64, agent: usize, step: usize) {
        self.tstate[agent] = AgentTrain::Applying;
        let model = self.cfg.workload.agents[agent].model;
        let dur = apply_update_s(model) + self.opts.sync_s;
        let gdev = model.train_group_devices() as f64;
        self.st_mut(step).busy_s += apply_update_s(model) * gdev;
        self.q.push_in(dur, Ev::ApplyDone { agent, step });
        let _ = t;
    }

    fn apply_done(&mut self, t: f64, agent: usize, step: usize) {
        self.st_mut(step).applied[agent] = true;
        self.release_training(t, agent);
        self.check_step_complete(t, step);
        // The agent may have next-step samples waiting (MARTI overlap).
        self.maybe_train(t, agent);
    }

    fn release_training(&mut self, t: f64, agent: usize) {
        if self.static_mode {
            self.tstate[agent] = AgentTrain::Idle;
            return;
        }
        let model = self.cfg.workload.agents[agent].model;
        if self.alloc.release(agent).is_some() {
            let cost = swap_out_cost(model, &self.cfg.cluster);
            let mut cost_s = cost.total();
            if t < self.flap_until {
                cost_s += self.flap_added_s;
            }
            self.counters.add(self.m_swap_s, cost_s);
            let ev = EngineEvent::SwapOut { agent, cost_s };
            self.sinks.emit(t, &ev);
            self.tstate[agent] = AgentTrain::SwappingOut;
            self.q.push_in(cost_s, Ev::SwapOutDone { agent });
        } else {
            self.tstate[agent] = AgentTrain::Idle;
        }
    }

    fn check_step_complete(&mut self, t: f64, step: usize) {
        if !self.step_complete(step) {
            return;
        }
        self.st_mut(step).end_t = t;
        if self.policies.pipeline.overlaps_steps() {
            // Next step already started at rollout boundary.
            return;
        }
        if step + 1 < self.total_steps {
            if !self.policies.alloc.dedicated_pools() {
                // MAS-RL: switch back to inference before next rollout.
                // Charge the switch to this step's budget *now* — it
                // belongs to the step, but the completion event (and
                // the step's report) fires before the switch lands.
                let sw = self.opts.switch_s;
                self.st_mut(step).switch_s_total += sw;
                self.sinks.emit(t, &EngineEvent::PhaseSwitch { step, to_train: false });
                self.q.push_in(sw, Ev::SwitchToRolloutDone(step));
            } else {
                self.q.push_at(t, Ev::StartStep(step + 1));
            }
        }
    }

    // -----------------------------------------------------------------------
    // Load balancing + metric sampling
    // -----------------------------------------------------------------------

    fn poll(&mut self, t: f64) {
        // Metric series for tracked agents.
        for (&a, series) in self.processed_series.iter_mut() {
            series.push((t, self.man.completed_per_agent[a] as usize));
        }
        for (&a, series) in self.queued_series.iter_mut() {
            series.push((t, self.man.queue_len(a)));
        }
        let busy_now: usize = (0..self.n_agents())
            .map(|a| {
                let outstanding = self.man.outstanding(a).min(
                    self.man.instance_count(a) * self.opts.concurrency,
                );
                (outstanding * self.inst_dev[a]).div_ceil(self.opts.concurrency)
            })
            .sum::<usize>()
            + self.alloc.active_devices();
        self.busy_series.push((t, busy_now));

        let migrated = self.try_rebalance(t);
        let ev = EngineEvent::ScalerDecision { migrated, busy_devices: busy_now };
        self.sinks.emit(t, &ev);
        if !self.all_done() {
            self.q.push_in(self.opts.scaler_poll_s, Ev::Poll);
        }
    }

    /// One balancing decision (the poll tick's migration logic; also
    /// invoked by degrade-and-rebalance recovery right after an
    /// instance loss, so surviving capacity re-plans around the hole
    /// without waiting for the next poll). Returns whether a migration
    /// was planned. No-op for policies with balancing disabled.
    fn try_rebalance(&mut self, t: f64) -> bool {
        if !self.policies.balance.enabled() {
            return false;
        }
        let queue_lens = self.man.queue_lens();
        let counts = self.man.instance_counts();
        let Some(plan) = self.policies.balance.plan(&LoadSnapshot {
            queue_lens: &queue_lens,
            instance_counts: &counts,
            delta_threshold: self.cfg.pipeline.delta_threshold,
            busy_scaling: &self.agent_busy_scaling,
        }) else {
            return false;
        };
        self.sinks.emit(
            t,
            &EngineEvent::MigrationPlanned {
                donor: plan.donor,
                target: plan.target,
                n_instances: plan.n_instances,
            },
        );
        // Drain the donor's *idlest* instances (least stranded
        // work); displaced requests re-queue on its survivors.
        let donor_insts: Vec<usize> = self
            .man
            .instances_by_load(plan.donor)
            .into_iter()
            .take(plan.n_instances)
            .collect();
        let mut displaced = Vec::new();
        for &iid in &donor_insts {
            displaced.extend(self.man.drain_instance(iid));
        }
        for rid in displaced {
            let agent = self.reqs.get(rid).agent;
            if let Dispatch::Started(_) = self.man.submit(rid, agent) {
                let info = self.reqs.get(rid);
                self.q
                    .push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
            }
        }
        self.agent_busy_scaling[plan.donor] = true;
        self.agent_busy_scaling[plan.target] = true;
        self.counters.add(self.m_scale_ops, 1.0);
        // Weight transfer via Set/Get (contiguous buffer, §9).
        let model = self.cfg.workload.agents[plan.target].model;
        let lat = crate::rollout::migration_latency(
            model,
            &self.transfer,
            0,
            self.cfg.cluster.devices_per_node, // cross-node typical
            self.opts.reinit_s,
        );
        self.q.push_in(
            lat,
            Ev::MigrationArrive {
                donor_insts,
                target: plan.target,
            },
        );
        true
    }

    fn migration_arrive(&mut self, t: f64, donor_insts: Vec<usize>, target: usize) {
        // Any not-yet-drained instance finishes its active requests
        // first; re-check shortly.
        if donor_insts.iter().any(|&i| !self.man.is_drained(i)) {
            self.q.push_in(1.0, Ev::MigrationArrive { donor_insts, target });
            return;
        }
        let donor = donor_insts
            .first()
            .and_then(|i| self.inst_agent.get(i))
            .copied();
        for iid in donor_insts {
            self.man.remove_instance(iid);
            let (new_id, started) = self.man.add_instance(target, self.opts.concurrency);
            self.inst_agent.insert(new_id, target);
            for rid in started {
                let info = self.reqs.get(rid);
                self.q.push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
            }
        }
        if let Some(d) = donor {
            self.agent_busy_scaling[d] = false;
        }
        self.agent_busy_scaling[target] = false;
        let _ = t;
    }

    // -----------------------------------------------------------------------
    // Fault plane (DESIGN.md §10)
    // -----------------------------------------------------------------------

    /// Execute `fault_plan[idx]`. Victim selection is deterministic —
    /// idlest-first within an agent ([`RolloutManager::instances_by_load`],
    /// load then lowest id) and fattest-agent-first across agents — and
    /// obeys the liveness rule: destructive faults never remove an
    /// agent's *last* live instance, so every recovery policy can still
    /// drive the run to completion (fail-fast aborts deliberately, not
    /// by starvation).
    fn fault_strike(&mut self, t: f64, idx: usize) {
        let kind = self.fault_plan[idx].kind.clone();
        let ev = EngineEvent::FaultInjected { kind: kind.name(), agent: kind.agent() };
        self.sinks.emit(t, &ev);
        match kind {
            FaultKind::InstanceCrash { agent } => {
                if self.man.instance_count(agent) >= 2 {
                    let victim = self.man.instances_by_load(agent)[0];
                    self.lose_instances(t, vec![victim]);
                }
            }
            FaultKind::NodePreemption { n } => {
                // A node going away takes the idlest instance of the
                // fattest pool, n times (tie → lowest agent id).
                let mut counts: Vec<usize> =
                    (0..self.n_agents()).map(|a| self.man.instance_count(a)).collect();
                let mut victims: Vec<usize> = Vec::new();
                for _ in 0..n {
                    let Some(agent) = (0..counts.len())
                        .filter(|&a| counts[a] >= 2)
                        .max_by_key(|&a| (counts[a], std::cmp::Reverse(a)))
                    else {
                        break;
                    };
                    let Some(victim) = self
                        .man
                        .instances_by_load(agent)
                        .into_iter()
                        .find(|i| !victims.contains(i))
                    else {
                        break;
                    };
                    victims.push(victim);
                    counts[agent] -= 1;
                }
                self.lose_instances(t, victims);
            }
            FaultKind::Straggler { agent, slowdown, duration_s } => {
                self.slow_until[agent] = self.slow_until[agent].max(t + duration_s);
                self.slow_mult[agent] = slowdown;
            }
            FaultKind::SwapLinkFlap { added_s, duration_s } => {
                self.flap_until = self.flap_until.max(t + duration_s);
                self.flap_added_s = added_s;
            }
            FaultKind::ClusterResize { delta } => self.cluster_resize(t, delta),
        }
    }

    /// Generated tokens of the call behind `info` — the lost-work
    /// accounting for a request killed mid-decode.
    fn call_tokens(&self, info: &ReqInfo) -> f64 {
        self.st(info.step).workload.trajectories[info.call.traj].calls[info.call.call].tokens
    }

    /// Kill `victims` and route their displaced work through the
    /// bundle's [`crate::policy::RecoveryPolicy`].
    ///
    /// Store invalidation: rows below the agent's oldest unapplied step
    /// are genuinely stale (their step's update already applied) and
    /// are evicted defensively; the *displaced* requests themselves
    /// never reached the store — GRPO samples only enter at group
    /// completion — so re-dispatch alone restores consistency.
    fn lose_instances(&mut self, t: f64, victims: Vec<usize>) {
        for iid in victims {
            let Some(&agent) = self.inst_agent.get(&iid) else {
                continue;
            };
            self.inst_agent.remove(&iid);
            let (active, queued) = self.man.fail_instance(iid);
            if let Some(s) = self.train_step_for(agent) {
                self.store.evict_stale(&self.agent_keys[agent], s as u64);
            }
            match self.policies.recovery.on_instance_lost(t, agent, iid) {
                RecoveryAction::Abort => {
                    for rid in active {
                        self.dead_reqs.insert(rid);
                    }
                    for rid in queued {
                        self.reqs.remove(rid);
                    }
                    if self.pending_error.is_none() {
                        self.pending_error =
                            Some(PallasError::InstanceLost { t, agent, instance: iid });
                    }
                }
                RecoveryAction::Retry => {
                    for rid in active {
                        // Mid-decode work is lost and re-done from
                        // scratch; the in-flight CallDone is tombstoned.
                        let info = self.reqs.get(rid).clone();
                        let lost = self.call_tokens(&info);
                        self.counters.add(self.m_lost_tokens, lost);
                        self.dead_reqs.insert(rid);
                        self.park_retry(t, info);
                    }
                    for rid in queued {
                        // Queued work hadn't started: nothing lost, but
                        // it still waits out the backoff.
                        let info = self.reqs.remove(rid);
                        self.park_retry(t, info);
                    }
                }
                RecoveryAction::Reprovision { delay_s } => {
                    // Graceful degradation: displaced work re-plans
                    // immediately onto survivors (no backoff), the
                    // balancer re-plans around the hole, and a
                    // replacement comes up after the recovery delay.
                    for rid in active {
                        let info = self.reqs.get(rid).clone();
                        let lost = self.call_tokens(&info);
                        self.counters.add(self.m_lost_tokens, lost);
                        self.dead_reqs.insert(rid);
                        self.resubmit(info);
                    }
                    for rid in queued {
                        let info = self.reqs.remove(rid);
                        self.resubmit(info);
                    }
                    self.counters.add(self.m_degraded_s, delay_s);
                    self.try_rebalance(t);
                    self.q.push_at(t + delay_s, Ev::Recover { agent });
                }
            }
        }
    }

    /// Park a displaced request for its policy backoff, then re-dispatch
    /// via [`Ev::RetryDue`].
    fn park_retry(&mut self, t: f64, info: ReqInfo) {
        let backoff = self.policies.recovery.backoff_s(info.attempt);
        self.counters.add(self.m_recovery_s, backoff);
        let idx = self.retry_parked.len();
        self.retry_parked.push(Some(info));
        self.q.push_at(t + backoff, Ev::RetryDue(idx));
    }

    fn retry_due(&mut self, t: f64, idx: usize) {
        let Some(mut info) = self.retry_parked[idx].take() else {
            return;
        };
        info.attempt += 1;
        self.counters.add(self.m_retries, 1.0);
        let ev = EngineEvent::RequestRetried { agent: info.agent, attempt: info.attempt };
        self.sinks.emit(t, &ev);
        self.resubmit(info);
    }

    /// Re-dispatch a displaced request as a fresh slab entry (new id —
    /// the dead id stays tombstoned until its stale completion drains).
    /// Decode is not re-priced: determinism over realism.
    fn resubmit(&mut self, info: ReqInfo) {
        let agent = info.agent;
        let rid = self.reqs.alloc(info);
        match self.man.submit(rid, agent) {
            Dispatch::Started(_) => {
                let i = self.reqs.get(rid);
                self.q.push_in(i.decode_s + i.env_s, Ev::CallDone(rid));
            }
            Dispatch::Enqueued(_) | Dispatch::Parked => {}
        }
    }

    /// Degrade recovery's delayed re-provision: bring a replacement
    /// instance up for `agent`.
    fn recover(&mut self, t: f64, agent: usize) {
        let (iid, started) = self.man.add_instance(agent, self.opts.concurrency);
        self.inst_agent.insert(iid, agent);
        let ev = EngineEvent::InstanceRecovered { agent, instance: iid };
        self.sinks.emit(t, &ev);
        for rid in started {
            let info = self.reqs.get(rid);
            self.q.push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
        }
    }

    /// Mid-run cluster resize. Scale-up adds instances to the thinnest
    /// pools (tie → lowest agent id); scale-down *gracefully drains*
    /// the idlest instance of the fattest pools — a planned resize
    /// loses no work, unlike a crash. The drained carcass finishes its
    /// active requests and is never re-used (the dispatch heap already
    /// excludes it); it is left in place rather than garbage-collected.
    fn cluster_resize(&mut self, t: f64, delta: i64) {
        let mut changed = 0usize;
        if delta > 0 {
            for _ in 0..delta {
                let Some(agent) = (0..self.n_agents())
                    .min_by_key(|&a| (self.man.instance_count(a), a))
                else {
                    break;
                };
                let (iid, started) = self.man.add_instance(agent, self.opts.concurrency);
                self.inst_agent.insert(iid, agent);
                for rid in started {
                    let info = self.reqs.get(rid);
                    self.q.push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
                }
                changed += 1;
            }
        } else {
            for _ in 0..(-delta) {
                let Some(agent) = (0..self.n_agents())
                    .filter(|&a| self.man.instance_count(a) >= 2)
                    .max_by_key(|&a| (self.man.instance_count(a), std::cmp::Reverse(a)))
                else {
                    break;
                };
                let iid = self.man.instances_by_load(agent)[0];
                let displaced = self.man.drain_instance(iid);
                self.inst_agent.remove(&iid);
                for rid in displaced {
                    let r_agent = self.reqs.get(rid).agent;
                    if let Dispatch::Started(_) = self.man.submit(rid, r_agent) {
                        let info = self.reqs.get(rid);
                        self.q.push_in(info.decode_s + info.env_s, Ev::CallDone(rid));
                    }
                }
                changed += 1;
            }
        }
        let ev = EngineEvent::ClusterResized { delta, instances: changed };
        self.sinks.emit(t, &ev);
    }

    // -----------------------------------------------------------------------
    // Checkpointing (DESIGN.md §12)
    // -----------------------------------------------------------------------

    /// Fingerprint of everything the checkpoint payload does *not*
    /// carry because restore rebuilds it from config: cluster, workload
    /// shape, pipeline, framework, run length, seed, fault-plan inputs,
    /// policy bundle, and the engine knobs. Resuming against a
    /// different config would silently diverge — the fingerprint turns
    /// that into a typed rejection. Deliberately *excluded*: the
    /// event-queue backend (snapshots are backend-agnostic),
    /// `workload_mode` (lazy and eager runs are byte-identical), and
    /// the checkpoint section itself (where snapshots are written does
    /// not change what is computed).
    pub(crate) fn fingerprint(&self) -> u64 {
        let o = &self.opts;
        let id = format!(
            "{:?}|{:?}|{:?}|{:?}|{}|{}|{:?}|{}|{}|{}|{}|{}|{}|{}|{}|{:?}",
            self.cfg.cluster,
            self.cfg.workload,
            self.cfg.pipeline,
            self.cfg.framework,
            self.cfg.steps,
            self.cfg.seed,
            self.cfg.faults,
            self.policies.name,
            o.instances_per_agent,
            o.concurrency,
            o.scaler_poll_s,
            o.reinit_s,
            o.switch_s,
            o.context_tokens,
            o.sync_s,
            o.track_agents,
        );
        crate::ckpt::fnv1a64(id.as_bytes())
    }

    /// Complete mutable engine state as a checkpoint payload. Pure-
    /// from-config state — the fault plan, transfer model, policy
    /// bundle, interned keys/ids, pool accounting, and each window
    /// step's *workload* — is rebuilt by [`Engine::restore_from`] and
    /// stays out of the payload; [`Engine::fingerprint`] guards that
    /// contract.
    pub(crate) fn snapshot(&self) -> Json {
        let (qnow, next_seq, entries) = self.q.snapshot_entries();
        let fs = |v: &[f64]| Json::arr(v.iter().map(|&x| Json::num(x)));
        Json::obj(vec![
            ("fingerprint", ju64(self.fingerprint())),
            (
                "queue",
                Json::obj(vec![
                    ("now", Json::num(qnow)),
                    ("next_seq", ju64(next_seq)),
                    (
                        "entries",
                        Json::arr(entries.into_iter().map(|(t, seq, ev)| {
                            Json::arr([Json::num(t), ju64(seq), ev_to_json(ev)])
                        })),
                    ),
                ]),
            ),
            ("man", self.man.snapshot()),
            ("store", self.store.snapshot()),
            ("alloc", self.alloc.snapshot()),
            ("window_base", Json::num(self.window_base as f64)),
            ("window", Json::arr(self.steps.iter().map(ctl_to_json))),
            (
                "reqs",
                Json::obj(vec![
                    (
                        "slots",
                        Json::arr(self.reqs.slots.iter().map(|s| match s {
                            None => Json::Null,
                            Some(r) => req_to_json(r),
                        })),
                    ),
                    (
                        "free",
                        Json::arr(self.reqs.free.iter().map(|&i| Json::num(i as f64))),
                    ),
                ]),
            ),
            (
                "tstate",
                Json::arr(self.tstate.iter().map(|s| {
                    Json::str(match s {
                        AgentTrain::Idle => "idle",
                        AgentTrain::SwappingIn => "swap_in",
                        AgentTrain::Computing => "computing",
                        AgentTrain::Applying => "applying",
                        AgentTrain::SwappingOut => "swap_out",
                    })
                })),
            ),
            (
                "inst_agent",
                Json::arr(self.inst_agent.iter().map(|(&i, &a)| {
                    Json::arr([Json::num(i as f64), Json::num(a as f64)])
                })),
            ),
            (
                "agent_busy_scaling",
                Json::arr(self.agent_busy_scaling.iter().map(|&b| Json::Bool(b))),
            ),
            ("sample_seq", ju64(self.sample_seq)),
            ("counters", fs(self.counters.snapshot_vals())),
            (
                "series",
                RunSeries {
                    processed: self.processed_series.clone(),
                    queued: self.queued_series.clone(),
                    busy: self.busy_series.clone(),
                }
                .to_ckpt_json(),
            ),
            ("guard", ju64(self.guard)),
            ("histo", Json::arr(self.histo.iter().map(|&h| ju64(h)))),
            ("now", Json::num(self.now)),
            ("done", Json::Bool(self.done)),
            ("failed", Json::Bool(self.failed)),
            (
                "stop",
                match &self.stop {
                    None => Json::Null,
                    Some(s) => Json::obj(vec![
                        ("t", Json::num(s.t)),
                        ("steps_completed", Json::num(s.steps_completed as f64)),
                    ]),
                },
            ),
            ("next_report", Json::num(self.next_report as f64)),
            ("pending", Json::arr(self.pending.iter().map(|r| r.to_ckpt_json()))),
            (
                "prev_counters",
                fs(&[
                    self.prev_scale_ops,
                    self.prev_swap_s,
                    self.prev_retries,
                    self.prev_lost_tokens,
                    self.prev_recovery_s,
                    self.prev_degraded_s,
                ]),
            ),
            ("dead_reqs", Json::arr(self.dead_reqs.iter().map(|&r| ju64(r)))),
            (
                "retry_parked",
                Json::arr(self.retry_parked.iter().map(|s| match s {
                    None => Json::Null,
                    Some(r) => req_to_json(r),
                })),
            ),
            ("slow_until", fs(&self.slow_until)),
            ("slow_mult", fs(&self.slow_mult)),
            ("flap_until", Json::num(self.flap_until)),
            ("flap_added_s", Json::num(self.flap_added_s)),
        ])
    }

    /// Overlay a [`Engine::snapshot`] payload onto a freshly
    /// constructed engine (same config/options/policies — enforced by
    /// the fingerprint). Wholesale subsystem state (event queue,
    /// rollout manager, experience store, training allocator) is
    /// replaced; the live step window is rebuilt by re-pulling each
    /// in-flight step's workload from the source — sources are pure in
    /// `(seed, step)` — and overlaying its serialized progress.
    pub(crate) fn restore_from(&mut self, j: &Json, path: &str) -> Result<(), PallasError> {
        self.try_restore(j).map_err(|reason| PallasError::Checkpoint {
            path: path.to_string(),
            reason,
        })
    }

    fn try_restore(&mut self, j: &Json) -> Result<(), String> {
        let n_agents = self.n_agents();
        let want = self.fingerprint();
        let got =
            j.get("fingerprint").and_then(as_ju64).ok_or("payload missing 'fingerprint'")?;
        if got != want {
            return Err(format!(
                "config fingerprint mismatch (checkpoint {got:016x}, this experiment \
                 {want:016x}): resume needs the run's exact config, seed, and engine options"
            ));
        }

        // -- step window: re-pull workloads, overlay progress ------------
        let window_base = j
            .get("window_base")
            .and_then(Json::as_usize)
            .ok_or("payload missing 'window_base'")?;
        let window =
            j.get("window").and_then(Json::as_arr).ok_or("payload missing 'window'")?;
        if window_base + window.len() > self.total_steps {
            return Err("step window extends past the configured run length".into());
        }
        self.source.fast_forward(window_base).map_err(|e| e.to_string())?;
        let mut steps = VecDeque::with_capacity(window.len());
        for (i, cj) in window.iter().enumerate() {
            let w = self
                .source
                .next_step()
                .ok_or_else(|| format!("workload source ran dry at step {}", window_base + i))?;
            let mut ctl = Self::build_ctl(w, self.sched_mode, n_agents);
            ctl_restore(&mut ctl, cj)?;
            steps.push_back(ctl);
        }
        self.steps = steps;
        self.window_base = window_base;

        // -- wholesale subsystem state -----------------------------------
        let qj = j.get("queue").ok_or("payload missing 'queue'")?;
        let qnow = qj.get("now").and_then(Json::as_f64).ok_or("queue missing 'now'")?;
        let next_seq =
            qj.get("next_seq").and_then(as_ju64).ok_or("queue missing 'next_seq'")?;
        let mut entries = Vec::new();
        for e in qj.get("entries").and_then(Json::as_arr).ok_or("queue missing 'entries'")? {
            let e = e.as_arr().filter(|e| e.len() == 3).ok_or("bad queue entry")?;
            let t = e[0].as_f64().filter(|t| t.is_finite()).ok_or("bad queue entry time")?;
            let seq = as_ju64(&e[1]).ok_or("bad queue entry seq")?;
            if t < qnow || seq >= next_seq {
                return Err("queue entry out of range (corrupt snapshot)".into());
            }
            entries.push((t, seq, ev_from_json(&e[2])?));
        }
        self.q = EventQueue::restore(self.opts.event_queue, qnow, next_seq, entries);
        self.man =
            RolloutManager::restore_from(j.get("man").ok_or("payload missing 'man'")?, n_agents)?;
        self.store.restore_from(j.get("store").ok_or("payload missing 'store'")?)?;
        self.alloc.restore_from(j.get("alloc").ok_or("payload missing 'alloc'")?)?;

        // -- request slab (slot indices are RequestIds; free-list order
        //    decides id recycling, so both restore verbatim) -------------
        let rj = j.get("reqs").ok_or("payload missing 'reqs'")?;
        let slots = rj.get("slots").and_then(Json::as_arr).ok_or("reqs missing 'slots'")?;
        self.reqs.slots.clear();
        for s in slots {
            self.reqs.slots.push(match s {
                Json::Null => None,
                s => Some(req_from_json(s)?),
            });
        }
        self.reqs.free = rj
            .get("free")
            .and_then(Json::as_arr)
            .ok_or("reqs missing 'free'")?
            .iter()
            .map(|v| v.as_u64().map(|x| x as u32).ok_or("bad free-list entry"))
            .collect::<Result<_, _>>()?;

        // -- per-agent vectors -------------------------------------------
        let ts = j.get("tstate").and_then(Json::as_arr).ok_or("payload missing 'tstate'")?;
        if ts.len() != n_agents {
            return Err("'tstate' length mismatch".into());
        }
        for (dst, v) in self.tstate.iter_mut().zip(ts) {
            *dst = match v.as_str().ok_or("bad tstate entry")? {
                "idle" => AgentTrain::Idle,
                "swap_in" => AgentTrain::SwappingIn,
                "computing" => AgentTrain::Computing,
                "applying" => AgentTrain::Applying,
                "swap_out" => AgentTrain::SwappingOut,
                other => return Err(format!("unknown tstate '{other}'")),
            };
        }
        let busy = j
            .get("agent_busy_scaling")
            .and_then(Json::as_arr)
            .ok_or("payload missing 'agent_busy_scaling'")?;
        if busy.len() != n_agents {
            return Err("'agent_busy_scaling' length mismatch".into());
        }
        for (dst, v) in self.agent_busy_scaling.iter_mut().zip(busy) {
            *dst = v.as_bool().ok_or("bad agent_busy_scaling entry")?;
        }
        self.inst_agent.clear();
        for p in
            j.get("inst_agent").and_then(Json::as_arr).ok_or("payload missing 'inst_agent'")?
        {
            let p = p.as_arr().filter(|p| p.len() == 2).ok_or("bad inst_agent pair")?;
            let iid = p[0].as_usize().ok_or("bad instance id")?;
            let agent = p[1].as_usize().filter(|&a| a < n_agents).ok_or("bad agent id")?;
            self.inst_agent.insert(iid, agent);
        }
        let f64s = |k: &str| -> Result<Vec<f64>, String> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("payload missing '{k}'"))?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| format!("bad value in '{k}'")))
                .collect()
        };
        let slow_until = f64s("slow_until")?;
        let slow_mult = f64s("slow_mult")?;
        if slow_until.len() != n_agents || slow_mult.len() != n_agents {
            return Err("straggler-window length mismatch".into());
        }
        self.slow_until = slow_until;
        self.slow_mult = slow_mult;

        // -- counters, series, reports -----------------------------------
        self.counters.restore_vals(&f64s("counters")?)?;
        let series =
            RunSeries::from_ckpt_json(j.get("series").ok_or("payload missing 'series'")?)?;
        let keys = |m: &BTreeMap<usize, Vec<(f64, usize)>>| m.keys().copied().collect::<Vec<_>>();
        if keys(&series.processed) != keys(&self.processed_series)
            || keys(&series.queued) != keys(&self.queued_series)
        {
            return Err("tracked-agent series keys do not match this experiment's options".into());
        }
        self.processed_series = series.processed;
        self.queued_series = series.queued;
        self.busy_series = series.busy;
        self.pending.clear();
        for r in j.get("pending").and_then(Json::as_arr).ok_or("payload missing 'pending'")? {
            self.pending.push_back(StepReport::from_ckpt_json(r)?);
        }
        let prev = f64s("prev_counters")?;
        if prev.len() != 6 {
            return Err("'prev_counters' must have 6 entries".into());
        }
        self.prev_scale_ops = prev[0];
        self.prev_swap_s = prev[1];
        self.prev_retries = prev[2];
        self.prev_lost_tokens = prev[3];
        self.prev_recovery_s = prev[4];
        self.prev_degraded_s = prev[5];

        // -- fault plane & run-loop scalars ------------------------------
        self.dead_reqs = j
            .get("dead_reqs")
            .and_then(Json::as_arr)
            .ok_or("payload missing 'dead_reqs'")?
            .iter()
            .map(|v| as_ju64(v).ok_or("bad dead request id"))
            .collect::<Result<_, _>>()?;
        self.retry_parked.clear();
        for s in j
            .get("retry_parked")
            .and_then(Json::as_arr)
            .ok_or("payload missing 'retry_parked'")?
        {
            self.retry_parked.push(match s {
                Json::Null => None,
                s => Some(req_from_json(s)?),
            });
        }
        let fscalar = |k: &str| {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("payload missing '{k}'"))
        };
        self.flap_until = fscalar("flap_until")?;
        self.flap_added_s = fscalar("flap_added_s")?;
        self.sample_seq =
            j.get("sample_seq").and_then(as_ju64).ok_or("payload missing 'sample_seq'")?;
        self.guard = j.get("guard").and_then(as_ju64).ok_or("payload missing 'guard'")?;
        let histo = j.get("histo").and_then(Json::as_arr).ok_or("payload missing 'histo'")?;
        if histo.len() != EV_KINDS {
            return Err("'histo' length mismatch".into());
        }
        for (dst, v) in self.histo.iter_mut().zip(histo) {
            *dst = as_ju64(v).ok_or("bad histogram entry")?;
        }
        self.now = fscalar("now")?;
        self.done = j.get("done").and_then(Json::as_bool).ok_or("payload missing 'done'")?;
        self.failed =
            j.get("failed").and_then(Json::as_bool).ok_or("payload missing 'failed'")?;
        self.stop = match j.get("stop") {
            None | Some(Json::Null) => None,
            Some(s) => Some(StopInfo {
                t: s.get("t").and_then(Json::as_f64).ok_or("stop missing 't'")?,
                steps_completed: s
                    .get("steps_completed")
                    .and_then(Json::as_usize)
                    .ok_or("stop missing 'steps_completed'")?,
            }),
        };
        self.next_report = j
            .get("next_report")
            .and_then(Json::as_usize)
            .ok_or("payload missing 'next_report'")?;
        if self.next_report != self.window_base {
            // Retirement advances both in lockstep (`collect_completed`).
            return Err("report cursor and window base disagree (corrupt snapshot)".into());
        }
        Ok(())
    }
}

/// Event-kind count and names: the run-loop histogram is a plain
/// `[u64; EV_KINDS]` indexed by [`ev_idx`] — nothing string-keyed on
/// the event path; names attach only if the livelock guard fires
/// ([`PallasError::EventBudget`]).
const EV_KINDS: usize = 13;
const EV_NAMES: [&str; EV_KINDS] = [
    "StartStep",
    "CallDone",
    "Poll",
    "MigrationArrive",
    "SwitchToTrain",
    "SwitchToRollout",
    "SwapInDone",
    "GradDone",
    "ApplyDone",
    "SwapOutDone",
    "FaultStrike",
    "RetryDue",
    "Recover",
];

fn ev_idx(ev: &Ev) -> usize {
    match ev {
        Ev::StartStep(_) => 0,
        Ev::CallDone(_) => 1,
        Ev::Poll => 2,
        Ev::MigrationArrive { .. } => 3,
        Ev::SwitchToTrainDone(_) => 4,
        Ev::SwitchToRolloutDone(_) => 5,
        Ev::SwapInDone { .. } => 6,
        Ev::GradDone { .. } => 7,
        Ev::ApplyDone { .. } => 8,
        Ev::SwapOutDone { .. } => 9,
        Ev::FaultStrike(_) => 10,
        Ev::RetryDue(_) => 11,
        Ev::Recover { .. } => 12,
    }
}

// ---------------------------------------------------------------------------
// Checkpoint codecs (DESIGN.md §12): events, request slab, step window
// ---------------------------------------------------------------------------

fn ev_to_json(ev: &Ev) -> Json {
    let n = |v: usize| Json::num(v as f64);
    match ev {
        Ev::StartStep(s) => Json::obj(vec![("k", Json::str("start_step")), ("s", n(*s))]),
        Ev::CallDone(rid) => Json::obj(vec![("k", Json::str("call_done")), ("rid", ju64(*rid))]),
        Ev::Poll => Json::obj(vec![("k", Json::str("poll"))]),
        Ev::MigrationArrive { donor_insts, target } => Json::obj(vec![
            ("k", Json::str("migration_arrive")),
            ("donors", Json::arr(donor_insts.iter().map(|&i| n(i)))),
            ("target", n(*target)),
        ]),
        Ev::SwitchToTrainDone(s) => {
            Json::obj(vec![("k", Json::str("switch_train")), ("s", n(*s))])
        }
        Ev::SwitchToRolloutDone(s) => {
            Json::obj(vec![("k", Json::str("switch_rollout")), ("s", n(*s))])
        }
        Ev::SwapInDone { agent, step } => Json::obj(vec![
            ("k", Json::str("swap_in")),
            ("agent", n(*agent)),
            ("s", n(*step)),
        ]),
        Ev::GradDone { agent, step, n: batch } => Json::obj(vec![
            ("k", Json::str("grad")),
            ("agent", n(*agent)),
            ("s", n(*step)),
            ("n", n(*batch)),
        ]),
        Ev::ApplyDone { agent, step } => Json::obj(vec![
            ("k", Json::str("apply")),
            ("agent", n(*agent)),
            ("s", n(*step)),
        ]),
        Ev::SwapOutDone { agent } => {
            Json::obj(vec![("k", Json::str("swap_out")), ("agent", n(*agent))])
        }
        Ev::FaultStrike(i) => Json::obj(vec![("k", Json::str("fault")), ("i", n(*i))]),
        Ev::RetryDue(i) => Json::obj(vec![("k", Json::str("retry")), ("i", n(*i))]),
        Ev::Recover { agent } => {
            Json::obj(vec![("k", Json::str("recover")), ("agent", n(*agent))])
        }
    }
}

fn ev_from_json(j: &Json) -> Result<Ev, String> {
    let k = j.get("k").and_then(Json::as_str).ok_or("event missing 'k'")?;
    let u = |key: &str| {
        j.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("event '{k}' missing '{key}'"))
    };
    Ok(match k {
        "start_step" => Ev::StartStep(u("s")?),
        "call_done" => {
            Ev::CallDone(j.get("rid").and_then(as_ju64).ok_or("call_done missing 'rid'")?)
        }
        "poll" => Ev::Poll,
        "migration_arrive" => Ev::MigrationArrive {
            donor_insts: j
                .get("donors")
                .and_then(Json::as_arr)
                .ok_or("migration_arrive missing 'donors'")?
                .iter()
                .map(|v| v.as_usize().ok_or("bad donor instance id"))
                .collect::<Result<_, _>>()?,
            target: u("target")?,
        },
        "switch_train" => Ev::SwitchToTrainDone(u("s")?),
        "switch_rollout" => Ev::SwitchToRolloutDone(u("s")?),
        "swap_in" => Ev::SwapInDone { agent: u("agent")?, step: u("s")? },
        "grad" => Ev::GradDone { agent: u("agent")?, step: u("s")?, n: u("n")? },
        "apply" => Ev::ApplyDone { agent: u("agent")?, step: u("s")? },
        "swap_out" => Ev::SwapOutDone { agent: u("agent")? },
        "fault" => Ev::FaultStrike(u("i")?),
        "retry" => Ev::RetryDue(u("i")?),
        "recover" => Ev::Recover { agent: u("agent")? },
        other => return Err(format!("unknown event kind '{other}'")),
    })
}

fn req_to_json(r: &ReqInfo) -> Json {
    Json::obj(vec![
        ("step", Json::num(r.step as f64)),
        ("traj", Json::num(r.call.traj as f64)),
        ("call", Json::num(r.call.call as f64)),
        ("decode_s", Json::num(r.decode_s)),
        ("env_s", Json::num(r.env_s)),
        ("agent", Json::num(r.agent as f64)),
        ("attempt", Json::num(r.attempt as f64)),
    ])
}

fn req_from_json(j: &Json) -> Result<ReqInfo, String> {
    let u = |k: &str| {
        j.get(k).and_then(Json::as_usize).ok_or_else(|| format!("request missing '{k}'"))
    };
    let f = |k: &str| {
        j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("request missing '{k}'"))
    };
    Ok(ReqInfo {
        step: u("step")?,
        call: CallRef { traj: u("traj")?, call: u("call")? },
        decode_s: f("decode_s")?,
        env_s: f("env_s")?,
        agent: u("agent")?,
        attempt: u("attempt")? as u32,
    })
}

/// Mutable fields of a [`StepCtl`]. The workload itself is re-pulled
/// from the source at restore (sources are pure in `(seed, step)`) and
/// `expected` derives from it, so neither is serialized.
fn ctl_to_json(ctl: &StepCtl) -> Json {
    Json::obj(vec![
        ("sched", ctl.sched.snapshot()),
        ("started", Json::Bool(ctl.started)),
        ("rollout_done", Json::Bool(ctl.rollout_done)),
        ("start_t", Json::num(ctl.start_t)),
        ("rollout_end_t", Json::num(ctl.rollout_end_t)),
        ("end_t", Json::num(ctl.end_t)),
        ("grads_done", Json::arr(ctl.grads_done.iter().map(|&g| Json::num(g as f64)))),
        ("applied", Json::arr(ctl.applied.iter().map(|&b| Json::Bool(b)))),
        ("traj_remaining", Json::num(ctl.traj_remaining as f64)),
        ("traj_start", Json::arr(ctl.traj_start.iter().map(|&t| Json::num(t)))),
        ("traj_end", Json::arr(ctl.traj_end.iter().map(|&t| Json::num(t)))),
        (
            "group_pending",
            Json::arr(ctl.group_pending.iter().map(|(&(q, ci), (outstanding, toks))| {
                Json::arr([
                    Json::num(q as f64),
                    Json::num(ci as f64),
                    Json::num(*outstanding as f64),
                    Json::arr(toks.iter().map(|&t| Json::num(t))),
                ])
            })),
        ),
        ("busy_s", Json::num(ctl.busy_s)),
        ("switch_s_total", Json::num(ctl.switch_s_total)),
    ])
}

/// Overlay serialized progress onto a freshly rebuilt control block
/// (from [`Engine::build_ctl`] on the re-pulled workload).
fn ctl_restore(ctl: &mut StepCtl, j: &Json) -> Result<(), String> {
    ctl.sched.restore_from(j.get("sched").ok_or("step missing 'sched'")?)?;
    let b = |k: &str| {
        j.get(k).and_then(Json::as_bool).ok_or_else(|| format!("step missing '{k}'"))
    };
    let f = |k: &str| {
        j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("step missing '{k}'"))
    };
    ctl.started = b("started")?;
    ctl.rollout_done = b("rollout_done")?;
    ctl.start_t = f("start_t")?;
    ctl.rollout_end_t = f("rollout_end_t")?;
    ctl.end_t = f("end_t")?;
    let grads = j.get("grads_done").and_then(Json::as_arr).ok_or("step missing 'grads_done'")?;
    if grads.len() != ctl.grads_done.len() {
        return Err("step 'grads_done' length mismatch".into());
    }
    for (dst, v) in ctl.grads_done.iter_mut().zip(grads) {
        *dst = v.as_usize().ok_or("bad grads_done entry")?;
    }
    let applied = j.get("applied").and_then(Json::as_arr).ok_or("step missing 'applied'")?;
    if applied.len() != ctl.applied.len() {
        return Err("step 'applied' length mismatch".into());
    }
    for (dst, v) in ctl.applied.iter_mut().zip(applied) {
        *dst = v.as_bool().ok_or("bad applied entry")?;
    }
    ctl.traj_remaining = j
        .get("traj_remaining")
        .and_then(Json::as_usize)
        .ok_or("step missing 'traj_remaining'")?;
    for (key, dst) in [("traj_start", &mut ctl.traj_start), ("traj_end", &mut ctl.traj_end)] {
        let arr =
            j.get(key).and_then(Json::as_arr).ok_or_else(|| format!("step missing '{key}'"))?;
        if arr.len() != dst.len() {
            return Err(format!("step '{key}' length mismatch"));
        }
        for (d, v) in dst.iter_mut().zip(arr) {
            *d = v.as_f64().ok_or_else(|| format!("bad {key} entry"))?;
        }
    }
    let groups =
        j.get("group_pending").and_then(Json::as_arr).ok_or("step missing 'group_pending'")?;
    let mut gp = BTreeMap::new();
    for g in groups {
        let g = g.as_arr().filter(|g| g.len() == 4).ok_or("bad group_pending entry")?;
        let q = g[0].as_usize().ok_or("bad group query")?;
        let ci = g[1].as_usize().ok_or("bad group turn")?;
        let outstanding = g[2].as_usize().ok_or("bad group outstanding")?;
        let toks = g[3]
            .as_arr()
            .ok_or("bad group tokens")?
            .iter()
            .map(|t| t.as_f64().ok_or("bad group token"))
            .collect::<Result<Vec<f64>, _>>()?;
        gp.insert((q, ci), (outstanding, toks));
    }
    ctl.group_pending = gp;
    ctl.busy_s = f("busy_s")?;
    ctl.switch_s_total = f("switch_s_total")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, Framework, WorkloadConfig};

    fn small_cfg(fw: Framework) -> ExperimentConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 4;
        let mut cfg = ExperimentConfig::new(wl, fw);
        cfg.steps = 2;
        cfg
    }

    /// `try_simulate` unwrapped — the non-panicking entry all tests
    /// drive (the deprecated `simulate` keeps one dedicated test).
    fn sim(cfg: &ExperimentConfig, opts: &SimOptions) -> SimOutcome {
        try_simulate(cfg, opts).unwrap()
    }

    fn run(fw: Framework) -> SimOutcome {
        sim(&small_cfg(fw), &SimOptions::default())
    }

    #[test]
    fn all_frameworks_complete() {
        for fw in Framework::all_baselines() {
            let out = run(fw);
            assert_eq!(out.reports.len(), 2, "{}", fw.name);
            for r in &out.reports {
                assert!(r.e2e_s > 0.0);
                assert!(r.rollout_s > 0.0);
                assert!(r.tokens > 0.0);
                assert!(r.e2e_s >= r.rollout_s * 0.5, "{}", fw.name);
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(Framework::flexmarl());
        let b = run(Framework::flexmarl());
        for (x, y) in a.reports.iter().zip(&b.reports) {
            assert_eq!(x.e2e_s, y.e2e_s);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn ordering_masrl_slowest_flexmarl_fastest() {
        // Paper-shaped load (skew + queueing) — the regime where the
        // co-design pays off; the tiny uncontended configs of the other
        // tests deliberately do not show it.
        let mut cfg = small_cfg(Framework::flexmarl());
        cfg.workload.queries_per_step = 4;
        cfg.workload.group_size = 16;
        cfg.steps = 1;
        let opts = SimOptions {
            instances_per_agent: 2,
            ..SimOptions::default()
        };
        let t = |fw: Framework| {
            let mut c = cfg.clone();
            c.framework = fw;
            sim(&c, &opts).total_s
        };
        let mas = t(Framework::mas_rl());
        let dist = t(Framework::dist_rl());
        let flex = t(Framework::flexmarl());
        assert!(mas > dist, "MAS-RL {mas} ≤ DistRL {dist}");
        assert!(dist > flex, "DistRL {dist} ≤ FlexMARL {flex}");
    }

    #[test]
    fn async_pipeline_hides_training() {
        let flex = run(Framework::flexmarl());
        let noasync = run(Framework::flexmarl_no_async());
        // Non-overlapped training time must be smaller with the pipeline.
        let t_async: f64 = flex.reports.iter().map(|r| r.train_s).sum();
        let t_sync: f64 = noasync.reports.iter().map(|r| r.train_s).sum();
        assert!(
            t_async < t_sync,
            "async train tail {t_async} ≥ sync {t_sync}"
        );
    }

    #[test]
    fn tokens_are_framework_invariant() {
        // Same workload → same generated tokens, whatever the system.
        let a = run(Framework::mas_rl());
        let b = run(Framework::flexmarl());
        for (x, y) in a.reports.iter().zip(&b.reports) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn load_balancer_triggers_scaling_on_skew() {
        let mut cfg = small_cfg(Framework::flexmarl());
        cfg.workload.queries_per_step = 4;
        cfg.workload.group_size = 16;
        cfg.steps = 1;
        let opts = SimOptions {
            instances_per_agent: 2,
            ..SimOptions::default()
        };
        let out = sim(&cfg, &opts);
        assert!(out.reports[0].scale_ops > 0, "no scaling on skewed load");
    }

    #[test]
    fn flexmarl_beats_no_balancing_on_skew() {
        let mut base = small_cfg(Framework::flexmarl());
        base.workload.queries_per_step = 4;
        base.workload.group_size = 16;
        base.steps = 1;
        let mut nolb = base.clone();
        nolb.framework = Framework::flexmarl_no_balancing();
        let opts = SimOptions {
            instances_per_agent: 2,
            ..SimOptions::default()
        };
        let t_lb = sim(&base, &opts).total_s;
        let t_nolb = sim(&nolb, &opts).total_s;
        assert!(t_lb < t_nolb, "LB {t_lb} ≥ no-LB {t_nolb}");
    }

    #[test]
    fn all_scenarios_complete_on_small_config() {
        for name in crate::workload::scenario::names() {
            let mut cfg = small_cfg(Framework::flexmarl());
            cfg.workload.scenario = name.to_string();
            let out = sim(&cfg, &SimOptions::default());
            assert_eq!(out.reports.len(), 2, "{name}");
            assert!(out.total_s > 0.0, "{name}");
            assert_eq!(out.reports[0].scenario, name);
            assert!(out.reports.iter().all(|r| r.tokens > 0.0), "{name}");
        }
    }

    #[test]
    fn trace_replay_reproduces_generated_run() {
        let mut cfg = small_cfg(Framework::flexmarl());
        cfg.workload.scenario = "core_skew".to_string();
        let generated = sim(&cfg, &SimOptions::default());

        let tr = crate::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
        let path = std::env::temp_dir().join("flexmarl_simloop_replay.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        let mut replay_cfg = cfg.clone();
        replay_cfg.workload.trace = Some(path.clone());
        let replayed = sim(&replay_cfg, &SimOptions::default());
        let _ = std::fs::remove_file(&path);

        assert_eq!(generated.total_s, replayed.total_s);
        for (a, b) in generated.reports.iter().zip(&replayed.reports) {
            assert_eq!(a.e2e_s, b.e2e_s);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.busy_device_s, b.busy_device_s);
            assert_eq!(a.agent_calls, b.agent_calls);
            assert_eq!(a.trajectory_latencies, b.trajectory_latencies);
        }
    }

    #[test]
    fn trace_scenario_is_authoritative_on_replay() {
        // Regression: a hetero_scale trace replayed under a config
        // whose scenario field was left at "baseline" must still shape
        // the mixed ensemble (models drive decode/train pricing) — the
        // trace header wins, and metrics match the recording run.
        let mut cfg = small_cfg(Framework::flexmarl());
        cfg.workload.scenario = "hetero_scale".to_string();
        let generated = sim(&cfg, &SimOptions::default());
        let tr = crate::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
        let path = std::env::temp_dir().join("flexmarl_simloop_authoritative.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();

        let mut replay_cfg = small_cfg(Framework::flexmarl()); // scenario: baseline
        replay_cfg.workload.trace = Some(path.clone());
        let (resolved, _) = resolve_workload(&replay_cfg).unwrap();
        let replayed = sim(&replay_cfg, &SimOptions::default());
        let _ = std::fs::remove_file(&path);

        assert_eq!(resolved.workload.scenario, "hetero_scale");
        assert!(resolved
            .workload
            .agents
            .iter()
            .any(|a| a.model.params_b != 14.0));
        assert_eq!(generated.total_s, replayed.total_s);
        assert_eq!(replayed.reports[0].scenario, "hetero_scale");
    }

    #[test]
    fn mismatched_trace_rejected() {
        let mut cfg = small_cfg(Framework::flexmarl());
        // Record with 8 MA agents, replay against 6-agent CA: must error.
        let tr = crate::workload::Trace::record(&cfg.workload, cfg.seed, 1).unwrap();
        let path = std::env::temp_dir().join("flexmarl_simloop_mismatch.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        cfg.workload = WorkloadConfig::ca();
        cfg.workload.trace = Some(path.clone());
        let err = resolve_workload(&cfg).unwrap_err();
        let _ = std::fs::remove_file(&path);
        assert!(
            matches!(
                err,
                PallasError::TraceAgentMismatch {
                    trace_agents: 8,
                    config_agents: 6,
                    ..
                }
            ),
            "{err:?}"
        );
        assert!(err.to_string().contains("agents"), "{err}");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_simulate_still_matches_try_simulate() {
        // Back-compat: the panicking wrapper must keep returning the
        // exact same simulation until it is removed.
        let cfg = small_cfg(Framework::flexmarl());
        let a = simulate(&cfg, &SimOptions::default());
        let b = sim(&cfg, &SimOptions::default());
        assert_eq!(a.total_s, b.total_s);
        for (x, y) in a.reports.iter().zip(&b.reports) {
            assert_eq!(x.to_json().to_pretty(), y.to_json().to_pretty());
        }
    }

    #[test]
    fn utilization_flexmarl_beats_masrl() {
        let flex = run(Framework::flexmarl());
        let mas = run(Framework::mas_rl());
        let u_flex = flex.reports[0].utilization();
        let u_mas = mas.reports[0].utilization();
        assert!(
            u_flex > u_mas,
            "FlexMARL util {u_flex} ≤ MAS-RL {u_mas}"
        );
    }
}
