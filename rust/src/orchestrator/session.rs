//! Incremental engine stepping (DESIGN.md §9).
//!
//! A [`Session`] is the engine opened up at the step boundary: instead
//! of one opaque `run() -> SimOutcome`, the caller advances the
//! simulation one MARL step at a time and receives each step's
//! finalized [`StepReport`] as it completes. Run-to-completion entries
//! ([`crate::experiment::Experiment::run`],
//! [`super::try_simulate`]) are thin drains over a session, so a
//! session-driven run is bit-identical to a monolithic one by
//! construction — and `tests/session.rs` pins it across the golden
//! grid anyway.
//!
//! Observation and early stop go through the typed sink API
//! ([`super::events`]): attach sinks before stepping, and any sink
//! returning [`ControlFlow::Stop`](super::events::ControlFlow::Stop)
//! cuts the run at the next event boundary with a well-formed partial
//! outcome.

use super::events::EventSink;
use super::simloop::{Engine, SimOutcome, StopInfo};
use crate::config::ExperimentConfig;
use crate::error::PallasError;
use crate::metrics::StepReport;
use crate::util::json::Json;

/// A resumable simulation: step it, watch it, stop it.
///
/// Obtain one from [`crate::experiment::Experiment::session`]. Typical
/// shape:
///
/// ```no_run
/// use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
/// use flexmarl::experiment::Experiment;
/// use flexmarl::orchestrator::ProgressSink;
///
/// let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
/// let mut session = Experiment::new(cfg).steps(3).build()?.session()?;
/// session.add_sink(Box::new(ProgressSink::stderr(3)));
/// while let Some(report) = session.step()? {
///     eprintln!("live: {:.0} tok/s", report.throughput_tps());
/// }
/// let outcome = session.finish();
/// # Ok::<(), flexmarl::error::PallasError>(())
/// ```
pub struct Session {
    engine: Engine,
    /// Every report yielded so far — what [`Session::finish`] hands
    /// back as the outcome's report list.
    reports: Vec<StepReport>,
}

// The serving plane (DESIGN.md §13) ships whole sessions across its
// worker threads; pin the capability at the definition so a future
// non-Send field fails here, not in a distant ServePlane bound.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Session>();
};

impl Session {
    pub(crate) fn from_engine(engine: Engine) -> Session {
        Session {
            engine,
            reports: Vec::new(),
        }
    }

    /// Attach an observer. Sinks see every event from this point on;
    /// attach before the first [`Session::step`] to observe the whole
    /// run. Sinks cannot perturb the simulation (the determinism rule,
    /// DESIGN.md §9) — only truncate it via
    /// [`ControlFlow::Stop`](super::events::ControlFlow::Stop).
    pub fn add_sink(&mut self, sink: Box<dyn EventSink>) {
        self.engine.add_sink(sink);
    }

    /// Advance the simulation until exactly one more MARL step
    /// completes and return its report; `None` once the run is over
    /// (all steps done, or a sink stopped it). The yielded sequence,
    /// driven to exhaustion, is bit-identical to
    /// [`crate::experiment::Experiment::run`]'s report list.
    ///
    /// # Errors
    ///
    /// [`PallasError::EventBudget`] if the run loop's livelock guard
    /// trips — yielded once; the session then reports itself done.
    pub fn step(&mut self) -> Result<Option<StepReport>, PallasError> {
        match self.engine.pump_step()? {
            Some(report) => {
                self.reports.push(report.clone());
                self.maybe_checkpoint()?;
                Ok(Some(report))
            }
            None => Ok(None),
        }
    }

    /// Consume the session into an outcome over everything that
    /// completed: the yielded reports, total virtual time, the
    /// run-wide series, and the stop record if a sink cut the run.
    /// Valid at any point — mid-run it is a well-formed partial
    /// outcome.
    pub fn finish(self) -> SimOutcome {
        self.engine.into_outcome(self.reports)
    }

    /// Drain the session to exhaustion and finish — the monolithic
    /// `run()` expressed over the streaming API. Pumps the engine
    /// directly into the outcome's report list (no per-step clone —
    /// that copy exists only for reports [`Session::step`] hands out
    /// interactively), so a batch drain allocates exactly what the
    /// retired monolithic loop did.
    pub fn run_to_end(mut self) -> Result<SimOutcome, PallasError> {
        while let Some(report) = self.engine.pump_step()? {
            self.reports.push(report);
            self.maybe_checkpoint()?;
        }
        Ok(self.finish())
    }

    /// Steps completed (and yielded) so far.
    pub fn steps_completed(&self) -> usize {
        self.reports.len()
    }

    /// Current virtual time (timestamp of the last handled event).
    pub fn now(&self) -> f64 {
        self.engine.now()
    }

    /// `true` once [`Session::step`] can only return `None`: every
    /// step reported, a sink stopped the run, or the event budget
    /// tripped.
    pub fn is_done(&self) -> bool {
        self.engine.is_done()
    }

    /// The early-stop record, once a sink has requested one.
    pub fn stop_info(&self) -> Option<&StopInfo> {
        self.engine.stop_info()
    }

    /// The resolved config this session is simulating.
    pub fn config(&self) -> &ExperimentConfig {
        self.engine.config()
    }

    /// Every report yielded so far, in step order. After a
    /// [`Session::restore`] this includes the restored prefix — what a
    /// resumed CLI run re-emits before streaming new steps.
    pub fn reports(&self) -> &[StepReport] {
        &self.reports
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Complete session state as a checkpoint payload: the engine's
    /// mutable state plus every report yielded so far (full fidelity —
    /// a resumed run re-yields byte-identical metrics).
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("engine", self.engine.snapshot()),
            ("reports", Json::arr(self.reports.iter().map(|r| r.to_ckpt_json()))),
        ])
    }

    /// Write a crash-consistent checkpoint file ([`crate::ckpt`]):
    /// temp file + atomic rename, so a kill at any instant leaves
    /// either the previous complete checkpoint or the new one.
    pub fn save(&self, path: &str) -> Result<(), PallasError> {
        crate::ckpt::write_file(path, &self.snapshot())
    }

    /// Restore a [`Session::snapshot`] payload onto a freshly built
    /// session (same config/seed/options — enforced by the payload's
    /// config fingerprint). `path` names the source file in errors;
    /// pass `""` for in-memory payloads.
    ///
    /// The contract (pinned in `tests/ckpt.rs` and CI): a run killed at
    /// any step and resumed from its last checkpoint yields the same
    /// remaining reports, byte for byte, as the uninterrupted run.
    pub fn restore(mut self, payload: &Json, path: &str) -> Result<Session, PallasError> {
        let bad = |reason: &str| PallasError::Checkpoint {
            path: path.to_string(),
            reason: reason.to_string(),
        };
        self.engine
            .restore_from(payload.get("engine").ok_or_else(|| bad("payload missing 'engine'"))?, path)?;
        let reports = payload
            .get("reports")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("payload missing 'reports'"))?;
        self.reports = reports
            .iter()
            .map(StepReport::from_ckpt_json)
            .collect::<Result<Vec<_>, String>>()
            .map_err(|reason| bad(&reason))?;
        Ok(self)
    }

    /// Write `cfg.checkpoint`'s periodic snapshot if one is due —
    /// called after every completed step by both [`Session::step`] and
    /// [`Session::run_to_end`] (the batch drain bypasses `step`).
    fn maybe_checkpoint(&mut self) -> Result<(), PallasError> {
        let ck = &self.engine.config().checkpoint;
        let Some(every) = ck.every else {
            return Ok(());
        };
        if every == 0 || self.reports.len() % every != 0 {
            return Ok(());
        }
        let path = ck.path();
        self.save(&path)
    }
}
