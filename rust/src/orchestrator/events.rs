//! Typed observer API for the step engine (DESIGN.md §9).
//!
//! The engine is a streaming system — the micro-batch asynchronous
//! pipeline moves experience between rollout and training continuously
//! — and this module is how callers watch it move: an [`EngineEvent`]
//! is emitted at every named decision point of the run loop, and an
//! [`EventSink`] receives each one together with the virtual time it
//! happened at.
//!
//! **Sink contract (the determinism rule):** sinks observe, they never
//! mutate. A sink gets `&EngineEvent` — shared borrows into live engine
//! state — and its only channel back into the engine is the returned
//! [`ControlFlow`]: `Stop` asks the run to halt after the current event
//! is fully handled. Attaching any combination of sinks therefore
//! cannot change a single bit of the simulation; it can only truncate
//! it. (`tests/session.rs` pins this.)
//!
//! Shipped sinks:
//!
//! * [`NullSink`] — ignores everything (dispatch-overhead baseline for
//!   the `session::` bench group).
//! * [`ProgressSink`] — human-readable step/migration progress lines,
//!   stderr by default (`--progress` on the CLI).
//! * [`JsonlSink`] — one compact [`StepReport`] JSON line per finished
//!   step, streamed as the run advances (`--emit jsonl`).
//! * [`TraceSink`] — captures the per-step workloads flowing through
//!   the engine into a [`Trace`], replacing the old special-cased
//!   recording path; the recorded trace round-trips bit-for-bit.
//! * [`BudgetSink`] — early stop on a step, generated-token, or
//!   virtual-time budget.
//! * [`WallClockSink`] — early stop on *real* elapsed time
//!   (`--max-wall-s`); the one shipped sink whose stop point is
//!   machine-dependent by design.

use crate::config::ExperimentConfig;
use crate::error::PallasError;
use crate::metrics::StepReport;
use crate::workload::{StepWorkload, Trace};
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What a sink tells the engine after observing an event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlFlow {
    /// Keep running.
    Continue,
    /// Halt the run after the current event finishes handling. The
    /// outcome stays well-formed: every step completed so far keeps its
    /// report, and [`crate::orchestrator::SimOutcome::stop`] records
    /// where the run was cut.
    Stop,
}

/// One observable decision of the step engine. Borrowed fields point
/// into live engine state — copy out what you need to keep.
///
/// `#[non_exhaustive]`: future PRs may add kinds; sinks must have a
/// catch-all arm.
#[non_exhaustive]
#[derive(Debug)]
pub enum EngineEvent<'a> {
    /// A MARL step's rollout began; `workload` is the step's resolved
    /// per-call workload (what [`TraceSink`] records).
    StepStarted {
        step: usize,
        workload: &'a StepWorkload,
    },
    /// A step fully completed (rollout done, every agent's update
    /// applied); `report` is the step's finalized metrics — the same
    /// value [`crate::orchestrator::Session::step`] yields.
    StepFinished {
        step: usize,
        report: &'a StepReport,
    },
    /// Training admitted a micro batch of `n` samples for `agent`
    /// (§4.3 pipeline admission).
    MicroBatchAdmitted {
        step: usize,
        agent: usize,
        n: usize,
    },
    /// The balancer decided to migrate `n_instances` inference
    /// instances from `donor` to `target` (§5.2).
    MigrationPlanned {
        donor: usize,
        target: usize,
        n_instances: usize,
    },
    /// A scaler poll tick concluded; `migrated` says whether this tick
    /// planned a migration, `busy_devices` is the sampled load.
    ScalerDecision {
        migrated: bool,
        busy_devices: usize,
    },
    /// An agent's training state began swapping onto devices (§6.1).
    SwapIn {
        agent: usize,
        step: usize,
        cost_s: f64,
    },
    /// An agent's training state began swapping off (suspend-to-
    /// destroy).
    SwapOut { agent: usize, cost_s: f64 },
    /// A colocated pool began a phase switch for `step` (`to_train`:
    /// offload inference / onload training, else the reverse).
    PhaseSwitch { step: usize, to_train: bool },
    /// A planned fault struck (DESIGN.md §10). `kind` is the
    /// [`crate::fault::FaultKind`] name; `agent` is set for faults that
    /// target one agent.
    FaultInjected {
        kind: &'static str,
        agent: Option<usize>,
    },
    /// A request displaced by an instance loss was re-dispatched by the
    /// retry recovery policy; `attempt` counts this request's retries
    /// (1-based at first re-dispatch).
    RequestRetried { agent: usize, attempt: u32 },
    /// The degrade recovery policy re-provisioned a replacement
    /// instance for `agent` after its recovery delay.
    InstanceRecovered { agent: usize, instance: usize },
    /// A mid-run cluster resize was applied: `delta` requested change,
    /// `instances` actually added (or drained, for negative `delta`).
    ClusterResized { delta: i64, instances: usize },
}

/// Observer of [`EngineEvent`]s. `t` is virtual simulation time.
///
/// Implementations must be `Send` (sweep cells run on worker threads)
/// and must not assume any event kind arrives: treat the enum as open.
pub trait EventSink: Send {
    /// Observe one event; return [`ControlFlow::Stop`] to request an
    /// early halt.
    fn on_event(&mut self, t: f64, ev: &EngineEvent<'_>) -> ControlFlow;
}

/// The engine's sink collection. Empty by default — the no-sink fast
/// path is one `is_empty` branch per decision point, which the
/// `session::` hotpath bench group pins at ~zero overhead.
#[derive(Default)]
pub(crate) struct SinkSet {
    sinks: Vec<Box<dyn EventSink>>,
    stop: bool,
}

impl SinkSet {
    pub(crate) fn from_sinks(sinks: Vec<Box<dyn EventSink>>) -> SinkSet {
        SinkSet { sinks, stop: false }
    }

    pub(crate) fn push(&mut self, sink: Box<dyn EventSink>) {
        self.sinks.push(sink);
    }

    /// Fan one event out to every sink; latch the stop flag if any
    /// sink requests it (all sinks still see the event).
    #[inline]
    pub(crate) fn emit(&mut self, t: f64, ev: &EngineEvent<'_>) {
        if self.sinks.is_empty() {
            return;
        }
        for s in &mut self.sinks {
            if s.on_event(t, ev) == ControlFlow::Stop {
                self.stop = true;
            }
        }
    }

    #[inline]
    pub(crate) fn stop_requested(&self) -> bool {
        self.stop
    }
}

// ---------------------------------------------------------------------------
// Shipped sinks
// ---------------------------------------------------------------------------

/// Ignores every event. Exists so the observer dispatch itself can be
/// benchmarked against the no-sink inlined loop.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn on_event(&mut self, _t: f64, _ev: &EngineEvent<'_>) -> ControlFlow {
        ControlFlow::Continue
    }
}

/// Human-readable progress lines (step start/finish, migrations).
/// Writes to stderr by default so stdout stays machine-parseable —
/// the CLI's `--progress` contract is that stdout and `--json` output
/// are byte-identical with or without it.
pub struct ProgressSink {
    total_steps: usize,
    w: Box<dyn Write + Send>,
}

impl ProgressSink {
    /// Progress to stderr; `total_steps` labels lines as `k/N`.
    pub fn stderr(total_steps: usize) -> ProgressSink {
        ProgressSink::new(total_steps, Box::new(std::io::stderr()))
    }

    pub fn new(total_steps: usize, w: Box<dyn Write + Send>) -> ProgressSink {
        ProgressSink { total_steps, w }
    }
}

impl EventSink for ProgressSink {
    fn on_event(&mut self, t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
        // Progress output is best-effort: a closed pipe must not kill
        // the simulation.
        let _ = match ev {
            EngineEvent::StepStarted { step, .. } => writeln!(
                self.w,
                "[t={t:9.1}s] step {}/{}: rollout started",
                step + 1,
                self.total_steps
            ),
            EngineEvent::StepFinished { step, report } => writeln!(
                self.w,
                "[t={t:9.1}s] step {}/{}: done  e2e {:.1}s  {:.0} tok/s  \
                 scale_ops {}",
                step + 1,
                self.total_steps,
                report.e2e_s,
                report.throughput_tps(),
                report.scale_ops
            ),
            EngineEvent::MigrationPlanned {
                donor,
                target,
                n_instances,
            } => writeln!(
                self.w,
                "[t={t:9.1}s] balancer: {n_instances} instance(s) \
                 agent{donor} -> agent{target}"
            ),
            EngineEvent::FaultInjected { kind, agent } => match agent {
                Some(a) => writeln!(self.w, "[t={t:9.1}s] fault: {kind} (agent{a})"),
                None => writeln!(self.w, "[t={t:9.1}s] fault: {kind}"),
            },
            EngineEvent::InstanceRecovered { agent, .. } => writeln!(
                self.w,
                "[t={t:9.1}s] recovery: agent{agent} re-provisioned"
            ),
            EngineEvent::ClusterResized { delta, instances } => writeln!(
                self.w,
                "[t={t:9.1}s] resize: delta {delta:+} -> {instances} instance(s) changed"
            ),
            _ => Ok(()),
        };
        ControlFlow::Continue
    }
}

/// Streams one compact JSON line per finished step — exactly
/// [`StepReport::to_json`] — as the run advances. Concatenating the
/// streamed lines of a session-driven run reproduces, byte for byte,
/// the per-step reports of a monolithic run (a CI job diffs the two).
pub struct JsonlSink {
    w: Box<dyn Write + Send>,
    /// Set once a write or flush fails; the sink stops the run and
    /// goes quiet instead of emitting a gap-ridden stream.
    failed: bool,
}

impl JsonlSink {
    /// Stream to stdout (the CLI's `--emit jsonl`).
    pub fn stdout() -> JsonlSink {
        JsonlSink::new(Box::new(std::io::stdout()))
    }

    pub fn new(w: Box<dyn Write + Send>) -> JsonlSink {
        JsonlSink { w, failed: false }
    }
}

impl EventSink for JsonlSink {
    fn on_event(&mut self, _t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
        if self.failed {
            // Keep requesting the stop until the engine honors it —
            // and never write another (now out-of-sequence) line.
            return ControlFlow::Stop;
        }
        if let EngineEvent::StepFinished { report, .. } = ev {
            // Flush per line: the point of streaming is that a consumer
            // sees each step as it lands, not at process exit. A failed
            // write or flush (closed pipe, full disk) is not swallowed:
            // the stream contract is one complete line per completed
            // step, so the sink warns once and stops the run cleanly —
            // the partial outcome stays well-formed.
            let res = writeln!(self.w, "{}", report.to_json().to_string())
                .and_then(|()| self.w.flush());
            if let Err(e) = res {
                self.failed = true;
                eprintln!("jsonl sink: write failed, stopping run: {e}");
                return ControlFlow::Stop;
            }
        }
        ControlFlow::Continue
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        // Best-effort final flush so a buffered writer dropped with the
        // engine doesn't silently lose its tail.
        if !self.failed {
            let _ = self.w.flush();
        }
    }
}

/// A shared in-memory byte sink: the serving plane's per-session
/// capture target (DESIGN.md §13). Clone one half into a
/// `JsonlSink::new(Box::new(buf.clone()))` handed to the session, keep
/// the other half, and read the finished session's exact JSONL bytes
/// back with [`CaptureBuffer::contents`] — the stream a `--emit jsonl`
/// run of the same config would have written to stdout, byte for byte.
#[derive(Debug, Clone, Default)]
pub struct CaptureBuffer {
    bytes: std::sync::Arc<std::sync::Mutex<Vec<u8>>>,
}

impl CaptureBuffer {
    pub fn new() -> CaptureBuffer {
        CaptureBuffer::default()
    }

    /// Snapshot of everything written so far.
    pub fn contents(&self) -> Vec<u8> {
        self.bytes.lock().expect("capture buffer poisoned").clone()
    }
}

impl Write for CaptureBuffer {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.bytes
            .lock()
            .expect("capture buffer poisoned")
            .extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Shared state behind a [`TraceSink`]/[`TraceHandle`] pair.
struct TraceState {
    workload: String,
    scenario: String,
    seed: u64,
    n_agents: usize,
    steps: Vec<StepWorkload>,
}

/// Records the per-step workloads the engine executes into a
/// [`Trace`] — trace capture as a plain observer instead of a special
/// path beside the run loop. Because the engine replays the workloads
/// [`crate::orchestrator::resolve_workload`] produced, the captured
/// trace is bit-identical to `Trace::record` on the same resolved
/// config (pinned in `tests/session.rs`).
pub struct TraceSink {
    shared: Arc<Mutex<TraceState>>,
}

/// Caller-side handle to a [`TraceSink`]'s captured steps: the sink is
/// boxed away into the engine, the handle stays with you.
pub struct TraceHandle {
    shared: Arc<Mutex<TraceState>>,
}

impl TraceSink {
    /// Build a recording sink for a *resolved* experiment config (the
    /// one [`crate::experiment::Experiment::config`] returns — its
    /// scenario field is already the canonical preset name the trace
    /// header must carry).
    pub fn new(cfg: &ExperimentConfig) -> (TraceSink, TraceHandle) {
        let shared = Arc::new(Mutex::new(TraceState {
            workload: cfg.workload.name.clone(),
            scenario: cfg.workload.scenario.clone(),
            seed: cfg.seed,
            n_agents: cfg.workload.agents.len(),
            steps: Vec::new(),
        }));
        (
            TraceSink {
                shared: Arc::clone(&shared),
            },
            TraceHandle { shared },
        )
    }
}

impl EventSink for TraceSink {
    fn on_event(&mut self, _t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
        if let EngineEvent::StepStarted { workload, .. } = ev {
            let mut st = self.shared.lock().unwrap();
            st.steps.push((*workload).clone());
        }
        ControlFlow::Continue
    }
}

impl TraceHandle {
    /// Assemble the captured steps into a [`Trace`]. Mirrors the
    /// validation `Trace::record` applies: at least one step must have
    /// been captured and the seed must round-trip through the JSONL
    /// header.
    pub fn trace(&self) -> Result<Trace, PallasError> {
        let st = self.shared.lock().unwrap();
        if st.steps.is_empty() {
            return Err(PallasError::Trace(
                "cannot record a zero-step trace (nothing to replay)".into(),
            ));
        }
        // A sink attached after step 0 started (or mid-run) captured a
        // suffix, not a replayable trace — steps must be contiguous
        // from 0, exactly what replay's parser will demand.
        if st.steps.iter().enumerate().any(|(i, w)| w.step != i) {
            return Err(PallasError::Trace(
                "trace capture missed leading steps (sink attached mid-run?)".into(),
            ));
        }
        if st.seed > crate::workload::trace::MAX_SEED {
            return Err(PallasError::Trace(format!(
                "seed {} exceeds 2^53 and cannot round-trip through the JSONL header",
                st.seed
            )));
        }
        Ok(Trace {
            workload: st.workload.clone(),
            scenario: st.scenario.clone(),
            seed: st.seed,
            n_agents: st.n_agents,
            steps: st.steps.clone(),
        })
    }

    /// Steps captured so far (grows while a session is stepping).
    pub fn steps_recorded(&self) -> usize {
        self.shared.lock().unwrap().steps.len()
    }
}

/// Early stop on simulation-side budgets: completed steps, generated
/// tokens, or virtual seconds. Budgets compose — the first one
/// exceeded stops the run.
#[derive(Debug, Clone, Copy, Default)]
pub struct BudgetSink {
    max_steps: Option<usize>,
    max_tokens: Option<f64>,
    max_sim_s: Option<f64>,
    steps_done: usize,
    tokens: f64,
}

impl BudgetSink {
    pub fn new() -> BudgetSink {
        BudgetSink::default()
    }

    /// Stop after `n` completed steps.
    pub fn max_steps(mut self, n: usize) -> BudgetSink {
        self.max_steps = Some(n);
        self
    }

    /// Stop once at least `tokens` have been generated (checked at
    /// step boundaries — the report carries the step's token count).
    pub fn max_tokens(mut self, tokens: f64) -> BudgetSink {
        self.max_tokens = Some(tokens);
        self
    }

    /// Stop once virtual time reaches `s` seconds.
    pub fn max_sim_s(mut self, s: f64) -> BudgetSink {
        self.max_sim_s = Some(s);
        self
    }
}

impl EventSink for BudgetSink {
    fn on_event(&mut self, t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
        if let EngineEvent::StepFinished { report, .. } = ev {
            self.steps_done += 1;
            self.tokens += report.tokens;
        }
        let step_hit = self.max_steps.is_some_and(|m| self.steps_done >= m);
        let tok_hit = self.max_tokens.is_some_and(|m| self.tokens >= m);
        let sim_hit = self.max_sim_s.is_some_and(|m| t >= m);
        if step_hit || tok_hit || sim_hit {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        }
    }
}

/// Early stop on *real* elapsed time (the CLI's `--max-wall-s`).
/// Deliberately nondeterministic: where the run is cut depends on the
/// machine — completed steps are still bit-exact, there are just fewer
/// of them on a slower box.
pub struct WallClockSink {
    deadline: Instant,
}

impl WallClockSink {
    pub fn after(budget: Duration) -> WallClockSink {
        WallClockSink {
            deadline: Instant::now() + budget,
        }
    }
}

impl EventSink for WallClockSink {
    fn on_event(&mut self, _t: f64, _ev: &EngineEvent<'_>) -> ControlFlow {
        if Instant::now() >= self.deadline {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(tokens: f64) -> StepReport {
        StepReport {
            framework: "X".into(),
            tokens,
            e2e_s: 10.0,
            ..StepReport::default()
        }
    }

    #[test]
    fn budget_sink_stops_on_each_axis() {
        let r = report(100.0);
        let fin = EngineEvent::StepFinished { step: 0, report: &r };

        let mut by_steps = BudgetSink::new().max_steps(2);
        assert_eq!(by_steps.on_event(1.0, &fin), ControlFlow::Continue);
        assert_eq!(by_steps.on_event(2.0, &fin), ControlFlow::Stop);

        let mut by_tokens = BudgetSink::new().max_tokens(150.0);
        assert_eq!(by_tokens.on_event(1.0, &fin), ControlFlow::Continue);
        assert_eq!(by_tokens.on_event(2.0, &fin), ControlFlow::Stop);

        let poll = EngineEvent::ScalerDecision { migrated: false, busy_devices: 0 };
        let mut by_sim = BudgetSink::new().max_sim_s(5.0);
        assert_eq!(by_sim.on_event(4.9, &poll), ControlFlow::Continue);
        assert_eq!(by_sim.on_event(5.0, &poll), ControlFlow::Stop);
    }

    #[test]
    fn sink_set_latches_stop_but_keeps_fanning_out() {
        struct Counter(Arc<Mutex<usize>>, ControlFlow);
        impl EventSink for Counter {
            fn on_event(&mut self, _t: f64, _ev: &EngineEvent<'_>) -> ControlFlow {
                *self.0.lock().unwrap() += 1;
                self.1
            }
        }
        let a = Arc::new(Mutex::new(0));
        let b = Arc::new(Mutex::new(0));
        let mut set = SinkSet::from_sinks(vec![
            Box::new(Counter(Arc::clone(&a), ControlFlow::Stop)),
            Box::new(Counter(Arc::clone(&b), ControlFlow::Continue)),
        ]);
        let r = report(1.0);
        set.emit(0.0, &EngineEvent::StepFinished { step: 0, report: &r });
        assert!(set.stop_requested());
        // The stopping sink did not shadow the later one.
        assert_eq!(*a.lock().unwrap(), 1);
        assert_eq!(*b.lock().unwrap(), 1);
    }

    #[test]
    fn jsonl_sink_emits_one_line_per_finished_step() {
        struct Buf(Arc<Mutex<Vec<u8>>>);
        impl Write for Buf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut sink = JsonlSink::new(Box::new(Buf(Arc::clone(&buf))));
        let r = report(42.0);
        let wl = StepWorkload {
            step: 0,
            trajectories: vec![],
        };
        sink.on_event(
            0.0,
            &EngineEvent::StepStarted {
                step: 0,
                workload: &wl,
            },
        );
        sink.on_event(1.0, &EngineEvent::StepFinished { step: 0, report: &r });
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert_eq!(text, format!("{}\n", r.to_json().to_string()));
    }

    #[test]
    fn jsonl_sink_stops_on_write_failure_and_stays_stopped() {
        struct Broken;
        impl Write for Broken {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "pipe closed",
                ))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut sink = JsonlSink::new(Box::new(Broken));
        let r = report(1.0);
        let fin = EngineEvent::StepFinished { step: 0, report: &r };
        // First failure: warn + stop.
        assert_eq!(sink.on_event(0.0, &fin), ControlFlow::Stop);
        // Latched: every later event keeps requesting the stop, and the
        // sink never attempts another write (Broken would not mind, but
        // a half-working writer would interleave out-of-order lines).
        assert_eq!(sink.on_event(1.0, &fin), ControlFlow::Stop);
    }

    #[test]
    fn capture_buffer_collects_jsonl_lines_through_a_clone() {
        // Serving-plane capture: the sink writes through one clone, the
        // plane reads back through the other — same underlying bytes.
        let buf = CaptureBuffer::new();
        let mut sink = JsonlSink::new(Box::new(buf.clone()));
        let r = report(3.0);
        sink.on_event(1.0, &EngineEvent::StepFinished { step: 0, report: &r });
        sink.on_event(2.0, &EngineEvent::StepFinished { step: 1, report: &r });
        drop(sink);
        let text = String::from_utf8(buf.contents()).unwrap();
        let want = format!("{}\n", r.to_json().to_string());
        assert_eq!(text, format!("{want}{want}"));
    }
}
