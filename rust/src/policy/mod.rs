//! Framework policy objects: the engine's pluggable decision points.
//!
//! The simloop used to interpret [`Framework`]'s capability booleans
//! inline — every flag combination was an `if`-branch woven through the
//! event handlers, and a framework that did not decompose into those
//! five booleans (LlamaRL's fully-async distributed pipeline, RollArt's
//! disaggregated multi-task scheduling — see PAPERS.md) had nowhere to
//! plug in. This module extracts each branch family into a trait, one
//! per paper mechanism:
//!
//! | trait | paper mechanism | decides |
//! |---|---|---|
//! | [`PipelinePolicy`] | §4.3 micro-batch async pipeline | when training may consume samples; whether steps overlap |
//! | [`BalancePolicy`]  | §5.2 hierarchical load balancing | whether a poll tick migrates inference instances |
//! | [`AllocPolicy`]    | §4.1 disaggregation + §6.1 agent-centric binding | pool layout, binding mode, colocation contention |
//! | [`SamplePolicy`]   | §5.1 dependency-driven parallel sampling | trajectory scheduling mode, instance provisioning |
//! | [`RecoveryPolicy`] | fault plane (DESIGN.md §10) | what happens when an inference instance is lost |
//!
//! A [`PolicyBundle`] is a named set of one impl per trait — the
//! engine consumes a bundle and nothing else. [`Framework::policies`]
//! derives the canonical bundle from the capability flags, so the four
//! baselines and both ablations keep working unchanged; a *new*
//! framework is just a new bundle handed to
//! [`crate::experiment::Experiment`] — no engine edits (DESIGN.md §8
//! shows a complete registration in under 50 lines).
//!
//! **Bit-identity contract:** for every flag combination, the derived
//! bundle reproduces the retired inline branches exactly — the
//! golden-grid integration test (`tests/golden_grid.rs`) pins
//! flag-derived and hand-assembled bundles to byte-identical
//! [`crate::metrics::StepReport`] JSON across all baselines × scenario
//! presets.
//!
//! (Not to be confused with [`crate::runtime::policy`], the *model*
//! policy executing on PJRT — these objects govern the system, not the
//! network.)

use crate::config::Framework;
use crate::rollout::{plan_migration, MigrationPlan, Mode};

// ---------------------------------------------------------------------------
// PipelinePolicy (§4.3)
// ---------------------------------------------------------------------------

/// When may training consume experience, and do MARL steps overlap?
///
/// Governs the retired `async_pipeline` / `one_step_async_rollout`
/// branches: micro-batch admission during rollout, the MARTI-style
/// stale-parameter prefetch of the next step, and whether reported
/// per-step E2E time is amortized over overlapped steps.
pub trait PipelinePolicy: Send + Sync {
    /// Short impl name (diagnostics, DESIGN.md §8 table).
    fn name(&self) -> &'static str;

    /// May an agent start gradient work while the step's rollout is
    /// still in flight (micro-batch asynchronous pipeline, §4.3)?
    /// `false` = full-batch synchronous training behind the rollout
    /// barrier.
    ///
    /// Cross-trait interaction: a colocated pool
    /// ([`AllocPolicy::dedicated_pools`] = `false`) that does not
    /// overlap steps physically cannot train and generate at once —
    /// the engine's phase-alternation gate then defers training to the
    /// rollout barrier *regardless* of this flag (same rule the
    /// capability flags always had). Early admission needs dedicated
    /// pools or a step-overlapping pipeline.
    fn admits_during_rollout(&self) -> bool;

    /// Does step *s+1*'s rollout launch at step *s*'s rollout boundary
    /// with stale parameters (MARTI's one-step-async overlap)? Returns
    /// the fraction of the colocated phase-switch cost charged for the
    /// pipelined half-switch that restores instance weights; `None` =
    /// steps never overlap.
    fn next_step_prefetch(&self) -> Option<f64>;

    /// Steps overlap in wall time, so per-step E2E must be amortized
    /// over the whole run (and pool accounting must provision rollout
    /// and training capacity simultaneously).
    fn overlaps_steps(&self) -> bool {
        self.next_step_prefetch().is_some()
    }
}

/// Full-batch synchronous training: gradients only after the step's
/// rollout barrier (MAS-RL, DistRL, the `w/o async` ablation).
#[derive(Debug, Clone, Copy, Default)]
pub struct SyncPipeline;

impl PipelinePolicy for SyncPipeline {
    fn name(&self) -> &'static str {
        "sync"
    }
    fn admits_during_rollout(&self) -> bool {
        false
    }
    fn next_step_prefetch(&self) -> Option<f64> {
        None
    }
}

/// Micro-batch asynchronous pipeline (§4.3): training consumes each
/// micro batch as soon as its GRPO groups land in the store, hiding
/// gradient time inside the rollout (FlexMARL).
#[derive(Debug, Clone, Copy, Default)]
pub struct MicroBatchAsync;

impl PipelinePolicy for MicroBatchAsync {
    fn name(&self) -> &'static str {
        "micro_batch_async"
    }
    fn admits_during_rollout(&self) -> bool {
        true
    }
    fn next_step_prefetch(&self) -> Option<f64> {
        None
    }
}

/// MARTI-style one-step-async rollout: step *s+1* generates with
/// stale-by-one parameters while step *s* trains; the half phase-switch
/// restoring instance weights is pipelined into the overlap.
#[derive(Debug, Clone, Copy)]
pub struct OneStepAsync {
    /// Also admit micro batches during the rollout (no named framework
    /// combines both — kept so every flag combination stays derivable).
    pub admit_during_rollout: bool,
    /// Fraction of the phase-switch cost charged for the pipelined
    /// weight restore (MARTI: 0.5).
    pub prefetch_switch_frac: f64,
}

impl Default for OneStepAsync {
    fn default() -> Self {
        OneStepAsync {
            admit_during_rollout: false,
            prefetch_switch_frac: 0.5,
        }
    }
}

impl PipelinePolicy for OneStepAsync {
    fn name(&self) -> &'static str {
        "one_step_async"
    }
    fn admits_during_rollout(&self) -> bool {
        self.admit_during_rollout
    }
    fn next_step_prefetch(&self) -> Option<f64> {
        Some(self.prefetch_switch_frac)
    }
}

// ---------------------------------------------------------------------------
// BalancePolicy (§5.2)
// ---------------------------------------------------------------------------

/// Per-agent load observed at one scaler poll tick — everything an
/// inter-agent balancer may consult when deciding to migrate inference
/// instances.
#[derive(Debug)]
pub struct LoadSnapshot<'a> {
    /// Queued (not yet running) requests per agent.
    pub queue_lens: &'a [usize],
    /// Inference instances currently serving each agent.
    pub instance_counts: &'a [usize],
    /// The configured disparity threshold Δ (§5.2).
    pub delta_threshold: usize,
    /// Agents already mid-migration (donor or target) — excluded from
    /// new plans to prevent oscillation.
    pub busy_scaling: &'a [bool],
}

/// Should this poll tick migrate inference instances between agents?
///
/// Governs the retired `load_balancing` branch around
/// [`plan_migration`] in the simloop's poll handler.
pub trait BalancePolicy: Send + Sync {
    /// Short impl name (diagnostics, DESIGN.md §8 table).
    fn name(&self) -> &'static str;

    /// Whether this policy can ever migrate. The engine skips snapshot
    /// assembly entirely when `false`, keeping static frameworks'
    /// poll ticks allocation-free.
    fn enabled(&self) -> bool {
        true
    }

    /// Propose a migration for the observed load, or `None` to leave
    /// placements alone this tick.
    fn plan(&self, load: &LoadSnapshot<'_>) -> Option<MigrationPlan>;
}

/// Hierarchical inter-agent balancing (§5.2): migrate instances from
/// the least-loaded donor to the most overloaded agent whenever the
/// queue-length disparity exceeds Δ (FlexMARL).
#[derive(Debug, Clone, Copy, Default)]
pub struct HierarchicalBalance;

impl BalancePolicy for HierarchicalBalance {
    fn name(&self) -> &'static str {
        "hierarchical"
    }
    fn plan(&self, load: &LoadSnapshot<'_>) -> Option<MigrationPlan> {
        plan_migration(
            load.queue_lens,
            load.instance_counts,
            load.delta_threshold,
            load.busy_scaling,
        )
    }
}

/// No inter-agent balancing: instances stay where they were provisioned
/// (MAS-RL, DistRL, MARTI, the `w/o balancing` ablation).
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticPlacement;

impl BalancePolicy for StaticPlacement {
    fn name(&self) -> &'static str {
        "static"
    }
    fn enabled(&self) -> bool {
        false
    }
    fn plan(&self, _load: &LoadSnapshot<'_>) -> Option<MigrationPlan> {
        None
    }
}

// ---------------------------------------------------------------------------
// AllocPolicy (§4.1 + §6.1)
// ---------------------------------------------------------------------------

/// How rollout and training share (or don't share) the device pool, and
/// how training process groups bind to it.
///
/// Governs the retired `disaggregated` / `agent_centric` branches: pool
/// provisioning, phase-switch alternation, the colocated decode
/// contention penalty, and static-partition vs on-demand binding in
/// [`crate::training::AgentCentricAllocator`].
pub trait AllocPolicy: Send + Sync {
    /// Short impl name (diagnostics, DESIGN.md §8 table).
    fn name(&self) -> &'static str;

    /// Dedicated rollout and training pools (§4.1 disaggregation) vs a
    /// single colocated pool time-multiplexed with onload/offload phase
    /// switches. A colocated pool under a non-overlapping pipeline
    /// enforces strict phase alternation: training waits for the
    /// rollout barrier even if the pipeline would admit micro batches
    /// early (see [`PipelinePolicy::admits_during_rollout`]).
    fn dedicated_pools(&self) -> bool;

    /// Agent-centric on-demand binding (§6.1): process groups hold
    /// devices only while they have work (suspend-to-destroy between),
    /// vs static per-agent partitions held for the whole run.
    fn on_demand_binding(&self) -> bool;

    /// Decode-time multiplier charged while training shares the pool
    /// with generation (colocated HBM/compute contention, §4.1);
    /// `1.0` when pools are dedicated.
    fn decode_contention_mult(&self) -> f64 {
        if self.dedicated_pools() {
            1.0
        } else {
            1.3
        }
    }
}

/// FlexMARL's allocation: dedicated pools + agent-centric on-demand
/// binding with state swap (§6.1).
#[derive(Debug, Clone, Copy, Default)]
pub struct AgentCentricAlloc;

impl AllocPolicy for AgentCentricAlloc {
    fn name(&self) -> &'static str {
        "agent_centric"
    }
    fn dedicated_pools(&self) -> bool {
        true
    }
    fn on_demand_binding(&self) -> bool {
        true
    }
}

/// Disaggregated pools with static per-agent training partitions
/// (DistRL — the Obs. 3 configuration whose utilization collapses).
#[derive(Debug, Clone, Copy, Default)]
pub struct DisaggregatedStatic;

impl AllocPolicy for DisaggregatedStatic {
    fn name(&self) -> &'static str {
        "disaggregated_static"
    }
    fn dedicated_pools(&self) -> bool {
        true
    }
    fn on_demand_binding(&self) -> bool {
        false
    }
}

/// One colocated pool, static partitions, onload/offload at each phase
/// switch (MAS-RL, MARTI).
#[derive(Debug, Clone, Copy, Default)]
pub struct ColocatedStatic;

impl AllocPolicy for ColocatedStatic {
    fn name(&self) -> &'static str {
        "colocated_static"
    }
    fn dedicated_pools(&self) -> bool {
        false
    }
    fn on_demand_binding(&self) -> bool {
        false
    }
}

/// Colocated pool with on-demand binding — no named framework ships
/// it, but the flag square must stay derivable and it is a useful
/// mixed-bundle ingredient (the golden-grid custom-framework test runs
/// one).
#[derive(Debug, Clone, Copy, Default)]
pub struct ColocatedOnDemand;

impl AllocPolicy for ColocatedOnDemand {
    fn name(&self) -> &'static str {
        "colocated_on_demand"
    }
    fn dedicated_pools(&self) -> bool {
        false
    }
    fn on_demand_binding(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// SamplePolicy (§5.1)
// ---------------------------------------------------------------------------

/// How trajectory generation is scheduled and how many inference
/// instances each agent gets at startup.
///
/// Governs the retired `parallel_sampling` branches: scheduler
/// [`Mode`] selection and the MAS-RL one-engine-per-agent provisioning
/// special case.
pub trait SamplePolicy: Send + Sync {
    /// Short impl name (diagnostics, DESIGN.md §8 table).
    fn name(&self) -> &'static str;

    /// Trajectory-scheduler mode for a step, given the workload's
    /// configured inter-query concurrency.
    fn mode(&self, inter_query: usize) -> Mode;

    /// Inference instances provisioned per agent at startup, given the
    /// engine-knob default ([`crate::orchestrator::SimOptions`]'s
    /// `instances_per_agent`).
    fn instances_per_agent(&self, configured: usize) -> usize;
}

/// Dependency-driven parallel sampling (§5.1): candidates progress
/// independently, `inter_query` queries concurrently admitted, a
/// replicated instance pool per agent.
#[derive(Debug, Clone, Copy, Default)]
pub struct ParallelSampling;

impl SamplePolicy for ParallelSampling {
    fn name(&self) -> &'static str {
        "parallel"
    }
    fn mode(&self, inter_query: usize) -> Mode {
        Mode::Parallel { inter_query }
    }
    fn instances_per_agent(&self, configured: usize) -> usize {
        configured
    }
}

/// Serial query processing with per-turn barriers (the MAS-RL execution
/// model): one query at a time, one inference engine per agent.
#[derive(Debug, Clone, Copy, Default)]
pub struct SerialTurnBarrier;

impl SamplePolicy for SerialTurnBarrier {
    fn name(&self) -> &'static str {
        "serial_turn_barrier"
    }
    fn mode(&self, _inter_query: usize) -> Mode {
        Mode::SerialQueries
    }
    fn instances_per_agent(&self, _configured: usize) -> usize {
        1
    }
}

// ---------------------------------------------------------------------------
// RecoveryPolicy (fault plane, DESIGN.md §10)
// ---------------------------------------------------------------------------

/// What the engine does with the work an instance loss displaced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RecoveryAction {
    /// Abort the run with a typed
    /// [`crate::error::PallasError::InstanceLost`].
    Abort,
    /// Re-dispatch the displaced requests onto surviving instances,
    /// each after [`RecoveryPolicy::backoff_s`] for its attempt count.
    Retry,
    /// Degrade gracefully: re-dispatch displaced requests immediately
    /// onto surviving capacity (re-planned via [`BalancePolicy`] when
    /// enabled), then re-provision a replacement instance after
    /// `delay_s` of degraded capacity.
    Reprovision { delay_s: f64 },
}

/// How a framework reacts when fault injection kills an inference
/// instance (DESIGN.md §10).
///
/// The engine consults this once per lost instance, *after* it has
/// already extracted the displaced requests from the
/// [`crate::rollout::RolloutManager`] and invalidated genuinely stale
/// experience-store rows — the policy only decides the fate of the
/// displaced work and of the lost capacity. Implementations must be
/// pure functions of their inputs (the determinism contract: recovery
/// decisions may not depend on wall clock, thread count, or ambient
/// randomness).
pub trait RecoveryPolicy: Send + Sync {
    /// Short impl name (diagnostics, DESIGN.md §8/§10 tables).
    fn name(&self) -> &'static str;

    /// Decide the fate of `instance` (serving `agent`), lost at virtual
    /// time `t`.
    fn on_instance_lost(&self, t: f64, agent: usize, instance: usize) -> RecoveryAction;

    /// Backoff before re-dispatching a request on its `attempt`-th
    /// retry (0-based). Only consulted for [`RecoveryAction::Retry`].
    fn backoff_s(&self, attempt: u32) -> f64 {
        let _ = attempt;
        0.0
    }
}

/// Abort on the first instance loss (strict reproducibility runs: a
/// faulted run is not the run you asked for).
#[derive(Debug, Clone, Copy, Default)]
pub struct FailFast;

impl RecoveryPolicy for FailFast {
    fn name(&self) -> &'static str {
        "fail_fast"
    }
    fn on_instance_lost(&self, _t: f64, _agent: usize, _instance: usize) -> RecoveryAction {
        RecoveryAction::Abort
    }
}

/// Re-dispatch displaced requests with capped exponential backoff —
/// lost in-flight decode work is re-done from scratch on surviving
/// instances.
#[derive(Debug, Clone, Copy)]
pub struct RetryBackoff {
    /// First-retry delay; attempt `k` waits `base * 2^k`, capped.
    pub base_delay_s: f64,
    /// Upper bound on any single backoff.
    pub cap_s: f64,
}

impl Default for RetryBackoff {
    fn default() -> Self {
        RetryBackoff {
            base_delay_s: 0.5,
            cap_s: 8.0,
        }
    }
}

impl RecoveryPolicy for RetryBackoff {
    fn name(&self) -> &'static str {
        "retry_backoff"
    }
    fn on_instance_lost(&self, _t: f64, _agent: usize, _instance: usize) -> RecoveryAction {
        RecoveryAction::Retry
    }
    fn backoff_s(&self, attempt: u32) -> f64 {
        (self.base_delay_s * f64::powi(2.0, attempt.min(16) as i32)).min(self.cap_s)
    }
}

/// Graceful degradation: displaced work re-plans immediately onto
/// surviving instances (the [`BalancePolicy`] re-balances around the
/// hole when enabled), and a replacement instance is re-provisioned
/// after a configurable recovery delay.
#[derive(Debug, Clone, Copy)]
pub struct DegradeRebalance {
    /// Virtual seconds of degraded capacity before the replacement
    /// instance comes up.
    pub recovery_delay_s: f64,
}

impl Default for DegradeRebalance {
    fn default() -> Self {
        DegradeRebalance {
            recovery_delay_s: 30.0,
        }
    }
}

impl RecoveryPolicy for DegradeRebalance {
    fn name(&self) -> &'static str {
        "degrade_rebalance"
    }
    fn on_instance_lost(&self, _t: f64, _agent: usize, _instance: usize) -> RecoveryAction {
        RecoveryAction::Reprovision {
            delay_s: self.recovery_delay_s,
        }
    }
}

/// Look up a canonical recovery policy by name (the config section's
/// `faults.recovery` key). Accepts the same spelling normalization as
/// [`crate::config::framework_by_name`].
pub fn recovery_by_name(name: &str) -> Option<Box<dyn RecoveryPolicy>> {
    let n: String = name
        .to_ascii_lowercase()
        .chars()
        .filter(|c| !['-', '_', ' '].contains(c))
        .collect();
    Some(match n.as_str() {
        "failfast" | "abort" => Box::new(FailFast),
        "retry" | "retrybackoff" => Box::new(RetryBackoff::default()),
        "degrade" | "degraderebalance" => Box::new(DegradeRebalance::default()),
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// PolicyBundle
// ---------------------------------------------------------------------------

/// A named set of one impl per policy trait — everything the engine
/// consults about framework behaviour. Derive one from capability flags
/// with [`Framework::policies`], or assemble one by hand to register a
/// framework the flags cannot express (DESIGN.md §8).
pub struct PolicyBundle {
    /// Label reported as [`crate::metrics::StepReport::framework`].
    /// Flag-derived bundles carry the framework's name, keeping report
    /// JSON byte-identical to the retired inline engine.
    pub name: String,
    /// §4.3 pipeline behaviour.
    pub pipeline: Box<dyn PipelinePolicy>,
    /// §5.2 inter-agent balancing.
    pub balance: Box<dyn BalancePolicy>,
    /// §4.1/§6.1 pool layout and binding.
    pub alloc: Box<dyn AllocPolicy>,
    /// §5.1 sampling schedule.
    pub sample: Box<dyn SamplePolicy>,
    /// Fault-plane recovery (DESIGN.md §10). Defaults to
    /// [`RetryBackoff`] — only consulted when a fault plan actually
    /// loses an instance, so fault-free runs never observe it.
    pub recovery: Box<dyn RecoveryPolicy>,
}

impl PolicyBundle {
    /// Assemble a custom bundle. Prefer [`Framework::policies`] for the
    /// named baselines.
    pub fn new(
        name: impl Into<String>,
        pipeline: Box<dyn PipelinePolicy>,
        balance: Box<dyn BalancePolicy>,
        alloc: Box<dyn AllocPolicy>,
        sample: Box<dyn SamplePolicy>,
    ) -> PolicyBundle {
        PolicyBundle {
            name: name.into(),
            pipeline,
            balance,
            alloc,
            sample,
            recovery: Box::new(RetryBackoff::default()),
        }
    }

    /// Replace the fault-recovery policy (builder style).
    pub fn with_recovery(mut self, recovery: Box<dyn RecoveryPolicy>) -> PolicyBundle {
        self.recovery = recovery;
        self
    }

    /// One-line summary of the bundle's composition (diagnostics).
    pub fn describe(&self) -> String {
        format!(
            "{}: pipeline={} balance={} alloc={} sample={} recovery={}",
            self.name,
            self.pipeline.name(),
            self.balance.name(),
            self.alloc.name(),
            self.sample.name(),
            self.recovery.name()
        )
    }
}

impl std::fmt::Debug for PolicyBundle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.describe())
    }
}

impl Framework {
    /// Derive the canonical policy bundle for this framework's
    /// capability flags. Every flag combination maps — including the
    /// squares no named constructor produces — so hand-tweaked
    /// [`Framework`] values keep behaving exactly as the retired
    /// inline branches did.
    pub fn policies(&self) -> PolicyBundle {
        let pipeline: Box<dyn PipelinePolicy> = if self.one_step_async_rollout {
            Box::new(OneStepAsync {
                admit_during_rollout: self.async_pipeline,
                ..OneStepAsync::default()
            })
        } else if self.async_pipeline {
            Box::new(MicroBatchAsync)
        } else {
            Box::new(SyncPipeline)
        };
        let balance: Box<dyn BalancePolicy> = if self.load_balancing {
            Box::new(HierarchicalBalance)
        } else {
            Box::new(StaticPlacement)
        };
        let alloc: Box<dyn AllocPolicy> = match (self.disaggregated, self.agent_centric) {
            (true, true) => Box::new(AgentCentricAlloc),
            (true, false) => Box::new(DisaggregatedStatic),
            (false, false) => Box::new(ColocatedStatic),
            (false, true) => Box::new(ColocatedOnDemand),
        };
        let sample: Box<dyn SamplePolicy> = if self.parallel_sampling {
            Box::new(ParallelSampling)
        } else {
            Box::new(SerialTurnBarrier)
        };
        // Canonical recovery default: a framework that can re-balance
        // load around a hole degrades gracefully; everything else
        // retries with backoff. Fail-fast is only ever explicit.
        let recovery: Box<dyn RecoveryPolicy> = if self.load_balancing {
            Box::new(DegradeRebalance::default())
        } else {
            Box::new(RetryBackoff::default())
        };
        PolicyBundle::new(self.name, pipeline, balance, alloc, sample).with_recovery(recovery)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Every trait is exercised through a trait *object* — the engine
    // only ever sees `Box<dyn …>`, so dyn dispatch is what must be
    // pinned, not the concrete impls.

    #[test]
    fn pipeline_policy_through_trait_objects() {
        let sync: Box<dyn PipelinePolicy> = Box::new(SyncPipeline);
        let asy: Box<dyn PipelinePolicy> = Box::new(MicroBatchAsync);
        let one: Box<dyn PipelinePolicy> = Box::new(OneStepAsync::default());
        assert!(!sync.admits_during_rollout() && !sync.overlaps_steps());
        assert_eq!(sync.next_step_prefetch(), None);
        assert!(asy.admits_during_rollout() && !asy.overlaps_steps());
        assert!(!one.admits_during_rollout() && one.overlaps_steps());
        assert_eq!(one.next_step_prefetch(), Some(0.5));
    }

    #[test]
    fn balance_policy_through_trait_objects() {
        let lb: Box<dyn BalancePolicy> = Box::new(HierarchicalBalance);
        let none: Box<dyn BalancePolicy> = Box::new(StaticPlacement);
        // A grossly skewed queue with idle donors must trigger the
        // hierarchical plan and must not trigger the static one.
        let queue_lens = [40usize, 0, 0, 0];
        let counts = [2usize, 2, 2, 2];
        let busy = [false; 4];
        let snap = LoadSnapshot {
            queue_lens: &queue_lens,
            instance_counts: &counts,
            delta_threshold: 5,
            busy_scaling: &busy,
        };
        let plan = lb.plan(&snap).expect("skew above delta must migrate");
        assert_eq!(plan.target, 0);
        assert!(lb.enabled());
        assert!(!none.enabled());
        assert!(none.plan(&snap).is_none());
        // The hierarchical policy is exactly plan_migration.
        assert_eq!(
            lb.plan(&snap),
            plan_migration(&queue_lens, &counts, 5, &busy)
        );
    }

    #[test]
    fn alloc_policy_through_trait_objects() {
        let table: [(Box<dyn AllocPolicy>, bool, bool, f64); 4] = [
            (Box::new(AgentCentricAlloc), true, true, 1.0),
            (Box::new(DisaggregatedStatic), true, false, 1.0),
            (Box::new(ColocatedStatic), false, false, 1.3),
            (Box::new(ColocatedOnDemand), false, true, 1.3),
        ];
        for (p, dedicated, on_demand, mult) in table {
            assert_eq!(p.dedicated_pools(), dedicated, "{}", p.name());
            assert_eq!(p.on_demand_binding(), on_demand, "{}", p.name());
            assert_eq!(p.decode_contention_mult(), mult, "{}", p.name());
        }
    }

    #[test]
    fn sample_policy_through_trait_objects() {
        let par: Box<dyn SamplePolicy> = Box::new(ParallelSampling);
        let ser: Box<dyn SamplePolicy> = Box::new(SerialTurnBarrier);
        assert_eq!(par.mode(4), Mode::Parallel { inter_query: 4 });
        assert_eq!(par.instances_per_agent(2), 2);
        assert_eq!(ser.mode(4), Mode::SerialQueries);
        assert_eq!(ser.instances_per_agent(2), 1);
    }

    #[test]
    fn derived_bundles_match_the_flag_matrix() {
        // The derivation must reproduce the retired inline branches for
        // every baseline: admits == async_pipeline, overlap == one-step,
        // pools/binding == disaggregated/agent_centric, and so on.
        for fw in Framework::all_baselines()
            .into_iter()
            .chain([Framework::flexmarl_no_balancing(), Framework::flexmarl_no_async()])
        {
            let b = fw.policies();
            assert_eq!(b.name, fw.name);
            assert_eq!(b.pipeline.admits_during_rollout(), fw.async_pipeline, "{}", fw.name);
            assert_eq!(b.pipeline.overlaps_steps(), fw.one_step_async_rollout, "{}", fw.name);
            assert_eq!(b.alloc.dedicated_pools(), fw.disaggregated, "{}", fw.name);
            assert_eq!(b.alloc.on_demand_binding(), fw.agent_centric, "{}", fw.name);
            assert_eq!(b.balance.enabled(), fw.load_balancing, "{}", fw.name);
            let expect_mult = if fw.disaggregated { 1.0 } else { 1.3 };
            assert_eq!(b.alloc.decode_contention_mult(), expect_mult, "{}", fw.name);
            match b.sample.mode(7) {
                Mode::Parallel { inter_query } => {
                    assert!(fw.parallel_sampling, "{}", fw.name);
                    assert_eq!(inter_query, 7);
                }
                Mode::SerialQueries => assert!(!fw.parallel_sampling, "{}", fw.name),
            }
        }
        // The unreachable-by-constructor squares still derive sanely.
        let mut odd = Framework::marti();
        odd.async_pipeline = true;
        let b = odd.policies();
        assert!(b.pipeline.admits_during_rollout() && b.pipeline.overlaps_steps());
        let mut coloc = Framework::flexmarl();
        coloc.disaggregated = false;
        let b = coloc.policies();
        assert!(!b.alloc.dedicated_pools() && b.alloc.on_demand_binding());
    }

    #[test]
    fn describe_names_every_axis() {
        let d = Framework::flexmarl().policies().describe();
        assert!(d.contains("FlexMARL"), "{d}");
        assert!(d.contains("micro_batch_async"), "{d}");
        assert!(d.contains("hierarchical"), "{d}");
        assert!(d.contains("agent_centric"), "{d}");
        assert!(d.contains("parallel"), "{d}");
        assert!(d.contains("recovery=degrade_rebalance"), "{d}");
    }

    #[test]
    fn recovery_policy_through_trait_objects() {
        let ff: Box<dyn RecoveryPolicy> = Box::new(FailFast);
        let rb: Box<dyn RecoveryPolicy> = Box::new(RetryBackoff::default());
        let dg: Box<dyn RecoveryPolicy> = Box::new(DegradeRebalance::default());
        assert_eq!(ff.on_instance_lost(1.0, 0, 3), RecoveryAction::Abort);
        assert_eq!(rb.on_instance_lost(1.0, 0, 3), RecoveryAction::Retry);
        assert_eq!(
            dg.on_instance_lost(1.0, 0, 3),
            RecoveryAction::Reprovision { delay_s: 30.0 }
        );
        // Capped exponential backoff: 0.5, 1, 2, 4, 8, 8, … and the
        // attempt exponent itself saturates (no pow overflow).
        assert_eq!(rb.backoff_s(0), 0.5);
        assert_eq!(rb.backoff_s(1), 1.0);
        assert_eq!(rb.backoff_s(3), 4.0);
        assert_eq!(rb.backoff_s(4), 8.0);
        assert_eq!(rb.backoff_s(40), 8.0);
        assert_eq!(rb.backoff_s(u32::MAX), 8.0);
        // Abort/Retry never consult backoff, but the default is 0.
        assert_eq!(ff.backoff_s(5), 0.0);
    }

    #[test]
    fn recovery_by_name_normalizes_spellings() {
        for (spelling, want) in [
            ("fail_fast", "fail_fast"),
            ("FailFast", "fail_fast"),
            ("abort", "fail_fast"),
            ("retry", "retry_backoff"),
            ("retry-backoff", "retry_backoff"),
            ("degrade", "degrade_rebalance"),
            ("Degrade Rebalance", "degrade_rebalance"),
        ] {
            let p = recovery_by_name(spelling)
                .unwrap_or_else(|| panic!("'{spelling}' should resolve"));
            assert_eq!(p.name(), want, "{spelling}");
        }
        assert!(recovery_by_name("crash_only_the_good_ones").is_none());
    }

    #[test]
    fn derived_recovery_defaults_follow_load_balancing() {
        // Load-balancing frameworks can re-plan around a hole, so they
        // degrade gracefully; static-placement frameworks retry.
        for fw in Framework::all_baselines() {
            let want = if fw.load_balancing {
                "degrade_rebalance"
            } else {
                "retry_backoff"
            };
            assert_eq!(fw.policies().recovery.name(), want, "{}", fw.name);
        }
        // Hand-assembled bundles default to retry and can override.
        let b = Framework::mas_rl().policies();
        let b = b.with_recovery(Box::new(FailFast));
        assert_eq!(b.recovery.name(), "fail_fast");
    }
}
