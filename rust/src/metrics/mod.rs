//! Evaluation metrics (§8.1): E2E time, speedup, token throughput, agent
//! rollout load, and hardware utilization — plus the time series behind
//! Figs. 1b, 8, 9, 10.
//!
//! Recording is allocation-free: counter keys are interned to integer
//! ids before the event loop starts ([`intern`]) and strings are only
//! rendered here, at report time.

pub mod intern;

pub use intern::{Counters, MetricId};

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Result of simulating (or really running) one MARL step.
///
/// Every field is finalized the moment the step completes — this is
/// what lets [`crate::orchestrator::Session::step`] stream a report per
/// step with no end-of-run pass. Run-wide data (the poll-sampled time
/// series behind Figs. 1b/8/9/10) lives in [`RunSeries`] on
/// [`crate::orchestrator::SimOutcome`] instead.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub framework: String,
    pub workload: String,
    /// Scenario preset the workload was shaped by ("baseline" = as
    /// configured); see [`crate::workload::scenario`].
    pub scenario: String,
    /// Wall/virtual seconds for the whole step.
    pub e2e_s: f64,
    /// Time until the last trajectory finished generating.
    pub rollout_s: f64,
    /// Non-overlapped policy-training time (time the step spends in
    /// training *after* rollouts are done — what Fig. 7 plots).
    pub train_s: f64,
    /// Everything else: phase switching, weight sync, swaps.
    pub other_s: f64,
    /// Total generated tokens.
    pub tokens: f64,
    /// Device-seconds of useful work (rollout decode + training compute).
    pub busy_device_s: f64,
    /// Devices available to the run (for utilization).
    pub pool_devices: usize,
    /// Per-agent processed-call counts.
    pub agent_calls: Vec<usize>,
    /// Interaction latencies of completed trajectories (Fig. 1a).
    pub trajectory_latencies: Vec<f64>,
    /// Scaling operations performed (inter-agent LB) during this step's
    /// completion window (from the previous step's completion to this
    /// one's).
    pub scale_ops: usize,
    /// State swap seconds incurred (training engine) during this step's
    /// completion window.
    pub swap_s: f64,
    /// Fault-plane recovery accounting (DESIGN.md §10) — all zero on a
    /// fault-free run. Requests re-dispatched by the retry recovery
    /// policy during this step's completion window.
    pub retries: usize,
    /// Generated tokens discarded because their instance died mid-
    /// decode (the work is re-done from scratch on retry/degrade).
    pub lost_tokens: f64,
    /// Backoff seconds the retry policy scheduled before re-dispatch.
    pub recovery_s: f64,
    /// Virtual seconds of degraded capacity (instance lost, replacement
    /// not yet re-provisioned) charged by the degrade policy.
    pub degraded_s: f64,
}

/// Poll-sampled time series covering the whole run — the data behind
/// Figs. 1b, 8, 9 and 10. These span step boundaries (the scaler keeps
/// polling across steps), so they belong to the run, not to any one
/// [`StepReport`]; they come back on
/// [`crate::orchestrator::SimOutcome::series`] (and keep growing while
/// a [`crate::orchestrator::Session`] is live).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunSeries {
    /// (time, processed_calls) per tracked agent (Figs. 8/9).
    pub processed: BTreeMap<usize, Vec<(f64, usize)>>,
    /// (time, queued_requests) per tracked agent (Fig. 1b).
    pub queued: BTreeMap<usize, Vec<(f64, usize)>>,
    /// (time, busy_devices) samples (Fig. 10).
    pub busy: Vec<(f64, usize)>,
}

impl StepReport {
    pub fn throughput_tps(&self) -> f64 {
        if self.e2e_s > 0.0 {
            self.tokens / self.e2e_s
        } else {
            0.0
        }
    }

    pub fn utilization(&self) -> f64 {
        if self.pool_devices == 0 || self.e2e_s == 0.0 {
            0.0
        } else {
            (self.busy_device_s / (self.pool_devices as f64 * self.e2e_s)).min(1.0)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("framework", Json::str(self.framework.clone())),
            ("workload", Json::str(self.workload.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("e2e_s", Json::num(self.e2e_s)),
            ("rollout_s", Json::num(self.rollout_s)),
            ("train_s", Json::num(self.train_s)),
            ("other_s", Json::num(self.other_s)),
            ("tokens", Json::num(self.tokens)),
            ("throughput_tps", Json::num(self.throughput_tps())),
            ("utilization", Json::num(self.utilization())),
            ("scale_ops", Json::num(self.scale_ops as f64)),
            ("swap_s", Json::num(self.swap_s)),
            ("retries", Json::num(self.retries as f64)),
            ("lost_tokens", Json::num(self.lost_tokens)),
            ("recovery_s", Json::num(self.recovery_s)),
            ("degraded_s", Json::num(self.degraded_s)),
            (
                "agent_calls",
                Json::arr(self.agent_calls.iter().map(|&c| Json::num(c as f64))),
            ),
        ])
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Full-fidelity checkpoint codec. [`StepReport::to_json`] is a
    /// *presentation* format (it omits `busy_device_s`, `pool_devices`
    /// and `trajectory_latencies`, and adds derived fields); a resumed
    /// run must rebuild the exact struct, so the checkpoint carries
    /// every field verbatim.
    pub fn to_ckpt_json(&self) -> Json {
        Json::obj(vec![
            ("framework", Json::str(self.framework.clone())),
            ("workload", Json::str(self.workload.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("e2e_s", Json::num(self.e2e_s)),
            ("rollout_s", Json::num(self.rollout_s)),
            ("train_s", Json::num(self.train_s)),
            ("other_s", Json::num(self.other_s)),
            ("tokens", Json::num(self.tokens)),
            ("busy_device_s", Json::num(self.busy_device_s)),
            ("pool_devices", Json::num(self.pool_devices as f64)),
            (
                "agent_calls",
                Json::arr(self.agent_calls.iter().map(|&c| Json::num(c as f64))),
            ),
            (
                "trajectory_latencies",
                Json::arr(self.trajectory_latencies.iter().map(|&l| Json::num(l))),
            ),
            ("scale_ops", Json::num(self.scale_ops as f64)),
            ("swap_s", Json::num(self.swap_s)),
            ("retries", Json::num(self.retries as f64)),
            ("lost_tokens", Json::num(self.lost_tokens)),
            ("recovery_s", Json::num(self.recovery_s)),
            ("degraded_s", Json::num(self.degraded_s)),
        ])
    }

    /// Decode [`StepReport::to_ckpt_json`].
    pub fn from_ckpt_json(j: &Json) -> Result<StepReport, String> {
        let s = |k: &str| -> Result<String, String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or(format!("report missing '{k}'"))?
                .to_string())
        };
        let f = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or(format!("report missing '{k}'"))
        };
        let u = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or(format!("report missing '{k}'"))
        };
        Ok(StepReport {
            framework: s("framework")?,
            workload: s("workload")?,
            scenario: s("scenario")?,
            e2e_s: f("e2e_s")?,
            rollout_s: f("rollout_s")?,
            train_s: f("train_s")?,
            other_s: f("other_s")?,
            tokens: f("tokens")?,
            busy_device_s: f("busy_device_s")?,
            pool_devices: u("pool_devices")?,
            agent_calls: j
                .get("agent_calls")
                .and_then(Json::as_arr)
                .ok_or("report missing 'agent_calls'")?
                .iter()
                .map(|c| c.as_usize().ok_or("bad agent_calls entry"))
                .collect::<Result<_, _>>()?,
            trajectory_latencies: j
                .get("trajectory_latencies")
                .and_then(Json::as_arr)
                .ok_or("report missing 'trajectory_latencies'")?
                .iter()
                .map(|l| l.as_f64().ok_or("bad trajectory latency"))
                .collect::<Result<_, _>>()?,
            scale_ops: u("scale_ops")?,
            swap_s: f("swap_s")?,
            retries: u("retries")?,
            lost_tokens: f("lost_tokens")?,
            recovery_s: f("recovery_s")?,
            degraded_s: f("degraded_s")?,
        })
    }
}

impl RunSeries {
    /// Checkpoint codec for the run-wide poll series: `(time, value)`
    /// pairs, keyed by tracked-agent id.
    pub fn to_ckpt_json(&self) -> Json {
        let series = |v: &[(f64, usize)]| {
            Json::arr(
                v.iter()
                    .map(|&(t, x)| Json::arr([Json::num(t), Json::num(x as f64)])),
            )
        };
        let keyed = |m: &BTreeMap<usize, Vec<(f64, usize)>>| {
            Json::Obj(
                m.iter()
                    .map(|(agent, v)| (agent.to_string(), series(v)))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("processed", keyed(&self.processed)),
            ("queued", keyed(&self.queued)),
            ("busy", series(&self.busy)),
        ])
    }

    /// Decode [`RunSeries::to_ckpt_json`].
    pub fn from_ckpt_json(j: &Json) -> Result<RunSeries, String> {
        fn series(j: &Json, what: &str) -> Result<Vec<(f64, usize)>, String> {
            j.as_arr()
                .ok_or(format!("bad '{what}' series"))?
                .iter()
                .map(|p| {
                    let p = p.as_arr().filter(|p| p.len() == 2).ok_or("bad series pair")?;
                    Ok((
                        p[0].as_f64().ok_or("bad series time")?,
                        p[1].as_usize().ok_or("bad series value")?,
                    ))
                })
                .collect()
        }
        fn keyed(
            j: Option<&Json>,
            what: &str,
        ) -> Result<BTreeMap<usize, Vec<(f64, usize)>>, String> {
            j.and_then(Json::as_obj)
                .ok_or(format!("series missing '{what}'"))?
                .iter()
                .map(|(k, v)| {
                    let agent: usize =
                        k.parse().map_err(|_| format!("bad agent key '{k}'"))?;
                    Ok((agent, series(v, what)?))
                })
                .collect()
        }
        Ok(RunSeries {
            processed: keyed(j.get("processed"), "processed")?,
            queued: keyed(j.get("queued"), "queued")?,
            busy: series(j.get("busy").unwrap_or(&Json::Null), "busy")?,
        })
    }
}

/// Aggregate several steps (mean over steps, as the paper's per-sample
/// averages do).
pub fn aggregate(reports: &[StepReport]) -> StepReport {
    assert!(!reports.is_empty());
    let n = reports.len() as f64;
    let mut out = reports[0].clone();
    if reports.len() == 1 {
        return out;
    }
    out.e2e_s = reports.iter().map(|r| r.e2e_s).sum::<f64>() / n;
    out.rollout_s = reports.iter().map(|r| r.rollout_s).sum::<f64>() / n;
    out.train_s = reports.iter().map(|r| r.train_s).sum::<f64>() / n;
    out.other_s = reports.iter().map(|r| r.other_s).sum::<f64>() / n;
    out.tokens = reports.iter().map(|r| r.tokens).sum::<f64>() / n;
    out.busy_device_s = reports.iter().map(|r| r.busy_device_s).sum::<f64>() / n;
    out.swap_s = reports.iter().map(|r| r.swap_s).sum::<f64>() / n;
    out.scale_ops = (reports.iter().map(|r| r.scale_ops).sum::<usize>() as f64 / n) as usize;
    out.retries = (reports.iter().map(|r| r.retries).sum::<usize>() as f64 / n) as usize;
    out.lost_tokens = reports.iter().map(|r| r.lost_tokens).sum::<f64>() / n;
    out.recovery_s = reports.iter().map(|r| r.recovery_s).sum::<f64>() / n;
    out.degraded_s = reports.iter().map(|r| r.degraded_s).sum::<f64>() / n;
    let n_agents = out.agent_calls.len();
    out.agent_calls = (0..n_agents)
        .map(|i| {
            (reports.iter().map(|r| r.agent_calls[i]).sum::<usize>() as f64 / n) as usize
        })
        .collect();
    out
}

/// A Table-2 style comparison row.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub framework: String,
    pub e2e_s: f64,
    pub speedup: f64,
    pub throughput_tps: f64,
}

/// Build Table-2 rows: speedups relative to the first (baseline) entry.
pub fn table_rows(reports: &[StepReport]) -> Vec<TableRow> {
    let base = reports.first().map(|r| r.e2e_s).unwrap_or(1.0);
    reports
        .iter()
        .map(|r| TableRow {
            framework: r.framework.clone(),
            e2e_s: r.e2e_s,
            speedup: base / r.e2e_s,
            throughput_tps: r.throughput_tps(),
        })
        .collect()
}

pub fn render_table2(workload: &str, rows: &[TableRow]) -> String {
    let mut s = String::from(
        "| Dataset | Framework | E2E Time | Speedup | Throughput |\n|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {} | {:.1}s | {:.1}x | {:.1}tps |\n",
            workload, r.framework, r.e2e_s, r.speedup, r.throughput_tps
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(fw: &str, e2e: f64, tokens: f64) -> StepReport {
        StepReport {
            framework: fw.into(),
            workload: "MA".into(),
            e2e_s: e2e,
            rollout_s: e2e * 0.8,
            train_s: e2e * 0.15,
            other_s: e2e * 0.05,
            tokens,
            busy_device_s: 100.0,
            pool_devices: 10,
            agent_calls: vec![5, 3],
            ..StepReport::default()
        }
    }

    #[test]
    fn throughput_and_utilization() {
        let r = mk("X", 100.0, 50_000.0);
        assert!((r.throughput_tps() - 500.0).abs() < 1e-9);
        assert!((r.utilization() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn speedup_relative_to_first() {
        let rows = table_rows(&[mk("base", 900.0, 1.0), mk("fast", 300.0, 1.0)]);
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        assert!((rows[1].speedup - 3.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_means() {
        let a = aggregate(&[mk("X", 100.0, 1000.0), mk("X", 200.0, 3000.0)]);
        assert!((a.e2e_s - 150.0).abs() < 1e-9);
        assert!((a.tokens - 2000.0).abs() < 1e-9);
        assert_eq!(a.agent_calls, vec![5, 3]);
    }

    #[test]
    fn json_emission_parses() {
        let j = mk("X", 10.0, 100.0).to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.at(&["framework"]).unwrap().as_str(), Some("X"));
    }

    #[test]
    fn recovery_accounting_serializes_and_aggregates() {
        // Fault-free reports carry the recovery fields zeroed (the
        // schema is unconditional so faulted and fault-free grids stay
        // comparable).
        let j = mk("X", 10.0, 100.0).to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        for key in ["retries", "lost_tokens", "recovery_s", "degraded_s"] {
            assert_eq!(parsed.at(&[key]).and_then(Json::as_f64), Some(0.0), "{key}");
        }
        let mut a = mk("X", 100.0, 1000.0);
        a.retries = 3;
        a.lost_tokens = 400.0;
        a.recovery_s = 1.5;
        a.degraded_s = 30.0;
        let mut b = mk("X", 100.0, 1000.0);
        b.retries = 2;
        b.lost_tokens = 100.0;
        let agg = aggregate(&[a, b]);
        assert_eq!(agg.retries, 2, "floor-mean like scale_ops");
        assert!((agg.lost_tokens - 250.0).abs() < 1e-9);
        assert!((agg.recovery_s - 0.75).abs() < 1e-9);
        assert!((agg.degraded_s - 15.0).abs() < 1e-9);
    }
}
