//! Metric-key interning: the allocation-free recording hot path
//! (DESIGN.md §4, "metric-key interning rules").
//!
//! Counter keys are interned to dense integer ids **at sim
//! construction**; the event loop then records by id into a
//! preallocated `Vec` slot — no `String` construction, hashing, or map
//! lookup per event. Values flow back out by id at report time.
//! [`Counters::freeze`] fences the two phases: once the event loop
//! starts, constructing a new counter key is a bug (it would put
//! allocation back on the hot path), and `register` debug-asserts it.
//!
//! The surface is deliberately minimal — register/freeze/add/get is
//! everything the engine needs; names live only in the registration
//! call sites.

/// Dense id of an interned counter key — `Copy`, `Vec`-indexable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricId(u32);

/// A set of named `f64` counters with id-indexed recording.
#[derive(Debug, Default)]
pub struct Counters {
    names: Vec<String>,
    vals: Vec<f64>,
    frozen: bool,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }

    /// Intern a counter key (construction phase only). After
    /// [`Counters::freeze`] this debug-panics: a key constructed once
    /// the event loop has begun is exactly the per-event allocation
    /// this module exists to eliminate.
    pub fn register(&mut self, name: &str) -> MetricId {
        debug_assert!(
            !self.frozen,
            "metric key '{name}' constructed after freeze (event loop already started)"
        );
        debug_assert!(
            !self.names.iter().any(|n| n == name),
            "metric key '{name}' interned twice"
        );
        let id = MetricId(self.names.len() as u32);
        self.names.push(name.to_string());
        self.vals.push(0.0);
        id
    }

    /// Fence between construction and recording: after this, no new
    /// keys may be interned (debug-asserted in [`Counters::register`]).
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    /// Record: a plain `Vec` index — allocation-free, branch-free.
    #[inline]
    pub fn add(&mut self, id: MetricId, v: f64) {
        self.vals[id.0 as usize] += v;
    }

    pub fn get(&self, id: MetricId) -> f64 {
        self.vals[id.0 as usize]
    }

    /// Checkpoint capture (DESIGN.md §12): the accumulated values only.
    /// Keys are re-registered in the same order by engine construction,
    /// so ids line up by position; a resumed run restores values into
    /// the freshly-interned table.
    pub fn snapshot_vals(&self) -> &[f64] {
        &self.vals
    }

    /// Restore accumulated values captured by
    /// [`Counters::snapshot_vals`] into a freshly-registered table.
    /// Errors (rather than panicking) on a count mismatch — that means
    /// the checkpoint came from a different engine layout.
    pub fn restore_vals(&mut self, vals: &[f64]) -> Result<(), String> {
        if vals.len() != self.vals.len() {
            return Err(format!(
                "counter table has {} keys, checkpoint has {}",
                self.vals.len(),
                vals.len()
            ));
        }
        self.vals.copy_from_slice(vals);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_record_read() {
        let mut c = Counters::new();
        let a = c.register("swap_s");
        let b = c.register("scale_ops");
        c.freeze();
        c.add(a, 1.5);
        c.add(a, 2.5);
        c.add(b, 1.0);
        assert_eq!(c.get(a), 4.0);
        assert_eq!(c.get(b), 1.0);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug_assert-gated")]
    fn registering_after_freeze_panics_in_debug() {
        let mut c = Counters::new();
        c.register("ok");
        c.freeze();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.register("late");
        }));
        assert!(res.is_err(), "late interning must debug-panic");
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug_assert-gated")]
    fn duplicate_key_panics_in_debug() {
        let mut c = Counters::new();
        c.register("x");
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.register("x");
        }));
        assert!(res.is_err());
    }
}
