//! Baseline frameworks of §8.1, expressed as capability configurations
//! over the same engine ([`crate::orchestrator::simloop`]):
//!
//! * **MAS-RL** — the single-agent RL stack naively ported to MARL:
//!   colocated resource pool, serial query processing with turn barriers,
//!   synchronous full-batch training, onload/offload at every phase
//!   switch.
//! * **DistRL** — disaggregated pools and parallel sampling, but
//!   synchronous training and static per-agent partitions (no balancing,
//!   no agent-centric binding).
//! * **MARTI-like** — colocated with one-step-asynchronous rollouts
//!   (step *s+1* generates with stale parameters while step *s* trains)
//!   and static allocation; the strongest published MARL baseline.
//!
//! The ablations of Table 3 (`w/o balancing`, `w/o async`) are FlexMARL
//! with a single capability cleared — see [`crate::config::Framework`].

pub use crate::config::{framework_by_name, Framework};

use crate::config::ExperimentConfig;
use crate::error::PallasError;
use crate::experiment::Experiment;
use crate::metrics::StepReport;
use crate::orchestrator::SimOptions;

/// Run one framework on a config and aggregate its per-step reports
/// (the per-sample averages the paper tables quote). Panics on
/// workload-resolution failure (see [`try_evaluate`]).
#[deprecated(
    since = "0.3.0",
    note = "panics on workload-resolution failure; use `try_evaluate` or \
            `experiment::Experiment::new(cfg).build()?.evaluate()`"
)]
pub fn evaluate(cfg: &ExperimentConfig, opts: &SimOptions) -> StepReport {
    try_evaluate(cfg, opts).unwrap_or_else(|e| match e {
        // A runtime livelock is not a resolution failure — keep the
        // budget error's own message, like `orchestrator::simulate`.
        PallasError::EventBudget { .. } => panic!("{e}"),
        e => panic!("workload resolution failed: {e}"),
    })
}

/// [`evaluate`] with failures surfaced as [`PallasError`] — the CLI
/// path, so a bad `--trace` (workload resolution) or a tripped
/// run-loop event budget exits cleanly instead of panicking.
/// Step-overlapping pipelines (one-step-async) report amortized E2E
/// over the simulated step count — trace replay can override
/// `cfg.steps`.
pub fn try_evaluate(cfg: &ExperimentConfig, opts: &SimOptions) -> Result<StepReport, PallasError> {
    Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()?
        .try_evaluate()
}

/// Table-2 style sweep: all four frameworks on one workload. Runs
/// through the deterministic parallel executor ([`crate::exec`]) with
/// the default worker count (`PALLAS_JOBS` / available parallelism) —
/// rows come back in `Framework::all_baselines` order and are
/// bit-identical for any worker count.
pub fn sweep(base: &ExperimentConfig, opts: &SimOptions) -> Vec<StepReport> {
    sweep_jobs(base, opts, crate::util::pool::default_jobs())
}

/// [`sweep`] with an explicit worker count.
pub fn sweep_jobs(base: &ExperimentConfig, opts: &SimOptions, jobs: usize) -> Vec<StepReport> {
    let grid = crate::exec::RunGrid {
        frameworks: Framework::all_baselines(),
        ..crate::exec::RunGrid::default()
    };
    crate::exec::run_specs_or_panic(base, opts, &grid.specs(base), jobs)
}

/// Scenario-matrix sweep: one framework across every workload scenario
/// preset ([`crate::workload::scenario`]) — the balancer, trajectory
/// scheduler, and allocator each get exercised under every traffic
/// shape the suite knows. The CI scenario matrix and the
/// `paper_benches` scenario group both run this shape, through the
/// parallel executor (each preset generates fresh: the scenario axis
/// clears any base trace, whose header would otherwise override it).
pub fn scenario_sweep(base: &ExperimentConfig, opts: &SimOptions) -> Vec<StepReport> {
    scenario_sweep_jobs(base, opts, crate::util::pool::default_jobs())
}

/// [`scenario_sweep`] with an explicit worker count.
pub fn scenario_sweep_jobs(
    base: &ExperimentConfig,
    opts: &SimOptions,
    jobs: usize,
) -> Vec<StepReport> {
    let grid = crate::exec::RunGrid {
        scenarios: crate::workload::scenario::owned_names(),
        ..crate::exec::RunGrid::default()
    };
    crate::exec::run_specs_or_panic(base, opts, &grid.specs(base), jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn sweep_produces_all_rows() {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.steps = 1;
        let rows = sweep(&cfg, &SimOptions::default());
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].framework, "MAS-RL");
        assert_eq!(rows[3].framework, "FlexMARL");
        for r in &rows {
            assert!(r.e2e_s > 0.0 && r.tokens > 0.0);
        }
    }

    #[test]
    fn scenario_sweep_covers_every_preset() {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.steps = 1;
        let rows = scenario_sweep(&cfg, &SimOptions::default());
        let names = crate::workload::scenario::names();
        assert_eq!(rows.len(), names.len());
        for (r, name) in rows.iter().zip(&names) {
            assert_eq!(r.scenario, *name);
            assert!(r.e2e_s > 0.0 && r.tokens > 0.0, "{name}");
        }
        // The shapes genuinely differ: not all rows can agree on tokens.
        let t0 = rows[0].tokens;
        assert!(rows.iter().any(|r| r.tokens != t0));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_evaluate_still_matches_try_evaluate() {
        // Back-compat: the panicking wrapper must keep returning the
        // exact same report until it is removed.
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::marti());
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.steps = 2;
        let opts = SimOptions::default();
        let a = evaluate(&cfg, &opts);
        let b = try_evaluate(&cfg, &opts).unwrap();
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }

    #[test]
    fn sweeps_are_worker_count_invariant() {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.steps = 1;
        let opts = SimOptions::default();
        let a = sweep_jobs(&cfg, &opts, 1);
        let b = sweep_jobs(&cfg, &opts, 3);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.framework, y.framework);
            assert_eq!(x.e2e_s, y.e2e_s);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.agent_calls, y.agent_calls);
        }
    }
}
