//! Inert, API-compatible stand-in for the `xla` crate (xla_extension
//! PJRT bindings), which is not vendored in this offline image.
//!
//! The runtime layer (`runtime::{mod, policy}`) aliases this module as
//! `xla` so it typechecks unchanged; at run time the very first step —
//! [`PjRtClient::cpu`] — returns an actionable error, so a
//! `ModelRuntime` can never be constructed and no other stub method is
//! reachable through the public API. Everything PJRT-dependent
//! (integration tests, `benches/hotpath.rs` §pjrt, the e2e examples)
//! already gates on `ModelRuntime::load` succeeding and skips cleanly.
//!
//! To run the real thing, vendor the `xla` crate and replace the
//! `use crate::xla_stub as xla;` alias in `runtime/mod.rs` and
//! `runtime/policy.rs` with `use xla;`.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn missing<T>() -> Result<T, Error> {
    Err(Error(
        "xla backend not available: the xla_extension crate is not \
         vendored in this build (see src/xla_stub.rs)"
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Host literal. The stub carries no data: no literal can ever reach an
/// executable because client construction fails first.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_x: T) -> Literal {
        Literal
    }

    pub fn create_from_shape(_ty: PrimitiveType, _dims: &[usize]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        missing()
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        missing()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        missing()
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        missing()
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        missing()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        missing()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        missing()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        missing()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_actionably() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not vendored"), "{e}");
    }

    #[test]
    fn model_runtime_load_fails_not_panics() {
        // The public gate every PJRT consumer checks.
        assert!(crate::runtime::ModelRuntime::load("/nonexistent").is_err());
    }
}
