//! Configuration system: typed experiment/cluster/agent configs with JSON
//! loading and the paper's experimental presets (§8.1).
//!
//! Everything the simulator and the real runtime need is specified here:
//! cluster topology (48 nodes × 16 NPUs, HCCS), agent ensembles (MA: 8 ×
//! Qwen2.5-14B; CA: mixed 14B/32B), workload shape (long-tail response
//! lengths, skewed agent invocation), pipeline hyperparameters (batch 64,
//! micro batch 16, Δ = 5, seed 2048), and framework capability flags.

use crate::error::PallasError;
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Framework variants (Table 1 / §8.1 baselines)
// ---------------------------------------------------------------------------

/// Capability flags that distinguish the four systems under test. The
/// ablations of Table 3 are `flexmarl()` with one flag cleared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Framework {
    pub name: &'static str,
    /// Dedicated rollout/training resource pools (§4.1) vs colocated
    /// time-division multiplexing with onload/offload at each switch.
    pub disaggregated: bool,
    /// Dependency-driven inter/intra-query parallel sampling (§5.1).
    pub parallel_sampling: bool,
    /// Hierarchical (intra- + inter-agent) load balancing (§5.2).
    pub load_balancing: bool,
    /// Micro-batch asynchronous pipeline (§4.3) vs full-batch sync.
    pub async_pipeline: bool,
    /// Agent-centric on-demand resource binding (§6.1) vs static
    /// per-agent partitions.
    pub agent_centric: bool,
    /// MARTI-style one-step-async rollout (stale-by-one parameters).
    pub one_step_async_rollout: bool,
}

impl Framework {
    /// Naive single-agent-RL port: colocated, serial, fully synchronous.
    pub fn mas_rl() -> Framework {
        Framework {
            name: "MAS-RL",
            disaggregated: false,
            parallel_sampling: false,
            load_balancing: false,
            async_pipeline: false,
            agent_centric: false,
            one_step_async_rollout: false,
        }
    }

    /// Disaggregated pools, parallel sampling, but synchronous full-batch
    /// training and static allocation.
    pub fn dist_rl() -> Framework {
        Framework {
            name: "DistRL",
            disaggregated: true,
            parallel_sampling: true,
            load_balancing: false,
            async_pipeline: false,
            agent_centric: false,
            one_step_async_rollout: false,
        }
    }

    /// MARTI-like: colocated, parallel sampling with async (stale-by-one)
    /// rollouts, static allocation.
    pub fn marti() -> Framework {
        Framework {
            name: "MARTI",
            disaggregated: false,
            parallel_sampling: true,
            load_balancing: false,
            async_pipeline: false,
            agent_centric: false,
            one_step_async_rollout: true,
        }
    }

    pub fn flexmarl() -> Framework {
        Framework {
            name: "FlexMARL",
            disaggregated: true,
            parallel_sampling: true,
            load_balancing: true,
            async_pipeline: true,
            agent_centric: true,
            one_step_async_rollout: false,
        }
    }

    /// Table 3 ablations.
    pub fn flexmarl_no_balancing() -> Framework {
        Framework {
            name: "FlexMARL w/o balancing",
            load_balancing: false,
            ..Framework::flexmarl()
        }
    }

    pub fn flexmarl_no_async() -> Framework {
        Framework {
            name: "FlexMARL w/o async",
            async_pipeline: false,
            ..Framework::flexmarl()
        }
    }

    pub fn all_baselines() -> Vec<Framework> {
        vec![
            Framework::mas_rl(),
            Framework::dist_rl(),
            Framework::marti(),
            Framework::flexmarl(),
        ]
    }
}

// ---------------------------------------------------------------------------
// Models & cluster
// ---------------------------------------------------------------------------

/// Policy model scale. The simulator only needs parameter count (compute
/// and state-size models derive from it); the real runtime maps this to
/// an AOT artifact bundle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelScale {
    pub params_b: f64, // billions
}

impl ModelScale {
    pub const B3: ModelScale = ModelScale { params_b: 3.0 };
    pub const B7: ModelScale = ModelScale { params_b: 7.0 };
    pub const B14: ModelScale = ModelScale { params_b: 14.0 };
    pub const B32: ModelScale = ModelScale { params_b: 32.0 };

    pub fn params(&self) -> f64 {
        self.params_b * 1e9
    }

    /// Inference weight bytes (bf16).
    pub fn weight_bytes(&self) -> f64 {
        self.params() * 2.0
    }

    /// Full training state (bf16 weights + fp32 master + fp32 Adam m,v),
    /// the paper's "weights and optimizer states" (§6.2).
    pub fn train_state_bytes(&self) -> f64 {
        self.params() * (2.0 + 4.0 + 4.0 + 4.0)
    }

    /// Devices needed to serve one inference instance (TP degree).
    /// 64 GB HBM per NPU; weights + KV head-room.
    pub fn instance_devices(&self) -> usize {
        if self.params_b <= 8.0 {
            2
        } else if self.params_b <= 16.0 {
            4
        } else {
            8
        }
    }

    /// Devices in one training process group (ZeRO-3 shards).
    pub fn train_group_devices(&self) -> usize {
        self.instance_devices() * 2
    }

    /// Autoregressive decode rate per request (tokens/s) under continuous
    /// batching. Calibrated so the Fig. 1a tail (8192 tokens) lands near
    /// the paper's ~170 s worst case for 14B.
    pub fn decode_tps(&self) -> f64 {
        // Memory-bound decode: rate ~ inverse in weight bytes, with an
        // interconnect-efficiency bonus for larger TP groups. 115 tok/s
        // for 14B → an 8192-token cap costs ~71 s per call, putting the
        // worst *query chains* near the paper's ~170 s (Fig. 1a) while
        // leaving queueing (not chain latency) as the dominant rollout
        // cost for the non-balanced baselines, as in Obs. 2.
        let base = 115.0 * (14.0 / self.params_b).powf(0.85);
        base.max(8.0)
    }

    /// *Effective* training throughput in tokens/s per device for the
    /// whole policy-optimization pass. Calibrated to Fig. 7 (DistRL
    /// trains the MA batch in ~156 s): GRPO training is not a clean
    /// pretraining step — it includes ZeRO-3 gather/scatter, the
    /// reference/reward forward passes and advantage bookkeeping, so the
    /// effective MFU over 6·N FLOPs/token is ~5.5%.
    pub fn train_tps_per_device(&self) -> f64 {
        let flops_per_token = 6.0 * self.params();
        280e12 * 0.055 / flops_per_token
    }
}

/// Physical cluster (paper: 48 nodes × 16 NPU × 64 GB, HCCS intra-node,
/// RDMA inter-node).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub devices_per_node: usize,
    pub hbm_per_device_gb: f64,
    /// Intra-node D2D (HCCS) bandwidth, bytes/s per link.
    pub d2d_bw: f64,
    /// Host<->device (PCIe/offload path) bandwidth per device, bytes/s.
    pub h2d_bw: f64,
    /// Node-level host-memory bandwidth shared by concurrent offloads.
    pub host_mem_bw: f64,
    /// Cross-node RDMA bandwidth, bytes/s.
    pub rdma_bw: f64,
    /// Control-plane cost of launching one transfer op (the §9 lesson:
    /// per-parameter sync is dominated by this).
    pub control_op_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 48,
            devices_per_node: 16,
            hbm_per_device_gb: 64.0,
            d2d_bw: 160e9,
            h2d_bw: 24e9,
            host_mem_bw: 120e9,
            rdma_bw: 50e9,
            control_op_s: 20e-6,
        }
    }
}

impl ClusterConfig {
    pub fn total_devices(&self) -> usize {
        self.nodes * self.devices_per_node
    }
}

// ---------------------------------------------------------------------------
// Agents & workload
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AgentConfig {
    pub name: String,
    pub model: ModelScale,
    /// Relative invocation weight in the workflow (Obs. 2 skew).
    pub invoke_weight: f64,
    /// Mean generated tokens per call (lognormal median).
    pub mean_tokens: f64,
    /// Lognormal sigma of token counts — the long-tail knob (Fig. 1a).
    pub token_sigma: f64,
}

/// Workload = the dataset analogue (MA / CA): queries per MARL step, the
/// multi-agent workflow shape, and GRPO grouping.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub name: String,
    pub agents: Vec<AgentConfig>,
    /// User queries per MARL step. Trajectories (training samples) per
    /// step = queries_per_step × group_size = the global batch (§8.1:
    /// batch 64 = 4 queries × GRPO group 16).
    pub queries_per_step: usize,
    /// Agent-calls per query: uniform in [min_turns, max_turns].
    pub min_turns: usize,
    pub max_turns: usize,
    /// Intra-query parallelism: GRPO group size (candidates per call).
    pub group_size: usize,
    /// Inter-query parallelism: queries dispatched concurrently.
    pub inter_query: usize,
    /// Max response tokens (vLLM cap; 8192 in §8.1).
    pub max_tokens: f64,
    /// Environment/tool latency added per call, seconds (lognormal).
    pub env_mu: f64,
    pub env_sigma: f64,
    /// Named traffic-shape preset applied on top of this config
    /// ([`crate::workload::scenario`]); `"baseline"` leaves it as-is.
    pub scenario: String,
    /// Optional JSONL trace path: replay recorded step workloads
    /// instead of generating ([`crate::workload::trace`]).
    pub trace: Option<String>,
}

impl WorkloadConfig {
    /// Merchant Assistant: 8 × 14B agents; two "core" agents carry ~76%
    /// of the rollout load (Obs. 2).
    pub fn ma() -> WorkloadConfig {
        let mk = |name: &str, w: f64, mean_tokens: f64| AgentConfig {
            name: name.to_string(),
            model: ModelScale::B14,
            invoke_weight: w,
            mean_tokens,
            token_sigma: 1.0,
        };
        WorkloadConfig {
            name: "MA".to_string(),
            agents: vec![
                mk("planner", 6.0, 320.0),
                mk("sales_analyst", 28.0, 640.0),   // core
                mk("marketing_strategist", 20.0, 560.0), // core
                mk("inventory", 4.0, 280.0),
                mk("after_sales", 5.0, 360.0),
                mk("pricing", 4.0, 300.0),
                mk("reviewer", 5.0, 240.0),
                mk("responder", 4.0, 400.0),
            ],
            queries_per_step: 4,
            min_turns: 3,
            max_turns: 6,
            group_size: 16,
            inter_query: 4,
            max_tokens: 8192.0,
            env_mu: 0.3,
            env_sigma: 0.8,
            scenario: "baseline".to_string(),
            trace: None,
        }
    }

    /// Category Assistant: mixed 14B/32B ensemble, shorter workflows.
    pub fn ca() -> WorkloadConfig {
        let mk = |name: &str, model: ModelScale, w: f64, mean_tokens: f64| AgentConfig {
            name: name.to_string(),
            model,
            invoke_weight: w,
            mean_tokens,
            token_sigma: 0.9,
        };
        WorkloadConfig {
            name: "CA".to_string(),
            agents: vec![
                mk("order_query", ModelScale::B14, 26.0, 320.0), // core
                mk("pricing_strategy", ModelScale::B32, 22.0, 380.0), // core
                mk("inventory_mgmt", ModelScale::B14, 6.0, 240.0),
                mk("catalog", ModelScale::B14, 5.0, 260.0),
                mk("promo", ModelScale::B14, 4.0, 280.0),
                mk("responder", ModelScale::B14, 5.0, 340.0),
            ],
            queries_per_step: 4,
            min_turns: 2,
            max_turns: 4,
            group_size: 16,
            inter_query: 4,
            max_tokens: 8192.0,
            env_mu: 0.2,
            env_sigma: 0.7,
            scenario: "baseline".to_string(),
            trace: None,
        }
    }

    /// Serving-plane session shape (DESIGN.md §13): the MA ensemble
    /// with the smallest batch geometry that still exercises every
    /// code path (2 queries × GRPO group 2). The `serve` front-end
    /// multiplexes hundreds of these per run, so each one must cost
    /// milliseconds, not seconds.
    pub fn tiny() -> WorkloadConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 2;
        wl.inter_query = 2;
        wl
    }

    /// Table 4 heterogeneous scalability configs on the MA workflow.
    pub fn scale_config(spec: &[(usize, ModelScale)]) -> WorkloadConfig {
        let mut base = WorkloadConfig::ma();
        let mut agents = Vec::new();
        let mut idx = 0;
        for &(count, model) in spec {
            for _ in 0..count {
                let proto = &base.agents[idx % base.agents.len()];
                agents.push(AgentConfig {
                    name: format!("agent{:02}_{}b", idx, model.params_b as u32),
                    model,
                    invoke_weight: proto.invoke_weight,
                    mean_tokens: proto.mean_tokens,
                    token_sigma: proto.token_sigma,
                });
                idx += 1;
            }
        }
        base.agents = agents;
        base.name = spec
            .iter()
            .map(|(c, m)| format!("{}x{}B", c, m.params_b as u32))
            .collect::<Vec<_>>()
            .join("+");
        base
    }

    pub fn core_agents(&self) -> Vec<usize> {
        // Agents carrying the top share of invocation weight.
        let total: f64 = self.agents.iter().map(|a| a.invoke_weight).sum();
        let mut idx: Vec<usize> = (0..self.agents.len()).collect();
        idx.sort_by(|&a, &b| {
            self.agents[b]
                .invoke_weight
                .partial_cmp(&self.agents[a].invoke_weight)
                .unwrap()
        });
        let mut out = Vec::new();
        let mut acc = 0.0;
        for i in idx {
            if acc / total >= 0.5 {
                break;
            }
            acc += self.agents[i].invoke_weight;
            out.push(i);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Pipeline / training hyperparameters (§8.1)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Global batch (samples) per policy update.
    pub global_batch: usize,
    /// Micro batch threshold for incremental dispatch (§4.3).
    pub micro_batch: usize,
    /// Inter-agent load-balancing disparity threshold Δ (§5.2).
    pub delta_threshold: usize,
    /// Rollout request timeout (fault tolerance, §5.2).
    pub request_timeout_s: f64,
    /// Learning rate (GRPO, Adam).
    pub lr: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            global_batch: 64,
            micro_batch: 16,
            delta_threshold: 5,
            request_timeout_s: 600.0,
            lr: 1e-6,
        }
    }
}

// ---------------------------------------------------------------------------
// Top-level experiment config
// ---------------------------------------------------------------------------

/// How per-step workloads are resolved (DESIGN.md §11). A routing
/// choice, never a semantic one: both modes produce byte-identical
/// runs (the lazy-equivalence contract, enforced in CI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WorkloadMode {
    /// Materialize every step up front (the classic path; memory scales
    /// with `steps`). The golden reference for equivalence diffs.
    #[default]
    Eager,
    /// Stream steps through a [`crate::workload::WorkloadSource`] —
    /// generated or trace-parsed on demand, peak memory O(one step).
    Lazy,
}

impl WorkloadMode {
    /// Parse a config/CLI spelling (`"eager"` / `"lazy"`,
    /// case-insensitive).
    pub fn from_name(name: &str) -> Option<WorkloadMode> {
        match name.to_ascii_lowercase().as_str() {
            "eager" => Some(WorkloadMode::Eager),
            "lazy" => Some(WorkloadMode::Lazy),
            _ => None,
        }
    }
}

/// Periodic checkpointing (DESIGN.md §12). Inert by default: a config
/// that never mentions checkpoints runs exactly as before, and the
/// section is excluded from the resume fingerprint (where snapshots
/// are written does not change what is computed).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CheckpointConfig {
    /// Write a checkpoint after every `n` completed MARL steps
    /// (`--checkpoint-every`); `None` disables checkpointing.
    pub every: Option<usize>,
    /// Directory for the checkpoint file (`--checkpoint-dir`); the
    /// current directory when unset.
    pub dir: Option<String>,
}

impl CheckpointConfig {
    /// The stable checkpoint path: `<dir>/ckpt.json`, atomically
    /// replaced on every write (the newest checkpoint is always the
    /// only one).
    pub fn path(&self) -> String {
        match &self.dir {
            Some(d) => format!("{}/ckpt.json", d.trim_end_matches('/')),
            None => "ckpt.json".to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub workload: WorkloadConfig,
    pub pipeline: PipelineConfig,
    pub framework: Framework,
    /// MARL steps to simulate.
    pub steps: usize,
    pub seed: u64,
    /// Fault-injection plan (DESIGN.md §10). Empty by default: a config
    /// that never mentions faults simulates byte-identically to one
    /// with `"faults": {}`.
    pub faults: crate::fault::FaultConfig,
    /// Workload resolution mode (`--workload-mode`): eager
    /// materialization (default) or the lazy streaming plane.
    pub workload_mode: WorkloadMode,
    /// Periodic checkpointing (DESIGN.md §12); disabled by default.
    pub checkpoint: CheckpointConfig,
}

impl ExperimentConfig {
    pub fn new(workload: WorkloadConfig, framework: Framework) -> Self {
        ExperimentConfig {
            cluster: ClusterConfig::default(),
            workload,
            pipeline: PipelineConfig::default(),
            framework,
            steps: 1,
            seed: 2048, // paper §8.1
            faults: crate::fault::FaultConfig::default(),
            workload_mode: WorkloadMode::default(),
            checkpoint: CheckpointConfig::default(),
        }
    }

    /// Load overrides from a JSON config file onto a preset base.
    pub fn from_json_file(path: &str) -> Result<Self, PallasError> {
        let text = std::fs::read_to_string(path).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })?;
        let j = parse(&text).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })?;
        Self::from_json(&j)
    }

    /// Build a config from a parsed JSON document.
    ///
    /// The document's key set is *validated*: a key the parser does not
    /// read — at the top level or inside the `pipeline` / `cluster` /
    /// `workload_overrides` sections — is rejected with
    /// [`PallasError::UnknownKey`] (including a nearest-valid-key
    /// suggestion), instead of the old behaviour of silently ignoring
    /// typos like `"scenarrio"`.
    pub fn from_json(j: &Json) -> Result<Self, PallasError> {
        let Some(top) = j.as_obj() else {
            return Err(PallasError::InvalidConfig(
                "config root must be a JSON object".into(),
            ));
        };
        check_keys(top, TOP_KEYS, "config")?;
        for (section, valid) in [
            ("pipeline", PIPELINE_KEYS),
            ("cluster", CLUSTER_KEYS),
            ("workload_overrides", OVERRIDE_KEYS),
            ("checkpoint", CHECKPOINT_KEYS),
        ] {
            if let Some(sub) = top.get(section) {
                let Some(obj) = sub.as_obj() else {
                    return Err(PallasError::InvalidConfig(format!(
                        "'{section}' must be a JSON object"
                    )));
                };
                check_keys(obj, valid, section)?;
            }
        }
        let wl_name = j.at(&["workload"]).and_then(Json::as_str).unwrap_or("MA");
        let workload = match wl_name.to_ascii_uppercase().as_str() {
            "MA" => WorkloadConfig::ma(),
            "CA" => WorkloadConfig::ca(),
            other => return Err(PallasError::UnknownWorkload(other.to_string())),
        };
        let fw_name = j.at(&["framework"]).and_then(Json::as_str).unwrap_or("FlexMARL");
        let framework = framework_by_name(fw_name)
            .ok_or_else(|| PallasError::UnknownFramework(fw_name.to_string()))?;
        let mut cfg = ExperimentConfig::new(workload, framework);
        if let Some(v) = j.at(&["seed"]).and_then(Json::as_u64) {
            cfg.seed = v;
        }
        if let Some(v) = j.at(&["steps"]).and_then(Json::as_usize) {
            cfg.steps = v;
        }
        if let Some(v) = j.at(&["pipeline", "global_batch"]).and_then(Json::as_usize) {
            cfg.pipeline.global_batch = v;
        }
        if let Some(v) = j.at(&["pipeline", "micro_batch"]).and_then(Json::as_usize) {
            cfg.pipeline.micro_batch = v;
        }
        if let Some(v) = j.at(&["pipeline", "delta_threshold"]).and_then(Json::as_usize) {
            cfg.pipeline.delta_threshold = v;
        }
        if let Some(v) = j.at(&["cluster", "nodes"]).and_then(Json::as_usize) {
            cfg.cluster.nodes = v;
        }
        if let Some(v) = j.at(&["cluster", "devices_per_node"]).and_then(Json::as_usize) {
            cfg.cluster.devices_per_node = v;
        }
        if let Some(v) = j.at(&["workload_overrides", "queries_per_step"]).and_then(Json::as_usize) {
            cfg.workload.queries_per_step = v;
        }
        if let Some(v) = j.at(&["workload_overrides", "group_size"]).and_then(Json::as_usize) {
            cfg.workload.group_size = v;
        }
        // Accepted both top-level and under workload_overrides (the
        // namespace every other workload field uses); nested wins.
        for path in [&["scenario"][..], &["workload_overrides", "scenario"][..]] {
            if let Some(v) = j.at(path).and_then(Json::as_str) {
                cfg.workload.scenario = v.to_string();
            }
        }
        for path in [&["trace"][..], &["workload_overrides", "trace"][..]] {
            if let Some(v) = j.at(path).and_then(Json::as_str) {
                cfg.workload.trace = Some(v.to_string());
            }
        }
        if let Some(v) = j.at(&["workload_mode"]).and_then(Json::as_str) {
            cfg.workload_mode = WorkloadMode::from_name(v).ok_or_else(|| {
                PallasError::InvalidConfig(format!(
                    "unknown workload_mode '{v}' (want 'eager' or 'lazy')"
                ))
            })?;
        }
        if let Some(v) = j.at(&["checkpoint", "every"]).and_then(Json::as_usize) {
            cfg.checkpoint.every = Some(v);
        }
        if let Some(v) = j.at(&["checkpoint", "dir"]).and_then(Json::as_str) {
            cfg.checkpoint.dir = Some(v.to_string());
        }
        // The faults section has its own schema (and its own unknown-key
        // rejection) in `crate::fault`; it also rejects non-objects.
        if let Some(sub) = top.get("faults") {
            cfg.faults = crate::fault::FaultConfig::from_json(sub)?;
        }
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), PallasError> {
        if self.workload.agents.is_empty() {
            return Err(PallasError::InvalidConfig("no agents".into()));
        }
        if crate::workload::scenario::by_name(&self.workload.scenario).is_none() {
            return Err(PallasError::UnknownScenario(self.workload.scenario.clone()));
        }
        if self.pipeline.micro_batch == 0
            || self.pipeline.global_batch % self.pipeline.micro_batch != 0
        {
            return Err(PallasError::InvalidConfig(format!(
                "global_batch {} must be a positive multiple of micro_batch {}",
                self.pipeline.global_batch, self.pipeline.micro_batch
            )));
        }
        let need: usize = self
            .workload
            .agents
            .iter()
            .map(|a| a.model.instance_devices())
            .sum();
        if need > self.cluster.total_devices() {
            return Err(PallasError::InvalidConfig(format!(
                "cluster too small: {} devices needed for one instance per agent, {} available",
                need,
                self.cluster.total_devices()
            )));
        }
        if self.checkpoint.every == Some(0) {
            return Err(PallasError::InvalidConfig(
                "checkpoint.every must be positive (omit it to disable checkpointing)".into(),
            ));
        }
        self.faults.validate()?;
        Ok(())
    }
}

/// Keys [`ExperimentConfig::from_json`] reads at the document root.
const TOP_KEYS: &[&str] = &[
    "checkpoint",
    "cluster",
    "faults",
    "framework",
    "pipeline",
    "scenario",
    "seed",
    "steps",
    "trace",
    "workload",
    "workload_mode",
    "workload_overrides",
];
/// Keys read inside `"pipeline"`.
const PIPELINE_KEYS: &[&str] = &["delta_threshold", "global_batch", "micro_batch"];
/// Keys read inside `"cluster"`.
const CLUSTER_KEYS: &[&str] = &["devices_per_node", "nodes"];
/// Keys read inside `"workload_overrides"`.
const OVERRIDE_KEYS: &[&str] = &["group_size", "queries_per_step", "scenario", "trace"];
/// Keys read inside `"checkpoint"`.
const CHECKPOINT_KEYS: &[&str] = &["dir", "every"];

/// Reject any key of `obj` not in `valid` — typos fail loudly with the
/// nearest valid key instead of being silently ignored.
fn check_keys(
    obj: &BTreeMap<String, Json>,
    valid: &'static [&'static str],
    section: &'static str,
) -> Result<(), PallasError> {
    for key in obj.keys() {
        if !valid.contains(&key.as_str()) {
            return Err(PallasError::unknown_key(key, section, valid));
        }
    }
    Ok(())
}

pub fn framework_by_name(name: &str) -> Option<Framework> {
    let n = name.to_ascii_lowercase().replace(['-', '_', ' '], "");
    Some(match n.as_str() {
        "masrl" => Framework::mas_rl(),
        "distrl" => Framework::dist_rl(),
        "marti" => Framework::marti(),
        "flexmarl" => Framework::flexmarl(),
        "flexmarlnobalancing" | "wobalancing" => Framework::flexmarl_no_balancing(),
        "flexmarlnoasync" | "woasync" => Framework::flexmarl_no_async(),
        _ => return None,
    })
}

/// Summary map for reports.
pub fn framework_flags(fw: &Framework) -> BTreeMap<&'static str, bool> {
    let mut m = BTreeMap::new();
    m.insert("disaggregated", fw.disaggregated);
    m.insert("parallel_sampling", fw.parallel_sampling);
    m.insert("load_balancing", fw.load_balancing);
    m.insert("async_pipeline", fw.async_pipeline);
    m.insert("agent_centric", fw.agent_centric);
    m.insert("one_step_async_rollout", fw.one_step_async_rollout);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for fw in Framework::all_baselines() {
            ExperimentConfig::new(WorkloadConfig::ma(), fw).validate().unwrap();
            ExperimentConfig::new(WorkloadConfig::ca(), fw).validate().unwrap();
        }
    }

    #[test]
    fn tiny_preset_validates_and_is_small() {
        let wl = WorkloadConfig::tiny();
        assert_eq!(wl.queries_per_step, 2);
        assert_eq!(wl.group_size, 2);
        ExperimentConfig::new(wl, Framework::flexmarl()).validate().unwrap();
    }

    #[test]
    fn ma_core_agents_carry_majority() {
        let wl = WorkloadConfig::ma();
        let core = wl.core_agents();
        assert!(core.len() >= 2 && core.len() <= 3);
        let total: f64 = wl.agents.iter().map(|a| a.invoke_weight).sum();
        let core_w: f64 = core.iter().map(|&i| wl.agents[i].invoke_weight).sum();
        // Obs. 2: core agents handle the majority (paper: >76% of requests
        // including repeat calls).
        assert!(core_w / total > 0.45, "core share {}", core_w / total);
    }

    #[test]
    fn scale_configs_table4() {
        let c1 = WorkloadConfig::scale_config(&[(5, ModelScale::B32)]);
        assert_eq!(c1.agents.len(), 5);
        assert_eq!(c1.name, "5x32B");
        let c2 = WorkloadConfig::scale_config(&[(3, ModelScale::B32), (7, ModelScale::B14)]);
        assert_eq!(c2.agents.len(), 10);
        let c3 = WorkloadConfig::scale_config(&[(15, ModelScale::B14)]);
        assert_eq!(c3.agents.len(), 15);
    }

    #[test]
    fn framework_lookup() {
        assert_eq!(framework_by_name("MAS-RL").unwrap().name, "MAS-RL");
        assert_eq!(framework_by_name("flexmarl").unwrap().name, "FlexMARL");
        assert!(framework_by_name("nope").is_none());
        assert!(!framework_by_name("wo_async").unwrap().async_pipeline);
    }

    #[test]
    fn json_overrides() {
        let j = parse(
            r#"{"workload": "CA", "framework": "DistRL", "seed": 7,
                "pipeline": {"micro_batch": 8}, "steps": 3}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workload.name, "CA");
        assert_eq!(cfg.framework.name, "DistRL");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.pipeline.micro_batch, 8);
        assert_eq!(cfg.steps, 3);
    }

    #[test]
    fn workload_mode_parsed_and_defaulted() {
        let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        assert_eq!(cfg.workload_mode, WorkloadMode::Eager);
        let j = parse(r#"{"workload": "MA", "workload_mode": "lazy"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().workload_mode, WorkloadMode::Lazy);
        let j = parse(r#"{"workload_mode": "Eager"}"#).unwrap(); // case-insensitive
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().workload_mode, WorkloadMode::Eager);
        let j = parse(r#"{"workload_mode": "greedy"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("unknown workload_mode 'greedy'"), "{err}");
        assert!(WorkloadMode::from_name("LAZY") == Some(WorkloadMode::Lazy));
    }

    #[test]
    fn scenario_parsed_and_validated() {
        let j =
            parse(r#"{"workload": "MA", "scenario": "core_skew", "trace": "t.jsonl"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workload.scenario, "core_skew");
        assert_eq!(cfg.workload.trace.as_deref(), Some("t.jsonl"));
        cfg.validate().unwrap();
        // The workload_overrides namespace works too (and wins).
        let j2 = parse(
            r#"{"scenario": "uniform",
                "workload_overrides": {"scenario": "tool_heavy"}}"#,
        )
        .unwrap();
        let cfg2 = ExperimentConfig::from_json(&j2).unwrap();
        assert_eq!(cfg2.workload.scenario, "tool_heavy");
        let mut bad = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        bad.workload.scenario = "gibberish".into();
        let err = bad.validate().unwrap_err();
        assert_eq!(err, PallasError::UnknownScenario("gibberish".into()));
        assert!(err.to_string().contains("gibberish"), "{err}");
    }

    #[test]
    fn misspelled_key_fails_loudly_with_suggestion() {
        // Satellite regression: `scenarrio` used to be silently ignored
        // (the run quietly fell back to "baseline").
        let j = parse(r#"{"workload": "MA", "scenarrio": "core_skew"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        match &err {
            PallasError::UnknownKey { key, section, nearest, .. } => {
                assert_eq!(key, "scenarrio");
                assert_eq!(*section, "config");
                assert_eq!(nearest.as_deref(), Some("scenario"));
            }
            other => panic!("expected UnknownKey, got {other:?}"),
        }
        assert_eq!(
            err.to_string(),
            "unknown config key 'scenarrio' (did you mean 'scenario'?)"
        );
    }

    #[test]
    fn unknown_nested_keys_rejected_per_section() {
        let j = parse(r#"{"pipeline": {"micro_batc": 8}}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert!(
            matches!(&err, PallasError::UnknownKey { section: "pipeline", nearest: Some(n), .. }
                     if n == "micro_batch"),
            "{err:?}"
        );
        let j = parse(r#"{"cluster": {"node": 4}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = parse(r#"{"workload_overrides": {"group_sizes": 4}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        // A distant junk key lists the valid set instead of guessing.
        let j = parse(r#"{"zzz_qqq": 1}"#).unwrap();
        let msg = ExperimentConfig::from_json(&j).unwrap_err().to_string();
        assert!(msg.contains("valid:"), "{msg}");
    }

    #[test]
    fn non_object_sections_rejected() {
        let j = parse(r#"{"pipeline": 3}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("'pipeline' must be a JSON object"), "{err}");
        let j = parse("[1,2]").unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn invalid_micro_batch_rejected() {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.pipeline.micro_batch = 7;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn faults_section_parsed_from_json() {
        // A config with no faults section carries the empty plan.
        let j = parse(r#"{"workload": "MA"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert!(cfg.faults.is_empty());
        // Preset base + field overlays, like every other section.
        let j = parse(
            r#"{"faults": {"preset": "chaos", "crashes": 3,
                           "recovery": "retry", "seed": 99}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.faults.crashes, 3);
        assert_eq!(cfg.faults.seed, Some(99));
        assert_eq!(cfg.faults.recovery.as_deref(), Some("retry"));
        cfg.validate().unwrap();
    }

    #[test]
    fn faults_unknown_key_rejected_with_suggestion() {
        let j = parse(r#"{"faults": {"crashs": 2, "horizon_s": 60}}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert!(
            matches!(&err, PallasError::UnknownKey { section: "faults", nearest: Some(n), .. }
                     if n == "crashes"),
            "{err:?}"
        );
        // Non-object section rejected like pipeline/cluster.
        let j = parse(r#"{"faults": 3}"#).unwrap();
        let msg = ExperimentConfig::from_json(&j).unwrap_err().to_string();
        assert!(msg.contains("'faults' must be a JSON object"), "{msg}");
    }

    #[test]
    fn faults_validation_runs_under_config_validate() {
        let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        cfg.faults.crashes = 2; // generators without a horizon
        assert!(cfg.validate().is_err());
        cfg.faults.horizon_s = 60.0;
        cfg.validate().unwrap();
    }

    #[test]
    fn model_scale_monotonics() {
        assert!(ModelScale::B32.decode_tps() < ModelScale::B14.decode_tps());
        assert!(ModelScale::B32.train_state_bytes() > ModelScale::B14.train_state_bytes());
        assert!(ModelScale::B32.instance_devices() >= ModelScale::B14.instance_devices());
        // Fig. 1a anchor: a capped 8192-token *call* costs ~60–120 s for
        // 14B; worst multi-call query chains then land near ~170 s
        // (checked at chain level in workload::tests::fig1a_latency_anchor).
        let worst = 8192.0 / ModelScale::B14.decode_tps();
        assert!(worst > 60.0 && worst < 120.0, "worst={worst}");
    }
}
