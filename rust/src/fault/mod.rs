//! Deterministic fault plane: injected failures for the step engine.
//!
//! The ROADMAP's elasticity experiments (worker preemption, stragglers,
//! mid-run cluster resize) need a fault model that keeps the PR 3
//! determinism contract: same seed + same plan ⇒ byte-identical output
//! for any `--jobs N`. This module provides the *plan* side of that
//! contract — a [`FaultConfig`] is resolved **up front** into a flat,
//! time-sorted [`FaultSpec`] list ([`FaultConfig::resolve`]), purely
//! from `(seed, config)`, and the engine injects each spec as a
//! first-class event in [`crate::sim::EventQueue`]. Nothing about fault
//! timing depends on engine state, thread count, or wall clock.
//!
//! What happens *after* a fault strikes is the recovery side, owned by
//! [`crate::policy::RecoveryPolicy`] (the fifth member of
//! [`crate::policy::PolicyBundle`]); the taxonomy here only describes
//! the failures themselves (DESIGN.md §10):
//!
//! | kind              | effect                                         |
//! |-------------------|------------------------------------------------|
//! | `InstanceCrash`   | one agent's idlest live instance dies now      |
//! | `NodePreemption`  | the `n` idlest instances across agents die     |
//! | `Straggler`       | one agent's decode slows `slowdown`× for a while |
//! | `SwapLinkFlap`    | swap transfers pay `added_s` extra for a while |
//! | `ClusterResize`   | instances are added / gracefully drained       |
//!
//! Liveness rule: destructive faults (crash/preemption, negative
//! resize) never remove an agent's *last* live instance — every
//! recovery policy can then still drive the run to completion (or, for
//! fail-fast, abort it deliberately).

use crate::error::PallasError;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// What goes wrong. Parameters are the fault's own magnitude; *which*
/// concrete instance dies is decided deterministically at strike time
/// by the engine (idlest-first, lowest-id tie-break).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Kill `agent`'s idlest live instance (skipped if it has one).
    InstanceCrash { agent: usize },
    /// Kill the `n` idlest instances across agents (a node going
    /// away), spread over the agents with the most replicas first.
    NodePreemption { n: usize },
    /// Degrade `agent`: decode of calls submitted during the window
    /// runs `slowdown`× slower.
    Straggler {
        agent: usize,
        slowdown: f64,
        duration_s: f64,
    },
    /// Swap-link congestion: every swap-in/out started during the
    /// window pays `added_s` extra seconds.
    SwapLinkFlap { added_s: f64, duration_s: f64 },
    /// Mid-run cluster resize: `delta > 0` adds instances (thinnest
    /// agent pools first), `delta < 0` gracefully drains the idlest
    /// instances of the fattest pools (displaced requests re-queue;
    /// planned resizes lose no work).
    ClusterResize { delta: i64 },
}

impl FaultKind {
    /// Stable kind label (config `kind` field, event/report tagging).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::InstanceCrash { .. } => "instance_crash",
            FaultKind::NodePreemption { .. } => "node_preemption",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::SwapLinkFlap { .. } => "swap_link_flap",
            FaultKind::ClusterResize { .. } => "cluster_resize",
        }
    }

    /// Agent index this fault targets, if it targets one.
    pub fn agent(&self) -> Option<usize> {
        match self {
            FaultKind::InstanceCrash { agent } | FaultKind::Straggler { agent, .. } => Some(*agent),
            _ => None,
        }
    }

    /// Fold an out-of-range agent index into range. Scenario presets
    /// can reshape the ensemble (e.g. `hetero_scale`), so an explicit
    /// spec written against the base agent list stays total.
    fn clamp_agent(&mut self, n_agents: usize) {
        if n_agents == 0 {
            return;
        }
        match self {
            FaultKind::InstanceCrash { agent } | FaultKind::Straggler { agent, .. } => {
                *agent %= n_agents;
            }
            _ => {}
        }
    }
}

/// One timed fault: at virtual time `t`, `kind` strikes.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub t: f64,
    pub kind: FaultKind,
}

/// The `faults` config section: explicit timed specs plus seeded
/// stochastic generators. `Default` is the empty plan — byte-identical
/// to a build that never heard of faults.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultConfig {
    /// Explicit timed faults, injected verbatim (after agent clamping).
    pub specs: Vec<FaultSpec>,
    /// Stochastic generator counts; each kind draws its strike times
    /// and parameters from its own decorrelated PRNG stream, so adding
    /// stragglers cannot move where the crashes land.
    pub crashes: usize,
    pub preemptions: usize,
    pub stragglers: usize,
    pub flaps: usize,
    pub resizes: usize,
    /// Virtual-time horizon generated strike times are drawn from;
    /// required (> 0) when any generator count is set, and an upper
    /// bound on explicit spec times when set.
    pub horizon_s: f64,
    /// Generator seed override; `None` uses the experiment seed.
    pub seed: Option<u64>,
    /// Recovery-policy override by name (`fail_fast` / `retry` /
    /// `degrade`); `None` keeps the framework's derived policy.
    pub recovery: Option<String>,
}

// Decorrelated PRNG stream ids, one per generator kind.
const STREAM_CRASH: u64 = 0xfa01;
const STREAM_PREEMPT: u64 = 0xfa02;
const STREAM_STRAGGLE: u64 = 0xfa03;
const STREAM_FLAP: u64 = 0xfa04;
const STREAM_RESIZE: u64 = 0xfa05;

/// Keys the `faults` config section accepts (sorted).
pub const FAULT_KEYS: &[&str] = &[
    "crashes",
    "flaps",
    "horizon_s",
    "preemptions",
    "preset",
    "recovery",
    "resizes",
    "seed",
    "specs",
    "stragglers",
];
/// Keys an explicit fault-spec object accepts (sorted).
pub const SPEC_KEYS: &[&str] = &[
    "added_s",
    "agent",
    "delta",
    "duration_s",
    "kind",
    "n",
    "slowdown",
    "t",
];

impl FaultConfig {
    /// No faults configured at all — the engine skips plan resolution
    /// and injects nothing (the no-fault fast path).
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
            && self.crashes + self.preemptions + self.stragglers + self.flaps + self.resizes == 0
    }

    /// Resolve the full fault plan for one run: explicit specs (agents
    /// clamped into `[0, n_agents)`) plus generated faults, sorted by
    /// strike time (stable — equal times keep spec order, and the
    /// engine's event queue breaks remaining ties by push order). Pure
    /// in `(self, cfg_seed, n_agents)`.
    pub fn resolve(&self, cfg_seed: u64, n_agents: usize) -> Vec<FaultSpec> {
        let mut plan = self.specs.clone();
        for s in &mut plan {
            s.kind.clamp_agent(n_agents);
        }
        let seed = self.seed.unwrap_or(cfg_seed);
        let h = self.horizon_s;
        if n_agents > 0 && h > 0.0 {
            let mut rng = Pcg64::with_stream(seed, STREAM_CRASH);
            for _ in 0..self.crashes {
                plan.push(FaultSpec {
                    t: rng.range_f64(0.0, h),
                    kind: FaultKind::InstanceCrash {
                        agent: rng.below(n_agents as u64) as usize,
                    },
                });
            }
            let mut rng = Pcg64::with_stream(seed, STREAM_PREEMPT);
            for _ in 0..self.preemptions {
                plan.push(FaultSpec {
                    t: rng.range_f64(0.0, h),
                    kind: FaultKind::NodePreemption {
                        n: 1 + rng.below(2) as usize,
                    },
                });
            }
            let mut rng = Pcg64::with_stream(seed, STREAM_STRAGGLE);
            for _ in 0..self.stragglers {
                plan.push(FaultSpec {
                    t: rng.range_f64(0.0, h),
                    kind: FaultKind::Straggler {
                        agent: rng.below(n_agents as u64) as usize,
                        slowdown: rng.range_f64(1.5, 4.0),
                        duration_s: rng.range_f64(10.0, 60.0),
                    },
                });
            }
            let mut rng = Pcg64::with_stream(seed, STREAM_FLAP);
            for _ in 0..self.flaps {
                plan.push(FaultSpec {
                    t: rng.range_f64(0.0, h),
                    kind: FaultKind::SwapLinkFlap {
                        added_s: rng.range_f64(0.2, 2.0),
                        duration_s: rng.range_f64(5.0, 30.0),
                    },
                });
            }
            let mut rng = Pcg64::with_stream(seed, STREAM_RESIZE);
            for _ in 0..self.resizes {
                let delta = if rng.below(2) == 0 { 1 } else { -1 };
                plan.push(FaultSpec {
                    t: rng.range_f64(0.0, h),
                    kind: FaultKind::ClusterResize { delta },
                });
            }
        }
        plan.sort_by(|a, b| a.t.partial_cmp(&b.t).expect("fault times are finite"));
        plan
    }

    /// Parse the `faults` config section. A `preset` key seeds the
    /// config from [`preset`]; every other key then overrides it.
    /// Unknown keys fail loudly with the nearest-valid suggestion, like
    /// the rest of the config surface.
    pub fn from_json(j: &Json) -> Result<FaultConfig, PallasError> {
        let Some(obj) = j.as_obj() else {
            return Err(PallasError::InvalidConfig(
                "'faults' must be a JSON object".into(),
            ));
        };
        for key in obj.keys() {
            if !FAULT_KEYS.contains(&key.as_str()) {
                return Err(PallasError::unknown_key(key, "faults", FAULT_KEYS));
            }
        }
        let mut cfg = match j.at(&["preset"]).and_then(Json::as_str) {
            Some(p) => preset(p).ok_or_else(|| {
                PallasError::InvalidConfig(format!(
                    "unknown fault preset '{p}' (valid: {})",
                    preset_names().join(", ")
                ))
            })?,
            None => FaultConfig::default(),
        };
        if let Some(v) = j.at(&["crashes"]).and_then(Json::as_usize) {
            cfg.crashes = v;
        }
        if let Some(v) = j.at(&["preemptions"]).and_then(Json::as_usize) {
            cfg.preemptions = v;
        }
        if let Some(v) = j.at(&["stragglers"]).and_then(Json::as_usize) {
            cfg.stragglers = v;
        }
        if let Some(v) = j.at(&["flaps"]).and_then(Json::as_usize) {
            cfg.flaps = v;
        }
        if let Some(v) = j.at(&["resizes"]).and_then(Json::as_usize) {
            cfg.resizes = v;
        }
        if let Some(v) = j.at(&["horizon_s"]).and_then(Json::as_f64) {
            cfg.horizon_s = v;
        }
        if let Some(v) = j.at(&["seed"]).and_then(Json::as_u64) {
            cfg.seed = Some(v);
        }
        if let Some(v) = j.at(&["recovery"]).and_then(Json::as_str) {
            cfg.recovery = Some(v.to_string());
        }
        if let Some(arr) = j.at(&["specs"]).and_then(Json::as_arr) {
            cfg.specs = arr.iter().map(spec_from_json).collect::<Result<_, _>>()?;
        }
        Ok(cfg)
    }

    /// Semantic validation (wired into
    /// [`crate::config::ExperimentConfig::validate`]): rates, delays
    /// and strike times must be finite and non-negative; generators
    /// need a positive horizon; explicit times stay within the horizon
    /// when one is set; a recovery override must name a known policy.
    pub fn validate(&self) -> Result<(), PallasError> {
        if !self.horizon_s.is_finite() || self.horizon_s < 0.0 {
            return Err(PallasError::InvalidConfig(format!(
                "faults.horizon_s must be finite and non-negative, got {}",
                self.horizon_s
            )));
        }
        let generated =
            self.crashes + self.preemptions + self.stragglers + self.flaps + self.resizes;
        if generated > 0 && self.horizon_s <= 0.0 {
            return Err(PallasError::InvalidConfig(
                "faults.horizon_s must be > 0 when stochastic fault generators are set".into(),
            ));
        }
        if let Some(name) = &self.recovery {
            if crate::policy::recovery_by_name(name).is_none() {
                return Err(PallasError::InvalidConfig(format!(
                    "unknown recovery policy '{name}' (valid: fail_fast, retry, degrade)"
                )));
            }
        }
        for (i, s) in self.specs.iter().enumerate() {
            if !s.t.is_finite() || s.t < 0.0 {
                return Err(PallasError::InvalidConfig(format!(
                    "fault spec {i}: time {} must be finite and non-negative",
                    s.t
                )));
            }
            if self.horizon_s > 0.0 && s.t > self.horizon_s {
                return Err(PallasError::InvalidConfig(format!(
                    "fault spec {i}: time {} is beyond faults.horizon_s {}",
                    s.t, self.horizon_s
                )));
            }
            match &s.kind {
                FaultKind::Straggler {
                    slowdown,
                    duration_s,
                    ..
                } => {
                    if !slowdown.is_finite() || *slowdown < 1.0 {
                        return Err(PallasError::InvalidConfig(format!(
                            "fault spec {i}: slowdown {slowdown} must be finite and >= 1"
                        )));
                    }
                    if !duration_s.is_finite() || *duration_s < 0.0 {
                        return Err(PallasError::InvalidConfig(format!(
                            "fault spec {i}: duration_s {duration_s} must be finite and \
                             non-negative"
                        )));
                    }
                }
                FaultKind::SwapLinkFlap { added_s, duration_s } => {
                    if !added_s.is_finite() || *added_s < 0.0 {
                        return Err(PallasError::InvalidConfig(format!(
                            "fault spec {i}: added_s {added_s} must be finite and non-negative"
                        )));
                    }
                    if !duration_s.is_finite() || *duration_s < 0.0 {
                        return Err(PallasError::InvalidConfig(format!(
                            "fault spec {i}: duration_s {duration_s} must be finite and \
                             non-negative"
                        )));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

fn spec_from_json(j: &Json) -> Result<FaultSpec, PallasError> {
    let Some(obj) = j.as_obj() else {
        return Err(PallasError::InvalidConfig(
            "each fault spec must be a JSON object".into(),
        ));
    };
    for key in obj.keys() {
        if !SPEC_KEYS.contains(&key.as_str()) {
            return Err(PallasError::unknown_key(key, "faults.specs", SPEC_KEYS));
        }
    }
    let t = j
        .at(&["t"])
        .and_then(Json::as_f64)
        .ok_or_else(|| PallasError::InvalidConfig("fault spec missing 't'".into()))?;
    let kind_s = j
        .at(&["kind"])
        .and_then(Json::as_str)
        .ok_or_else(|| PallasError::InvalidConfig("fault spec missing 'kind'".into()))?;
    let agent = j.at(&["agent"]).and_then(Json::as_usize).unwrap_or(0);
    let kind = match kind_s {
        "instance_crash" => FaultKind::InstanceCrash { agent },
        "node_preemption" => FaultKind::NodePreemption {
            n: j.at(&["n"]).and_then(Json::as_usize).unwrap_or(1),
        },
        "straggler" => FaultKind::Straggler {
            agent,
            slowdown: j.at(&["slowdown"]).and_then(Json::as_f64).unwrap_or(2.0),
            duration_s: j.at(&["duration_s"]).and_then(Json::as_f64).unwrap_or(30.0),
        },
        "swap_link_flap" => FaultKind::SwapLinkFlap {
            added_s: j.at(&["added_s"]).and_then(Json::as_f64).unwrap_or(0.5),
            duration_s: j.at(&["duration_s"]).and_then(Json::as_f64).unwrap_or(30.0),
        },
        "cluster_resize" => FaultKind::ClusterResize {
            delta: j.at(&["delta"]).and_then(Json::as_f64).unwrap_or(1.0) as i64,
        },
        other => {
            return Err(PallasError::InvalidConfig(format!(
                "unknown fault kind '{other}' (valid: instance_crash, node_preemption, \
                 straggler, swap_link_flap, cluster_resize)"
            )))
        }
    };
    Ok(FaultSpec { t, kind })
}

/// Named fault presets (the CLI's `--faults <preset>`).
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "preemption",
        "preemption_retry",
        "preemption_degrade",
        "preemption_failfast",
        "flaky",
        "flaky_failfast",
        "chaos",
    ]
}

/// Look up a fault preset by name. The `preemption*` family is the
/// recovery-policy proving ground (same strikes, different recovery);
/// `flaky` is non-fatal degradation only (no instance losses — safe
/// even under fail-fast); `chaos` exercises every stochastic generator.
pub fn preset(name: &str) -> Option<FaultConfig> {
    let n = name.to_ascii_lowercase().replace('-', "_");
    let preemption = |recovery: Option<&str>| FaultConfig {
        specs: vec![
            FaultSpec {
                t: 5.0,
                kind: FaultKind::NodePreemption { n: 1 },
            },
            FaultSpec {
                t: 9.0,
                kind: FaultKind::InstanceCrash { agent: 1 },
            },
        ],
        recovery: recovery.map(str::to_string),
        ..FaultConfig::default()
    };
    let flaky = |recovery: Option<&str>| FaultConfig {
        specs: vec![
            FaultSpec {
                t: 3.0,
                kind: FaultKind::Straggler {
                    agent: 1,
                    slowdown: 2.0,
                    duration_s: 40.0,
                },
            },
            FaultSpec {
                t: 6.0,
                kind: FaultKind::SwapLinkFlap {
                    added_s: 0.5,
                    duration_s: 30.0,
                },
            },
            FaultSpec {
                t: 12.0,
                kind: FaultKind::ClusterResize { delta: 2 },
            },
        ],
        recovery: recovery.map(str::to_string),
        ..FaultConfig::default()
    };
    Some(match n.as_str() {
        "preemption" => preemption(None),
        "preemption_retry" => preemption(Some("retry")),
        "preemption_degrade" => preemption(Some("degrade")),
        "preemption_failfast" => preemption(Some("fail_fast")),
        "flaky" => flaky(None),
        "flaky_failfast" => flaky(Some("fail_fast")),
        "chaos" => FaultConfig {
            crashes: 1,
            preemptions: 1,
            stragglers: 2,
            flaps: 1,
            resizes: 1,
            horizon_s: 120.0,
            ..FaultConfig::default()
        },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn empty_config_resolves_to_empty_plan() {
        let cfg = FaultConfig::default();
        assert!(cfg.is_empty());
        assert!(cfg.resolve(2048, 8).is_empty());
        cfg.validate().unwrap();
    }

    #[test]
    fn resolve_is_pure_and_sorted() {
        let cfg = preset("chaos").unwrap();
        let a = cfg.resolve(2048, 8);
        let b = cfg.resolve(2048, 8);
        assert_eq!(a, b, "same seed must resolve the same plan");
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].t <= w[1].t), "plan sorted by t");
        let c = cfg.resolve(7, 8);
        assert_ne!(a, c, "different seed, different plan");
    }

    #[test]
    fn generator_streams_are_decorrelated() {
        // Adding stragglers must not move where the crashes land.
        let mut just_crashes = preset("chaos").unwrap();
        just_crashes.preemptions = 0;
        just_crashes.stragglers = 0;
        just_crashes.flaps = 0;
        just_crashes.resizes = 0;
        let mut with_stragglers = just_crashes.clone();
        with_stragglers.stragglers = 3;
        let crashes_of = |plan: &[FaultSpec]| {
            plan.iter()
                .filter(|s| matches!(s.kind, FaultKind::InstanceCrash { .. }))
                .cloned()
                .collect::<Vec<_>>()
        };
        assert_eq!(
            crashes_of(&just_crashes.resolve(2048, 8)),
            crashes_of(&with_stragglers.resolve(2048, 8))
        );
    }

    #[test]
    fn generated_agents_in_range() {
        let mut cfg = FaultConfig::default();
        cfg.crashes = 16;
        cfg.stragglers = 16;
        cfg.horizon_s = 100.0;
        for n_agents in [1usize, 3, 8] {
            for s in cfg.resolve(2048, n_agents) {
                if let Some(a) = s.kind.agent() {
                    assert!(a < n_agents, "agent {a} out of range for {n_agents}");
                }
                assert!(s.t >= 0.0 && s.t <= 100.0);
            }
        }
    }

    #[test]
    fn explicit_agents_clamped_into_range() {
        let cfg = FaultConfig {
            specs: vec![FaultSpec {
                t: 1.0,
                kind: FaultKind::InstanceCrash { agent: 11 },
            }],
            ..FaultConfig::default()
        };
        let plan = cfg.resolve(0, 8);
        assert_eq!(plan[0].kind, FaultKind::InstanceCrash { agent: 3 });
    }

    #[test]
    fn presets_resolve_and_validate() {
        for name in preset_names() {
            let cfg = preset(name).unwrap_or_else(|| panic!("preset {name}"));
            cfg.validate().unwrap();
            assert!(!cfg.is_empty(), "{name} must configure faults");
            assert!(!cfg.resolve(2048, 8).is_empty(), "{name} resolves empty");
        }
        assert!(preset("nope").is_none());
        // Spelling variants normalize.
        assert_eq!(preset("preemption-retry"), preset("preemption_retry"));
    }

    #[test]
    fn validation_rejects_nan_and_negatives() {
        let mut cfg = FaultConfig::default();
        cfg.horizon_s = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.horizon_s = -1.0;
        assert!(cfg.validate().is_err());
        cfg.horizon_s = 0.0;
        cfg.crashes = 1; // generator without a horizon
        assert!(cfg.validate().is_err());
        cfg.horizon_s = 50.0;
        cfg.validate().unwrap();

        let bad_slow = FaultConfig {
            specs: vec![FaultSpec {
                t: 1.0,
                kind: FaultKind::Straggler {
                    agent: 0,
                    slowdown: 0.5,
                    duration_s: 10.0,
                },
            }],
            ..FaultConfig::default()
        };
        assert!(bad_slow.validate().is_err());
        let bad_t = FaultConfig {
            specs: vec![FaultSpec {
                t: -3.0,
                kind: FaultKind::NodePreemption { n: 1 },
            }],
            ..FaultConfig::default()
        };
        assert!(bad_t.validate().is_err());
        let beyond = FaultConfig {
            horizon_s: 10.0,
            specs: vec![FaultSpec {
                t: 11.0,
                kind: FaultKind::NodePreemption { n: 1 },
            }],
            ..FaultConfig::default()
        };
        let err = beyond.validate().unwrap_err();
        assert!(err.to_string().contains("beyond"), "{err}");
        let bad_recovery = FaultConfig {
            recovery: Some("yolo".into()),
            ..FaultConfig::default()
        };
        assert!(bad_recovery.validate().is_err());
    }

    #[test]
    fn json_roundtrip_and_unknown_keys() {
        let j = parse(
            r#"{"preset": "preemption", "recovery": "degrade",
                "crashes": 2, "horizon_s": 60.0, "seed": 9}"#,
        )
        .unwrap();
        let cfg = FaultConfig::from_json(&j).unwrap();
        assert_eq!(cfg.specs.len(), 2, "preset specs kept");
        assert_eq!(cfg.recovery.as_deref(), Some("degrade"), "override wins");
        assert_eq!(cfg.crashes, 2);
        assert_eq!(cfg.horizon_s, 60.0);
        assert_eq!(cfg.seed, Some(9));
        cfg.validate().unwrap();

        // Typo'd key → did-you-mean suggestion, like the rest of config.
        let j = parse(r#"{"recoverry": "retry"}"#).unwrap();
        let err = FaultConfig::from_json(&j).unwrap_err();
        match &err {
            PallasError::UnknownKey { section, nearest, .. } => {
                assert_eq!(*section, "faults");
                assert_eq!(nearest.as_deref(), Some("recovery"));
            }
            other => panic!("expected UnknownKey, got {other:?}"),
        }
        // Bad preset / non-object / unknown kind.
        let j = parse(r#"{"preset": "zzz"}"#).unwrap();
        assert!(FaultConfig::from_json(&j).is_err());
        assert!(FaultConfig::from_json(&parse("[1]").unwrap()).is_err());
        let j = parse(r#"{"specs": [{"t": 1.0, "kind": "meteor"}]}"#).unwrap();
        assert!(FaultConfig::from_json(&j).is_err());
        let j = parse(r#"{"specs": [{"t": 1.0, "kind": "straggler", "agnet": 1}]}"#).unwrap();
        assert!(matches!(
            FaultConfig::from_json(&j).unwrap_err(),
            PallasError::UnknownKey { section: "faults.specs", .. }
        ));
    }

    #[test]
    fn explicit_specs_parse_every_kind() {
        let j = parse(
            r#"{"specs": [
                {"t": 1.0, "kind": "instance_crash", "agent": 2},
                {"t": 2.0, "kind": "node_preemption", "n": 3},
                {"t": 3.0, "kind": "straggler", "agent": 1, "slowdown": 3.0,
                 "duration_s": 20.0},
                {"t": 4.0, "kind": "swap_link_flap", "added_s": 1.5, "duration_s": 10.0},
                {"t": 5.0, "kind": "cluster_resize", "delta": -2}
            ]}"#,
        )
        .unwrap();
        let cfg = FaultConfig::from_json(&j).unwrap();
        assert_eq!(cfg.specs.len(), 5);
        assert_eq!(cfg.specs[0].kind, FaultKind::InstanceCrash { agent: 2 });
        assert_eq!(cfg.specs[1].kind, FaultKind::NodePreemption { n: 3 });
        assert_eq!(cfg.specs[4].kind, FaultKind::ClusterResize { delta: -2 });
        cfg.validate().unwrap();
    }
}
