//! Synthetic tiny-corpus for the *real* end-to-end MARL run
//! (examples/marl_train.rs): a learnable stand-in for the proprietary
//! e-commerce dialogues.
//!
//! Task: each user query carries a *topic* token in its prompt. Each
//! agent role has a per-topic target token band; the rule-based reward is
//! the fraction of generated tokens inside the agent's band for the
//! query's topic (plus a small repetition penalty). GRPO should push each
//! policy's generation distribution into its band — observable as a
//! rising mean reward and falling GRPO loss within tens of steps, which
//! is what EXPERIMENTS.md §E2E records.

use crate::util::rng::Pcg64;

pub const N_TOPICS: usize = 8;
/// Width of each target token band.
pub const BAND: usize = 32;

#[derive(Debug, Clone, Copy)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub prompt_len: usize,
    /// Conditional task (default): the target band depends on the
    /// query topic — the model must read the prompt. Unconditional
    /// ("easy") mode: per-agent fixed band — learnable by shifting the
    /// marginal output distribution, which a 3M-param policy does within
    /// tens of GRPO steps (used for the demonstrative e2e curve).
    pub conditional: bool,
}

impl CorpusConfig {
    pub fn new(vocab: usize, prompt_len: usize) -> Self {
        assert!(vocab >= N_TOPICS * BAND + N_TOPICS + 16);
        CorpusConfig { vocab, prompt_len, conditional: true }
    }

    pub fn easy(vocab: usize, prompt_len: usize) -> Self {
        CorpusConfig { conditional: false, ..Self::new(vocab, prompt_len) }
    }

    /// Topic marker tokens occupy the top of the vocab.
    pub fn topic_token(&self, topic: usize) -> i32 {
        (self.vocab - N_TOPICS + topic) as i32
    }

    /// Target band for (agent, topic): agents are offset so different
    /// agents must learn different mappings (no parameter sharing, §8.1).
    pub fn band_start(&self, agent: usize, topic: usize) -> usize {
        if self.conditional {
            ((agent * 3 + topic) % N_TOPICS) * BAND
        } else {
            ((agent * 3) % N_TOPICS) * BAND
        }
    }

    pub fn in_band(&self, agent: usize, topic: usize, token: i32) -> bool {
        let start = self.band_start(agent, topic) as i32;
        token >= start && token < start + BAND as i32
    }

    /// Sample a prompt: filler tokens + the topic marker at a fixed
    /// position (so small models can attend to it easily).
    pub fn make_prompt(&self, rng: &mut Pcg64, topic: usize) -> Vec<i32> {
        assert!(topic < N_TOPICS);
        let filler_lo = N_TOPICS * BAND;
        let filler_hi = self.vocab - N_TOPICS;
        let mut p: Vec<i32> = (0..self.prompt_len)
            .map(|_| rng.range_f64(filler_lo as f64, filler_hi as f64) as i32)
            .collect();
        // Marker at position 0 and repeated at the end for recency.
        p[0] = self.topic_token(topic);
        let last = self.prompt_len - 1;
        p[last] = self.topic_token(topic);
        p
    }

    pub fn topic_of_prompt(&self, prompt: &[i32]) -> Option<usize> {
        let t0 = (self.vocab - N_TOPICS) as i32;
        prompt
            .iter()
            .find(|&&t| t >= t0)
            .map(|&t| (t - t0) as usize)
    }

    /// Rule-based reward in [0, 1]: band hit-rate with a distinct-token
    /// bonus (discourages collapsing onto one token).
    pub fn reward(&self, agent: usize, topic: usize, response: &[i32]) -> f64 {
        if response.is_empty() {
            return 0.0;
        }
        let hits = response
            .iter()
            .filter(|&&t| self.in_band(agent, topic, t))
            .count() as f64;
        let hit_rate = hits / response.len() as f64;
        let mut distinct: Vec<i32> = response.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let diversity = distinct.len() as f64 / response.len() as f64;
        0.9 * hit_rate + 0.1 * diversity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusConfig {
        CorpusConfig::new(512, 32)
    }

    #[test]
    fn prompt_carries_recoverable_topic() {
        let c = cfg();
        let mut rng = Pcg64::new(1);
        for topic in 0..N_TOPICS {
            let p = c.make_prompt(&mut rng, topic);
            assert_eq!(p.len(), 32);
            assert_eq!(c.topic_of_prompt(&p), Some(topic));
            // Filler never collides with markers.
            assert!(p[1..31].iter().all(|&t| (t as usize) < 512 - N_TOPICS));
        }
    }

    #[test]
    fn reward_extremes() {
        let c = cfg();
        let start = c.band_start(2, 5) as i32;
        let perfect: Vec<i32> = (start..start + 16).collect();
        assert!(c.reward(2, 5, &perfect) > 0.95);
        let miss: Vec<i32> = vec![(N_TOPICS * BAND) as i32 + 5; 16];
        assert!(c.reward(2, 5, &miss) < 0.11);
        assert_eq!(c.reward(0, 0, &[]), 0.0);
    }

    #[test]
    fn repetition_penalized() {
        let c = cfg();
        let start = c.band_start(0, 0) as i32;
        let varied: Vec<i32> = (start..start + 16).collect();
        let collapsed = vec![start; 16];
        assert!(c.reward(0, 0, &varied) > c.reward(0, 0, &collapsed));
    }

    #[test]
    fn easy_mode_band_is_topic_independent() {
        let c = CorpusConfig::easy(512, 32);
        for a in 0..4 {
            let b0 = c.band_start(a, 0);
            assert!((0..N_TOPICS).all(|t| c.band_start(a, t) == b0));
        }
        // Conditional mode differs across topics.
        let c2 = cfg();
        assert!((0..N_TOPICS).any(|t| c2.band_start(0, t) != c2.band_start(0, 0)));
    }

    #[test]
    fn agents_have_distinct_bands() {
        let c = cfg();
        let bands: Vec<usize> = (0..4).map(|a| c.band_start(a, 0)).collect();
        let mut uniq = bands.clone();
        uniq.sort();
        uniq.dedup();
        assert!(uniq.len() >= 3, "{bands:?}");
    }
}
