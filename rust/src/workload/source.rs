//! The lazy workload plane (DESIGN.md §11): pull-based step sources.
//!
//! Eager resolution materializes `Vec<StepWorkload>` up front, so
//! memory scales as steps × agents. A [`WorkloadSource`] instead hands
//! the engine one [`StepWorkload`] per pull, generated or parsed on
//! demand — peak memory becomes O(live window), independent of run
//! length. Three adapters cover every resolution path:
//!
//! - [`VecSource`] — wraps an eagerly materialized vector; the golden
//!   reference the lazy plane is byte-diffed against in CI;
//! - [`ScenarioSource`] — generates each step on demand from a resolved
//!   [`Scenario`] (possible because generation is deterministic in
//!   `(seed, step)` — no step depends on its predecessor);
//! - [`TraceSource`] — streams a recorded trace through
//!   [`TraceReader`], one line per step.
//!
//! # Determinism contract
//!
//! A source must yield the *same* step sequence the eager path would
//! materialize — lazy vs eager runs are byte-identical end to end
//! (metrics JSON, JSONL event streams, trace round-trips), enforced by
//! the `lazy-equivalence` CI job and the property tests in
//! `tests/lazy.rs`.
//!
//! # Error discipline
//!
//! `next_step` is a plain pull (`Option`, not `Result`) so trivial
//! sources stay trivial; a source that can fail mid-stream (trace
//! parse errors surface lazily) stores the error and reports `None`,
//! and the engine retrieves the cause via [`WorkloadSource::take_error`]
//! before deciding whether exhaustion was expected.

use crate::config::WorkloadConfig;
use crate::error::PallasError;
use crate::workload::{trace::TraceReader, Scenario, StepWorkload};

/// How many steps a source still has to yield.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LenHint {
    /// Exactly this many steps remain (all in-repo sources).
    Exact(usize),
    /// At least this many steps remain (unbounded/external feeds).
    AtLeast(usize),
}

impl LenHint {
    /// The guaranteed floor on remaining steps.
    pub fn lower_bound(self) -> usize {
        match self {
            LenHint::Exact(n) | LenHint::AtLeast(n) => n,
        }
    }

    /// The remaining count, when known exactly.
    pub fn exact(self) -> Option<usize> {
        match self {
            LenHint::Exact(n) => Some(n),
            LenHint::AtLeast(_) => None,
        }
    }
}

/// A pull-iterator of per-step workloads, consumed by the engine one
/// step at a time through `Session::pump_step`.
///
/// `Send` because resolved experiments cross sweep-executor threads.
pub trait WorkloadSource: Send {
    /// Yield the next step's workload, or `None` when exhausted (or
    /// failed — see [`WorkloadSource::take_error`]).
    fn next_step(&mut self) -> Option<StepWorkload>;

    /// Exact-or-lower-bound count of steps *remaining* (not total).
    fn len_hint(&self) -> LenHint;

    /// If the previous `None` was a failure rather than clean
    /// exhaustion, surface the typed cause (takes it; idempotent
    /// afterwards). Default: infallible source.
    fn take_error(&mut self) -> Option<PallasError> {
        None
    }

    /// Resume support (DESIGN.md §12): advance the source past its
    /// first `n` steps without the engine seeing them, leaving it
    /// positioned exactly where a run that pulled `n` steps would be.
    /// The default pulls and discards — correct for any source;
    /// [`ScenarioSource`] overrides with an O(1) cursor jump. Returns
    /// an error if the source ends (or fails) before `n` steps.
    fn fast_forward(&mut self, n: usize) -> Result<(), PallasError> {
        for i in 0..n {
            if self.next_step().is_none() {
                return Err(self.take_error().unwrap_or_else(|| {
                    PallasError::InvalidConfig(format!(
                        "workload source ended at step {i} while resuming to step {n}"
                    ))
                }));
            }
        }
        Ok(())
    }
}

/// Eager adapter: a pre-materialized `Vec<StepWorkload>`, yielded in
/// order. This is the classic path and the golden reference for every
/// lazy-equivalence diff.
#[derive(Debug)]
pub struct VecSource {
    steps: std::vec::IntoIter<StepWorkload>,
}

impl VecSource {
    pub fn new(steps: Vec<StepWorkload>) -> VecSource {
        VecSource {
            steps: steps.into_iter(),
        }
    }
}

impl WorkloadSource for VecSource {
    fn next_step(&mut self) -> Option<StepWorkload> {
        self.steps.next()
    }

    fn len_hint(&self) -> LenHint {
        LenHint::Exact(self.steps.len())
    }
}

/// Lazy generator adapter: produces step `s` on demand via
/// [`Scenario::step`] over an already-shaped config. Identical output
/// to eager materialization because generation is deterministic in
/// `(seed, step)`.
pub struct ScenarioSource {
    shaped: WorkloadConfig,
    scen: Box<dyn Scenario>,
    seed: u64,
    next: usize,
    total: usize,
}

impl ScenarioSource {
    /// `shaped` must already be the scenario-shaped, canonically named
    /// config (the output of `scenario::resolve`).
    pub fn new(
        shaped: WorkloadConfig,
        scen: Box<dyn Scenario>,
        seed: u64,
        total: usize,
    ) -> ScenarioSource {
        ScenarioSource {
            shaped,
            scen,
            seed,
            next: 0,
            total,
        }
    }
}

impl WorkloadSource for ScenarioSource {
    fn next_step(&mut self) -> Option<StepWorkload> {
        if self.next >= self.total {
            return None;
        }
        let s = self.next;
        self.next += 1;
        Some(self.scen.step(&self.shaped, self.seed, s))
    }

    fn len_hint(&self) -> LenHint {
        LenHint::Exact(self.total - self.next)
    }

    /// O(1): generation is pure in `(seed, step)`, so resuming is a
    /// cursor assignment — no steps are generated and discarded.
    fn fast_forward(&mut self, n: usize) -> Result<(), PallasError> {
        if self.next != 0 {
            return Err(PallasError::InvalidConfig(format!(
                "fast_forward on a source already at step {}",
                self.next
            )));
        }
        if n > self.total {
            return Err(PallasError::InvalidConfig(format!(
                "cannot resume to step {n}: scenario has {} steps",
                self.total
            )));
        }
        self.next = n;
        Ok(())
    }
}

impl std::fmt::Debug for ScenarioSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScenarioSource")
            .field("scenario", &self.scen.name())
            .field("seed", &self.seed)
            .field("next", &self.next)
            .field("total", &self.total)
            .finish()
    }
}

/// Streaming replay adapter: pulls steps out of a [`TraceReader`] one
/// JSONL line at a time. Parse errors surface lazily — the source
/// reports `None` and hands the typed error to the engine through
/// [`WorkloadSource::take_error`].
#[derive(Debug)]
pub struct TraceSource {
    reader: TraceReader,
    error: Option<PallasError>,
}

impl TraceSource {
    /// Wrap an opened reader (header already validated).
    pub fn new(reader: TraceReader) -> TraceSource {
        TraceSource {
            reader,
            error: None,
        }
    }

    /// Live-feed constructor: stream JSONL records from any buffered
    /// reader (stdin pipe, file tail, socket). Header validation and
    /// the typed truncated-record diagnostics are identical to the
    /// file path — see [`TraceReader::from_reader`].
    pub fn from_reader(src: Box<dyn std::io::BufRead + Send>) -> Result<TraceSource, PallasError> {
        Ok(TraceSource::new(TraceReader::from_reader(src)?))
    }

    /// Stream records from stdin (`--trace -`): blocks on each pull
    /// until the writer side of the pipe delivers the next line, so a
    /// live producer drives the run one step at a time.
    pub fn stdin() -> Result<TraceSource, PallasError> {
        Ok(TraceSource::new(TraceReader::open_path("-")?))
    }
}

impl WorkloadSource for TraceSource {
    fn next_step(&mut self) -> Option<StepWorkload> {
        match self.reader.next_step() {
            Ok(w) => w,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn len_hint(&self) -> LenHint {
        LenHint::Exact(self.reader.steps() - self.reader.steps_yielded())
    }

    fn take_error(&mut self) -> Option<PallasError> {
        self.error.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::{scenario, Trace};

    fn small(name: &str) -> WorkloadConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 2;
        wl.scenario = name.to_string();
        wl
    }

    fn drain(src: &mut dyn WorkloadSource) -> Vec<StepWorkload> {
        let mut out = Vec::new();
        while let Some(w) = src.next_step() {
            out.push(w);
        }
        out
    }

    #[test]
    fn vec_source_yields_in_order_with_exact_hints() {
        let tr = Trace::record(&small("baseline"), 7, 3).unwrap();
        let mut src = VecSource::new(tr.steps.clone());
        assert_eq!(src.len_hint(), LenHint::Exact(3));
        assert_eq!(src.next_step().unwrap(), tr.steps[0]);
        assert_eq!(src.len_hint(), LenHint::Exact(2));
        assert_eq!(drain(&mut src), &tr.steps[1..]);
        assert_eq!(src.len_hint(), LenHint::Exact(0));
        assert!(src.next_step().is_none());
        assert!(src.take_error().is_none());
    }

    #[test]
    fn scenario_source_matches_eager_materialization_for_every_preset() {
        for name in scenario::names() {
            let (shaped, scen) = scenario::resolve(&small(name)).unwrap();
            let eager: Vec<StepWorkload> = (0..4).map(|s| scen.step(&shaped, 2048, s)).collect();
            let (shaped2, scen2) = scenario::resolve(&small(name)).unwrap();
            let mut src = ScenarioSource::new(shaped2, scen2, 2048, 4);
            assert_eq!(src.len_hint(), LenHint::Exact(4));
            assert_eq!(drain(&mut src), eager, "{name} lazy != eager");
            assert_eq!(src.len_hint(), LenHint::Exact(0));
        }
    }

    #[test]
    fn trace_source_streams_the_recorded_steps() {
        let tr = Trace::record(&small("flash_crowd"), 2048, 3).unwrap();
        let reader = crate::workload::TraceReader::from_text(&tr.to_jsonl()).unwrap();
        let mut src = TraceSource::new(reader);
        assert_eq!(src.len_hint(), LenHint::Exact(3));
        assert_eq!(drain(&mut src), tr.steps);
        assert!(src.take_error().is_none(), "clean exhaustion");
    }

    #[test]
    fn trace_source_surfaces_parse_errors_via_take_error() {
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        let dup = format!("{}\n{}\n{}\n", lines[0], lines[1], lines[1]);
        let reader = crate::workload::TraceReader::from_text(&dup).unwrap();
        let mut src = TraceSource::new(reader);
        assert!(src.next_step().is_some());
        assert!(src.next_step().is_none(), "error must read as exhaustion");
        let err = src.take_error().expect("typed cause must be retrievable");
        assert!(err.to_string().contains("out of order"), "{err}");
        assert!(src.take_error().is_none(), "take_error is take-once");
    }

    #[test]
    fn fast_forward_positions_sources_like_n_pulls() {
        // Scenario override (O(1) cursor jump) and the default
        // pull-and-discard path (trace) both land exactly where a run
        // that consumed n steps would be.
        let (shaped, scen) = scenario::resolve(&small("bursty")).unwrap();
        let eager: Vec<StepWorkload> = (0..5).map(|s| scen.step(&shaped, 2048, s)).collect();
        let (shaped2, scen2) = scenario::resolve(&small("bursty")).unwrap();
        let mut src = ScenarioSource::new(shaped2, scen2, 2048, 5);
        src.fast_forward(3).unwrap();
        assert_eq!(src.len_hint(), LenHint::Exact(2));
        assert_eq!(drain(&mut src), &eager[3..]);

        let tr = Trace::record(&small("flash_crowd"), 2048, 5).unwrap();
        let reader = crate::workload::TraceReader::from_text(&tr.to_jsonl()).unwrap();
        let mut src = TraceSource::new(reader);
        src.fast_forward(3).unwrap();
        assert_eq!(src.len_hint(), LenHint::Exact(2));
        assert_eq!(drain(&mut src), &tr.steps[3..]);

        // Past-the-end resume is a typed error, not a panic.
        let (shaped3, scen3) = scenario::resolve(&small("bursty")).unwrap();
        let mut src = ScenarioSource::new(shaped3, scen3, 2048, 5);
        assert!(src.fast_forward(6).is_err());
        let tr2 = Trace::record(&small("baseline"), 7, 2).unwrap();
        let reader2 = crate::workload::TraceReader::from_text(&tr2.to_jsonl()).unwrap();
        let mut src2 = TraceSource::new(reader2);
        assert!(src2.fast_forward(3).is_err());
    }

    #[test]
    fn trace_source_from_reader_is_the_live_feed_path() {
        // The serve driver replays line streams from arbitrary readers;
        // equivalence with the in-memory path and lazy error surfacing
        // (truncated feed → take_error) are the contract.
        let tr = Trace::record(&small("diurnal"), 2048, 3).unwrap();
        let jsonl = tr.to_jsonl();
        let boxed: Box<dyn std::io::BufRead + Send> =
            Box::new(std::io::Cursor::new(jsonl.as_bytes().to_vec()));
        let mut src = TraceSource::from_reader(boxed).unwrap();
        assert_eq!(src.len_hint(), LenHint::Exact(3));
        assert_eq!(drain(&mut src), tr.steps);
        assert!(src.take_error().is_none());

        let cut = jsonl[..jsonl.trim_end().len() - 10].to_string();
        let boxed: Box<dyn std::io::BufRead + Send> =
            Box::new(std::io::Cursor::new(cut.into_bytes()));
        let mut src = TraceSource::from_reader(boxed).unwrap();
        while src.next_step().is_some() {}
        let err = src.take_error().expect("truncated feed must surface typed");
        assert!(err.to_string().contains("truncated final record"), "{err}");
    }

    #[test]
    fn len_hint_accessors() {
        assert_eq!(LenHint::Exact(5).lower_bound(), 5);
        assert_eq!(LenHint::Exact(5).exact(), Some(5));
        assert_eq!(LenHint::AtLeast(2).lower_bound(), 2);
        assert_eq!(LenHint::AtLeast(2).exact(), None);
    }
}
