//! Workload trace record/replay (JSONL).
//!
//! Any generated workload stream can be captured to a JSONL trace and
//! replayed bit-identically — across the simulator, the baselines, and
//! the wall-clock serving example. Replay is exact because Rust's f64
//! Display emits the shortest round-tripping decimal and our JSON
//! parser is correctly rounded: `tokens`/`env_s` survive the text
//! round-trip bit-for-bit.
//!
//! Schema (one JSON object per line; documented in DESIGN.md §2):
//!
//! ```text
//! {"kind":"header","version":1,"workload":"MA","scenario":"bursty",
//!  "seed":2048,"n_agents":8,"steps":3}
//! {"kind":"step","step":0,"trajectories":[
//!    {"query":0,"candidate":0,"calls":[[agent,tokens,env_s],...]},...]}
//! ```
//!
//! The header carries provenance (base workload name, scenario, seed)
//! so a replay run can reconstruct the recording config; the step lines
//! carry the full per-call data, so replay never re-generates.

use crate::config::WorkloadConfig;
use crate::error::PallasError;
use crate::util::json::{parse, Json};
use crate::workload::{scenario, CallSpec, StepWorkload, TrajectorySpec};

pub const TRACE_VERSION: u64 = 1;

/// Largest seed the JSONL header can carry losslessly (JSON numbers
/// are f64: integers are exact up to 2^53).
pub const MAX_SEED: u64 = 1 << 53;

#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Base workload name at record time ("MA"/"CA"/custom).
    pub workload: String,
    /// Scenario preset the trace was generated under.
    pub scenario: String,
    /// Generator seed at record time.
    pub seed: u64,
    /// Agent count of the shaped config (replay sanity check).
    pub n_agents: usize,
    pub steps: Vec<StepWorkload>,
}

impl Trace {
    /// Generate and capture `steps` MARL steps of the scenario named in
    /// `wl.scenario`.
    pub fn record(wl: &WorkloadConfig, seed: u64, steps: usize) -> Result<Trace, PallasError> {
        if steps == 0 {
            return Err(PallasError::Trace(
                "cannot record a zero-step trace (nothing to replay)".into(),
            ));
        }
        // The header stores the seed as a JSON number (f64): above 2^53
        // it would silently round, breaking the round-trip contract.
        if seed > MAX_SEED {
            return Err(PallasError::Trace(format!(
                "seed {seed} exceeds 2^53 and cannot round-trip through the JSONL header"
            )));
        }
        let (shaped, scen) = scenario::resolve(wl)?;
        let step_wls = (0..steps).map(|s| scen.step(&shaped, seed, s)).collect();
        Ok(Trace {
            workload: wl.name.clone(),
            scenario: scen.name().to_string(),
            seed,
            n_agents: shaped.agents.len(),
            steps: step_wls,
        })
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let header = Json::obj(vec![
            ("kind", Json::str("header")),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("workload", Json::str(self.workload.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("n_agents", Json::num(self.n_agents as f64)),
            ("steps", Json::num(self.steps.len() as f64)),
        ]);
        out.push_str(&header.to_string());
        out.push('\n');
        for w in &self.steps {
            let trajs = Json::arr(w.trajectories.iter().map(|t| {
                Json::obj(vec![
                    ("query", Json::num(t.query as f64)),
                    ("candidate", Json::num(t.candidate as f64)),
                    (
                        "calls",
                        Json::arr(t.calls.iter().map(|c| {
                            Json::arr([
                                Json::num(c.agent as f64),
                                Json::num(c.tokens),
                                Json::num(c.env_s),
                            ])
                        })),
                    ),
                ])
            }));
            let line = Json::obj(vec![
                ("kind", Json::str("step")),
                ("step", Json::num(w.step as f64)),
                ("trajectories", trajs),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Trace, PallasError> {
        let mut header: Option<(String, String, u64, usize, usize)> = None;
        let mut steps: Vec<StepWorkload> = Vec::new();
        // A final line that fails to parse AND lacks the trailing
        // newline the recorder always writes is almost certainly a
        // truncated copy (interrupted download, partial write). Name
        // that specifically instead of the generic parse error.
        let n_lines = text.lines().count();
        let missing_final_newline = !text.is_empty() && !text.ends_with('\n');
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let j = parse(line).map_err(|e| {
                if lineno + 1 == n_lines && missing_final_newline {
                    PallasError::Trace(format!(
                        "trace line {}: truncated final record (file ends mid-line; \
                         re-record or re-copy the trace)",
                        lineno + 1
                    ))
                } else {
                    PallasError::Trace(format!("trace line {}: {e}", lineno + 1))
                }
            })?;
            let kind = j.at(&["kind"]).and_then(Json::as_str).ok_or_else(|| {
                PallasError::Trace(format!("trace line {}: missing 'kind'", lineno + 1))
            })?;
            match kind {
                "header" => {
                    // A second header would silently replace the
                    // provenance (n_agents/seed/scenario) that earlier
                    // step lines were already validated against.
                    if header.is_some() {
                        return Err(PallasError::Trace(format!(
                            "trace line {}: duplicate header",
                            lineno + 1
                        )));
                    }
                    let version = j.at(&["version"]).and_then(Json::as_u64).unwrap_or(0);
                    if version != TRACE_VERSION {
                        return Err(PallasError::Trace(format!(
                            "unsupported trace version {version} (want {TRACE_VERSION})"
                        )));
                    }
                    // Replay re-shapes the config from this name, so an
                    // unknown preset (edited file, newer recorder) must
                    // fail here as a parse error, not later as a panic.
                    let scen = req_str(&j, "scenario", lineno)?;
                    if scenario::by_name(&scen).is_none() {
                        return Err(PallasError::UnknownScenario(scen));
                    }
                    header = Some((
                        req_str(&j, "workload", lineno)?,
                        scen,
                        req_u64(&j, "seed", lineno)?,
                        req_u64(&j, "n_agents", lineno)? as usize,
                        req_u64(&j, "steps", lineno)? as usize,
                    ));
                }
                "step" => {
                    let Some((_, _, _, n_agents, _)) = &header else {
                        return Err(PallasError::Trace("trace: step line before header".into()));
                    };
                    let sw = parse_step(&j, *n_agents, lineno)?;
                    // Step lines must be contiguous and in record
                    // order: a duplicated/reordered line would replay
                    // a different sequence than was recorded, silently.
                    if sw.step != steps.len() {
                        return Err(PallasError::Trace(format!(
                            "trace line {}: step {} out of order (expected {})",
                            lineno + 1,
                            sw.step,
                            steps.len()
                        )));
                    }
                    steps.push(sw);
                }
                other => {
                    return Err(PallasError::Trace(format!(
                        "trace line {}: unknown kind '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        let (workload, scenario, seed, n_agents, n_steps) =
            header.ok_or_else(|| PallasError::Trace("trace: no header line".into()))?;
        if steps.len() != n_steps {
            return Err(PallasError::Trace(format!(
                "trace: header says {n_steps} steps, found {}",
                steps.len()
            )));
        }
        // Mirror the record-side rule: an empty trace has nothing to
        // replay and would index-panic in the engine.
        if steps.is_empty() {
            return Err(PallasError::Trace(
                "trace has no steps (nothing to replay)".into(),
            ));
        }
        Ok(Trace {
            workload,
            scenario,
            seed,
            n_agents,
            steps,
        })
    }

    pub fn write_file(&self, path: &str) -> Result<(), PallasError> {
        std::fs::write(path, self.to_jsonl()).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })
    }

    pub fn read_file(path: &str) -> Result<Trace, PallasError> {
        let text = std::fs::read_to_string(path).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })?;
        Self::from_jsonl(&text)
    }

    pub fn total_calls(&self) -> usize {
        self.steps.iter().map(|s| s.total_calls()).sum()
    }
}

fn req_str(j: &Json, key: &str, lineno: usize) -> Result<String, PallasError> {
    j.at(&[key])
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| PallasError::Trace(format!("trace line {}: missing '{key}'", lineno + 1)))
}

fn req_u64(j: &Json, key: &str, lineno: usize) -> Result<u64, PallasError> {
    j.at(&[key])
        .and_then(Json::as_u64)
        .ok_or_else(|| PallasError::Trace(format!("trace line {}: missing '{key}'", lineno + 1)))
}

fn parse_step(j: &Json, n_agents: usize, lineno: usize) -> Result<StepWorkload, PallasError> {
    let step = req_u64(j, "step", lineno)? as usize;
    let trajs = j
        .at(&["trajectories"])
        .and_then(Json::as_arr)
        .ok_or_else(|| {
            PallasError::Trace(format!("trace line {}: missing 'trajectories'", lineno + 1))
        })?;
    let mut trajectories = Vec::with_capacity(trajs.len());
    for t in trajs {
        let query = req_u64(t, "query", lineno)? as usize;
        let candidate = req_u64(t, "candidate", lineno)? as usize;
        let calls_j = t
            .at(&["calls"])
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                PallasError::Trace(format!(
                    "trace line {}: trajectory missing 'calls'",
                    lineno + 1
                ))
            })?;
        let mut calls = Vec::with_capacity(calls_j.len());
        for c in calls_j {
            let triple = c.as_arr().filter(|a| a.len() == 3).ok_or_else(|| {
                PallasError::Trace(format!(
                    "trace line {}: call is not [agent,tokens,env_s]",
                    lineno + 1
                ))
            })?;
            let agent = triple[0].as_u64().ok_or_else(|| {
                PallasError::Trace(format!("trace line {}: bad agent", lineno + 1))
            })? as usize;
            // Bound here so a corrupted trace fails as a parse error,
            // not an index panic deep inside the engine.
            if agent >= n_agents {
                return Err(PallasError::Trace(format!(
                    "trace line {}: agent {agent} out of range (n_agents {n_agents})",
                    lineno + 1
                )));
            }
            calls.push(CallSpec {
                agent,
                tokens: triple[1].as_f64().ok_or_else(|| {
                    PallasError::Trace(format!("trace line {}: bad tokens", lineno + 1))
                })?,
                env_s: triple[2].as_f64().ok_or_else(|| {
                    PallasError::Trace(format!("trace line {}: bad env_s", lineno + 1))
                })?,
            });
        }
        trajectories.push(TrajectorySpec {
            query,
            candidate,
            calls,
        });
    }
    Ok(StepWorkload { step, trajectories })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn small(scenario: &str) -> WorkloadConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 2;
        wl.scenario = scenario.to_string();
        wl
    }

    #[test]
    fn jsonl_roundtrip_is_bit_identical_for_every_preset() {
        for name in scenario::names() {
            let tr = Trace::record(&small(name), 2048, 2).unwrap();
            let back = Trace::from_jsonl(&tr.to_jsonl()).unwrap();
            // PartialEq on f64 fields: exact, not approximate.
            assert_eq!(tr, back, "{name} round-trip drifted");
            assert_eq!(back.scenario, name);
            assert!(back.total_calls() > 0);
        }
    }

    #[test]
    fn replayed_trace_matches_regeneration() {
        let wl = small("core_skew");
        let tr = Trace::record(&wl, 7, 3).unwrap();
        let (shaped, scen) = scenario::resolve(&wl).unwrap();
        for (s, recorded) in tr.steps.iter().enumerate() {
            assert_eq!(recorded, &scen.step(&shaped, 7, s));
        }
    }

    #[test]
    fn file_roundtrip() {
        let tr = Trace::record(&small("bursty"), 2048, 2).unwrap();
        let path = std::env::temp_dir().join("flexmarl_trace_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        let back = Trace::read_file(&path).unwrap();
        assert_eq!(tr, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_traces_rejected() {
        assert!(Trace::from_jsonl("").is_err());
        assert!(Trace::from_jsonl("not json\n").is_err());
        // Step before header.
        assert!(Trace::from_jsonl(r#"{"kind":"step","step":0,"trajectories":[]}"#).is_err());
        // Unknown kind.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let bad = tr.to_jsonl().replace("\"header\"", "\"headerz\"");
        assert!(Trace::from_jsonl(&bad).is_err());
        // Header/step-count mismatch.
        let jsonl = tr.to_jsonl();
        let header_only = jsonl.lines().next().unwrap();
        assert!(Trace::from_jsonl(header_only).is_err());
        // Wrong version.
        let wrong = jsonl.replace("\"version\":1", "\"version\":99");
        assert!(Trace::from_jsonl(&wrong).is_err());
    }

    #[test]
    fn out_of_order_step_lines_rejected() {
        // A duplicated step line keeps the header count right but
        // replays a different sequence than recorded — must be a
        // parse error, not a silent divergence.
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 steps");
        let dup = format!("{}\n{}\n{}\n", lines[0], lines[1], lines[1]);
        let err = Trace::from_jsonl(&dup).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");
        let swapped = format!("{}\n{}\n{}\n", lines[0], lines[2], lines[1]);
        assert!(Trace::from_jsonl(&swapped).is_err());
        // A second header mid-file must not rebind provenance.
        let reheader = format!("{}\n{}\n{}\n{}\n", lines[0], lines[1], lines[0], lines[2]);
        let err = Trace::from_jsonl(&reheader).unwrap_err();
        assert!(err.to_string().contains("duplicate header"), "{err}");
    }

    #[test]
    fn out_of_range_agent_is_a_parse_error() {
        // Regression: a corrupted call agent index must fail at parse
        // time, not panic inside the engine.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let jsonl = tr.to_jsonl();
        let a0 = &tr.steps[0].trajectories[0].calls[0];
        let needle = format!("[{},", a0.agent);
        let bad = jsonl.replacen(&needle, "[99,", 1);
        assert_ne!(bad, jsonl, "test setup: call triple not found");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn unknown_scenario_fails_record() {
        let mut wl = small("baseline");
        wl.scenario = "nope".into();
        assert!(Trace::record(&wl, 1, 1).is_err());
        // Zero steps: nothing to replay — rejected at record time.
        assert!(Trace::record(&small("baseline"), 1, 0).is_err());
    }

    #[test]
    fn unknown_header_scenario_is_a_parse_error() {
        // Replay re-shapes the config from the header's scenario name,
        // so a name this build doesn't know must fail at parse time.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let bad = tr
            .to_jsonl()
            .replace("\"scenario\":\"baseline\"", "\"scenario\":\"from_the_future\"");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        assert_eq!(err, PallasError::UnknownScenario("from_the_future".into()));
        assert!(err.to_string().contains("from_the_future"), "{err}");
    }

    #[test]
    fn truncated_final_line_named_specifically() {
        // Regression (DESIGN.md §10 hardening): a trace cut mid-write
        // (partial copy, interrupted download) used to surface as an
        // opaque JSON parse error; it must name the truncation and the
        // line it happened on.
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        // Chop the file mid-way through the final record (drop the
        // trailing newline and the last 10 bytes).
        let cut = &jsonl[..jsonl.trim_end().len() - 10];
        assert!(!cut.ends_with('\n'), "test setup: cut must end mid-line");
        let err = Trace::from_jsonl(cut).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated final record"), "{msg}");
        assert!(msg.contains("trace line 3"), "{msg}");
        assert!(matches!(err, PallasError::Trace(_)), "{err:?}");
    }

    #[test]
    fn corrupt_but_complete_final_line_keeps_generic_error() {
        // The truncation diagnosis requires the missing trailing
        // newline; a complete-but-corrupt last line is still reported
        // as the parse error it is.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let jsonl = tr.to_jsonl();
        let bad = jsonl.replace("\"trajectories\":", "\"trajectories\"~");
        assert!(bad.ends_with('\n'), "test setup: newline must survive");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        let msg = err.to_string();
        assert!(!msg.contains("truncated"), "{msg}");
        assert!(msg.contains("trace line 2"), "{msg}");
    }

    #[test]
    fn oversized_seed_rejected_at_record() {
        // Seeds above 2^53 cannot round-trip through a JSON number.
        let err = Trace::record(&small("baseline"), MAX_SEED + 1, 1).unwrap_err();
        assert!(err.to_string().contains("2^53"), "{err}");
        assert!(Trace::record(&small("baseline"), MAX_SEED, 1).is_ok());
    }
}
