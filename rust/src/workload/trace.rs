//! Workload trace record/replay (JSONL).
//!
//! Any generated workload stream can be captured to a JSONL trace and
//! replayed bit-identically — across the simulator, the baselines, and
//! the wall-clock serving example. Replay is exact because Rust's f64
//! Display emits the shortest round-tripping decimal and our JSON
//! parser is correctly rounded: `tokens`/`env_s` survive the text
//! round-trip bit-for-bit.
//!
//! Schema (one JSON object per line; documented in DESIGN.md §2):
//!
//! ```text
//! {"kind":"header","version":1,"workload":"MA","scenario":"bursty",
//!  "seed":2048,"n_agents":8,"steps":3}
//! {"kind":"step","step":0,"trajectories":[
//!    {"query":0,"candidate":0,"calls":[[agent,tokens,env_s],...]},...]}
//! ```
//!
//! The header carries provenance (base workload name, scenario, seed)
//! so a replay run can reconstruct the recording config; the step lines
//! carry the full per-call data, so replay never re-generates.

use crate::config::WorkloadConfig;
use crate::error::PallasError;
use crate::util::json::{parse, Json};
use crate::workload::{scenario, CallSpec, StepWorkload, TrajectorySpec};
use std::io::BufRead;

pub const TRACE_VERSION: u64 = 1;

/// Largest seed the JSONL header can carry losslessly (JSON numbers
/// are f64: integers are exact up to 2^53).
pub const MAX_SEED: u64 = 1 << 53;

#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Base workload name at record time ("MA"/"CA"/custom).
    pub workload: String,
    /// Scenario preset the trace was generated under.
    pub scenario: String,
    /// Generator seed at record time.
    pub seed: u64,
    /// Agent count of the shaped config (replay sanity check).
    pub n_agents: usize,
    pub steps: Vec<StepWorkload>,
}

impl Trace {
    /// Generate and capture `steps` MARL steps of the scenario named in
    /// `wl.scenario`.
    pub fn record(wl: &WorkloadConfig, seed: u64, steps: usize) -> Result<Trace, PallasError> {
        if steps == 0 {
            return Err(PallasError::Trace(
                "cannot record a zero-step trace (nothing to replay)".into(),
            ));
        }
        // The header stores the seed as a JSON number (f64): above 2^53
        // it would silently round, breaking the round-trip contract.
        if seed > MAX_SEED {
            return Err(PallasError::Trace(format!(
                "seed {seed} exceeds 2^53 and cannot round-trip through the JSONL header"
            )));
        }
        let (shaped, scen) = scenario::resolve(wl)?;
        let step_wls = (0..steps).map(|s| scen.step(&shaped, seed, s)).collect();
        Ok(Trace {
            workload: wl.name.clone(),
            scenario: scen.name().to_string(),
            seed,
            n_agents: shaped.agents.len(),
            steps: step_wls,
        })
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let header = Json::obj(vec![
            ("kind", Json::str("header")),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("workload", Json::str(self.workload.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("n_agents", Json::num(self.n_agents as f64)),
            ("steps", Json::num(self.steps.len() as f64)),
        ]);
        out.push_str(&header.to_string());
        out.push('\n');
        for w in &self.steps {
            let trajs = Json::arr(w.trajectories.iter().map(trajectory_to_json));
            let line = Json::obj(vec![
                ("kind", Json::str("step")),
                ("step", Json::num(w.step as f64)),
                ("trajectories", trajs),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Trace, PallasError> {
        let mut header: Option<(String, String, u64, usize, usize)> = None;
        let mut steps: Vec<StepWorkload> = Vec::new();
        // A final line that fails to parse AND lacks the trailing
        // newline the recorder always writes is almost certainly a
        // truncated copy (interrupted download, partial write). Name
        // that specifically instead of the generic parse error.
        let n_lines = text.lines().count();
        let missing_final_newline = !text.is_empty() && !text.ends_with('\n');
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let j = parse(line).map_err(|e| {
                if lineno + 1 == n_lines && missing_final_newline {
                    PallasError::Trace(format!(
                        "trace line {}: truncated final record (file ends mid-line; \
                         re-record or re-copy the trace)",
                        lineno + 1
                    ))
                } else {
                    PallasError::Trace(format!("trace line {}: {e}", lineno + 1))
                }
            })?;
            let kind = j.at(&["kind"]).and_then(Json::as_str).ok_or_else(|| {
                PallasError::Trace(format!("trace line {}: missing 'kind'", lineno + 1))
            })?;
            match kind {
                "header" => {
                    // A second header would silently replace the
                    // provenance (n_agents/seed/scenario) that earlier
                    // step lines were already validated against.
                    if header.is_some() {
                        return Err(PallasError::Trace(format!(
                            "trace line {}: duplicate header",
                            lineno + 1
                        )));
                    }
                    let version = j.at(&["version"]).and_then(Json::as_u64).unwrap_or(0);
                    if version != TRACE_VERSION {
                        return Err(PallasError::Trace(format!(
                            "unsupported trace version {version} (want {TRACE_VERSION})"
                        )));
                    }
                    // Replay re-shapes the config from this name, so an
                    // unknown preset (edited file, newer recorder) must
                    // fail here as a parse error, not later as a panic.
                    let scen = req_str(&j, "scenario", lineno)?;
                    if scenario::by_name(&scen).is_none() {
                        return Err(PallasError::UnknownScenario(scen));
                    }
                    header = Some((
                        req_str(&j, "workload", lineno)?,
                        scen,
                        req_u64(&j, "seed", lineno)?,
                        req_u64(&j, "n_agents", lineno)? as usize,
                        req_u64(&j, "steps", lineno)? as usize,
                    ));
                }
                "step" => {
                    let Some((_, _, _, n_agents, _)) = &header else {
                        return Err(PallasError::Trace("trace: step line before header".into()));
                    };
                    let sw = parse_step(&j, *n_agents, lineno)?;
                    // Step lines must be contiguous and in record
                    // order: a duplicated/reordered line would replay
                    // a different sequence than was recorded, silently.
                    if sw.step != steps.len() {
                        return Err(PallasError::Trace(format!(
                            "trace line {}: step {} out of order (expected {})",
                            lineno + 1,
                            sw.step,
                            steps.len()
                        )));
                    }
                    steps.push(sw);
                }
                other => {
                    return Err(PallasError::Trace(format!(
                        "trace line {}: unknown kind '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        let (workload, scenario, seed, n_agents, n_steps) =
            header.ok_or_else(|| PallasError::Trace("trace: no header line".into()))?;
        if steps.len() != n_steps {
            return Err(PallasError::Trace(format!(
                "trace: header says {n_steps} steps, found {}",
                steps.len()
            )));
        }
        // Mirror the record-side rule: an empty trace has nothing to
        // replay and would index-panic in the engine.
        if steps.is_empty() {
            return Err(PallasError::Trace(
                "trace has no steps (nothing to replay)".into(),
            ));
        }
        Ok(Trace {
            workload,
            scenario,
            seed,
            n_agents,
            steps,
        })
    }

    pub fn write_file(&self, path: &str) -> Result<(), PallasError> {
        std::fs::write(path, self.to_jsonl()).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })
    }

    pub fn read_file(path: &str) -> Result<Trace, PallasError> {
        let text = std::fs::read_to_string(path).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })?;
        Self::from_jsonl(&text)
    }

    /// [`Trace::read_file`] with the CLI's `-` convention: `"-"` reads
    /// the whole trace from stdin (piped feeds), anything else is a
    /// filesystem path. Errors keep the same shapes — stdin read
    /// failures surface as [`PallasError::File`] with path `"-"`.
    pub fn read_path(path: &str) -> Result<Trace, PallasError> {
        if path == "-" {
            let mut text = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut text).map_err(|e| {
                PallasError::File {
                    path: "-".to_string(),
                    error: e.to_string(),
                }
            })?;
            Self::from_jsonl(&text)
        } else {
            Self::read_file(path)
        }
    }

    pub fn total_calls(&self) -> usize {
        self.steps.iter().map(|s| s.total_calls()).sum()
    }
}

fn req_str(j: &Json, key: &str, lineno: usize) -> Result<String, PallasError> {
    j.at(&[key])
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| PallasError::Trace(format!("trace line {}: missing '{key}'", lineno + 1)))
}

fn req_u64(j: &Json, key: &str, lineno: usize) -> Result<u64, PallasError> {
    j.at(&[key])
        .and_then(Json::as_u64)
        .ok_or_else(|| PallasError::Trace(format!("trace line {}: missing '{key}'", lineno + 1)))
}

/// Encode one trajectory as the canonical JSON record —
/// `{"query":q,"candidate":c,"calls":[[agent,tokens,env_s],...]}` —
/// the exact shape trace step lines have always carried. Also the
/// distributed plane's result payload (DESIGN.md §14): a trajectory is
/// the same bytes in a trace file and on the wire.
pub fn trajectory_to_json(t: &TrajectorySpec) -> Json {
    Json::obj(vec![
        ("query", Json::num(t.query as f64)),
        ("candidate", Json::num(t.candidate as f64)),
        (
            "calls",
            Json::arr(t.calls.iter().map(|c| {
                Json::arr([
                    Json::num(c.agent as f64),
                    Json::num(c.tokens),
                    Json::num(c.env_s),
                ])
            })),
        ),
    ])
}

/// Decode one [`trajectory_to_json`] record, bounds-checking agents
/// against `n_agents`. Errors are bare reasons ("bad agent",
/// "agent 9 out of range (n_agents 8)") — the caller prefixes its own
/// location vocabulary (trace line number, dist frame index).
pub fn trajectory_from_json(t: &Json, n_agents: usize) -> Result<TrajectorySpec, String> {
    let field = |key: &str| -> Result<usize, String> {
        t.at(&[key])
            .and_then(Json::as_u64)
            .map(|v| v as usize)
            .ok_or_else(|| format!("missing '{key}'"))
    };
    let query = field("query")?;
    let candidate = field("candidate")?;
    let calls_j = t
        .at(&["calls"])
        .and_then(Json::as_arr)
        .ok_or_else(|| "trajectory missing 'calls'".to_string())?;
    let mut calls = Vec::with_capacity(calls_j.len());
    for c in calls_j {
        let triple = c
            .as_arr()
            .filter(|a| a.len() == 3)
            .ok_or_else(|| "call is not [agent,tokens,env_s]".to_string())?;
        let agent = triple[0].as_u64().ok_or_else(|| "bad agent".to_string())? as usize;
        // Bound here so a corrupted record fails as a parse error,
        // not an index panic deep inside the engine.
        if agent >= n_agents {
            return Err(format!(
                "agent {agent} out of range (n_agents {n_agents})"
            ));
        }
        calls.push(CallSpec {
            agent,
            tokens: triple[1].as_f64().ok_or_else(|| "bad tokens".to_string())?,
            env_s: triple[2].as_f64().ok_or_else(|| "bad env_s".to_string())?,
        });
    }
    Ok(TrajectorySpec {
        query,
        candidate,
        calls,
    })
}

fn parse_step(j: &Json, n_agents: usize, lineno: usize) -> Result<StepWorkload, PallasError> {
    let step = req_u64(j, "step", lineno)? as usize;
    let trajs = j
        .at(&["trajectories"])
        .and_then(Json::as_arr)
        .ok_or_else(|| {
            PallasError::Trace(format!("trace line {}: missing 'trajectories'", lineno + 1))
        })?;
    let mut trajectories = Vec::with_capacity(trajs.len());
    for t in trajs {
        let traj = trajectory_from_json(t, n_agents)
            .map_err(|e| PallasError::Trace(format!("trace line {}: {e}", lineno + 1)))?;
        trajectories.push(traj);
    }
    Ok(StepWorkload { step, trajectories })
}

// ---------------------------------------------------------------------------
// Streaming reader
// ---------------------------------------------------------------------------

/// Streaming trace reader: one step per pull, O(one step) in memory.
///
/// [`Trace::from_jsonl`] parses the whole file eagerly — fine for
/// tooling, impossible at streaming-plane scale (DESIGN.md §11), where
/// a replay source must hand the engine one [`StepWorkload`] at a time.
/// `TraceReader` validates the header up front (same checks, same typed
/// [`PallasError`] messages as the eager parser, byte for byte — pinned
/// by tests) and then reads one line per [`TraceReader::next_step`]
/// call, preserving the truncated-final-line diagnosis: a line the
/// underlying reader returns without a trailing newline is by
/// construction the file's last.
///
/// One documented divergence, reachable only on already-invalid files:
/// after the header's promised step count has been delivered the reader
/// returns `Ok(None)` without scanning trailing lines (laziness is the
/// point), whereas the eager parser — which always sees the whole file
/// — reports trailing garbage as a parse error.
pub struct TraceReader {
    src: Box<dyn BufRead + Send>,
    workload: String,
    scenario: String,
    seed: u64,
    n_agents: usize,
    n_steps: usize,
    /// Lines consumed from `src` so far (0-based index of the next).
    lineno: usize,
    yielded: usize,
    done: bool,
}

impl TraceReader {
    /// Open a trace file and validate its header. File errors surface
    /// as [`PallasError::File`], header problems exactly as in
    /// [`Trace::from_jsonl`].
    pub fn open(path: &str) -> Result<TraceReader, PallasError> {
        let f = std::fs::File::open(path).map_err(|e| PallasError::File {
            path: path.to_string(),
            error: e.to_string(),
        })?;
        Self::start(Box::new(std::io::BufReader::new(f)))
    }

    /// Read from an in-memory JSONL string (tests, equivalence checks).
    pub fn from_text(text: &str) -> Result<TraceReader, PallasError> {
        Self::start(Box::new(std::io::Cursor::new(text.as_bytes().to_vec())))
    }

    /// Stream records from any buffered reader — the live-feed entry
    /// point (stdin pipe, file tail, socket). The header is validated
    /// up front exactly as in [`TraceReader::open`]; records arriving
    /// later are pulled on demand by [`TraceReader::next_step`], with
    /// the same typed truncated-record diagnostics.
    pub fn from_reader(src: Box<dyn BufRead + Send>) -> Result<TraceReader, PallasError> {
        Self::start(src)
    }

    /// [`TraceReader::open`] with the CLI's `-` convention: `"-"`
    /// streams records from stdin as they arrive (a blocking pipe keeps
    /// the run live), anything else is a filesystem path.
    pub fn open_path(path: &str) -> Result<TraceReader, PallasError> {
        if path == "-" {
            // StdinLock is !Send; Stdin itself is Read + Send, so buffer
            // it ourselves to fit the Box<dyn BufRead + Send> source.
            Self::from_reader(Box::new(std::io::BufReader::new(std::io::stdin())))
        } else {
            Self::open(path)
        }
    }

    fn start(mut src: Box<dyn BufRead + Send>) -> Result<TraceReader, PallasError> {
        let mut lineno = 0usize;
        let Some((n, line, complete)) = next_record_line(&mut src, &mut lineno)? else {
            return Err(PallasError::Trace("trace: no header line".into()));
        };
        let j = parse_record(&line, n, complete)?;
        match record_kind(&j, n)?.as_str() {
            "header" => {
                let version = j.at(&["version"]).and_then(Json::as_u64).unwrap_or(0);
                if version != TRACE_VERSION {
                    return Err(PallasError::Trace(format!(
                        "unsupported trace version {version} (want {TRACE_VERSION})"
                    )));
                }
                let scen = req_str(&j, "scenario", n)?;
                if scenario::by_name(&scen).is_none() {
                    return Err(PallasError::UnknownScenario(scen));
                }
                let workload = req_str(&j, "workload", n)?;
                let seed = req_u64(&j, "seed", n)?;
                let n_agents = req_u64(&j, "n_agents", n)? as usize;
                let n_steps = req_u64(&j, "steps", n)? as usize;
                if n_steps == 0 {
                    return Err(PallasError::Trace(
                        "trace has no steps (nothing to replay)".into(),
                    ));
                }
                Ok(TraceReader {
                    src,
                    workload,
                    scenario: scen,
                    seed,
                    n_agents,
                    n_steps,
                    lineno,
                    yielded: 0,
                    done: false,
                })
            }
            "step" => Err(PallasError::Trace("trace: step line before header".into())),
            other => Err(PallasError::Trace(format!(
                "trace line {}: unknown kind '{other}'",
                n + 1
            ))),
        }
    }

    /// Base workload name from the header ("MA"/"CA"/custom).
    pub fn workload(&self) -> &str {
        &self.workload
    }

    /// Scenario preset the trace was generated under (validated known).
    pub fn scenario(&self) -> &str {
        &self.scenario
    }

    /// Generator seed at record time.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Agent count of the shaped config (replay sanity check).
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Total steps the header promises.
    pub fn steps(&self) -> usize {
        self.n_steps
    }

    /// Steps already yielded by [`TraceReader::next_step`].
    pub fn steps_yielded(&self) -> usize {
        self.yielded
    }

    /// Pull the next step. `Ok(None)` once the header's step count has
    /// been delivered; any error poisons the reader (subsequent calls
    /// return `Ok(None)`).
    pub fn next_step(&mut self) -> Result<Option<StepWorkload>, PallasError> {
        if self.done {
            return Ok(None);
        }
        let r = self.next_step_inner();
        if !matches!(r, Ok(Some(_))) {
            self.done = true;
        }
        r
    }

    fn next_step_inner(&mut self) -> Result<Option<StepWorkload>, PallasError> {
        if self.yielded == self.n_steps {
            return Ok(None);
        }
        let rec = next_record_line(&mut self.src, &mut self.lineno)?;
        let Some((n, line, complete)) = rec else {
            return Err(PallasError::Trace(format!(
                "trace: header says {} steps, found {}",
                self.n_steps, self.yielded
            )));
        };
        let j = parse_record(&line, n, complete)?;
        match record_kind(&j, n)?.as_str() {
            "header" => Err(PallasError::Trace(format!(
                "trace line {}: duplicate header",
                n + 1
            ))),
            "step" => {
                let sw = parse_step(&j, self.n_agents, n)?;
                if sw.step != self.yielded {
                    return Err(PallasError::Trace(format!(
                        "trace line {}: step {} out of order (expected {})",
                        n + 1,
                        sw.step,
                        self.yielded
                    )));
                }
                self.yielded += 1;
                Ok(Some(sw))
            }
            other => Err(PallasError::Trace(format!(
                "trace line {}: unknown kind '{other}'",
                n + 1
            ))),
        }
    }
}

impl std::fmt::Debug for TraceReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceReader")
            .field("workload", &self.workload)
            .field("scenario", &self.scenario)
            .field("seed", &self.seed)
            .field("n_agents", &self.n_agents)
            .field("n_steps", &self.n_steps)
            .field("yielded", &self.yielded)
            .finish_non_exhaustive()
    }
}

/// Next non-blank line as `(0-based index, trimmed text, had trailing
/// newline)`. A line without a trailing newline is necessarily the
/// file's last — the signal behind the truncated-final-record message.
fn next_record_line(
    src: &mut impl BufRead,
    lineno: &mut usize,
) -> Result<Option<(usize, String, bool)>, PallasError> {
    loop {
        let mut buf = String::new();
        let n = src
            .read_line(&mut buf)
            .map_err(|e| PallasError::Trace(format!("trace line {}: {e}", *lineno + 1)))?;
        if n == 0 {
            return Ok(None);
        }
        let idx = *lineno;
        *lineno += 1;
        let complete = buf.ends_with('\n');
        let line = buf.trim();
        if line.is_empty() {
            continue;
        }
        return Ok(Some((idx, line.to_string(), complete)));
    }
}

/// Parse one record line with the eager parser's exact error texts:
/// mid-line EOF → the truncated-final-record diagnosis, anything else →
/// the generic parse error.
fn parse_record(line: &str, lineno: usize, complete: bool) -> Result<Json, PallasError> {
    parse(line).map_err(|e| {
        if !complete {
            PallasError::Trace(format!(
                "trace line {}: truncated final record (file ends mid-line; \
                 re-record or re-copy the trace)",
                lineno + 1
            ))
        } else {
            PallasError::Trace(format!("trace line {}: {e}", lineno + 1))
        }
    })
}

fn record_kind(j: &Json, lineno: usize) -> Result<String, PallasError> {
    j.at(&["kind"])
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| PallasError::Trace(format!("trace line {}: missing 'kind'", lineno + 1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn small(scenario: &str) -> WorkloadConfig {
        let mut wl = WorkloadConfig::ma();
        wl.queries_per_step = 2;
        wl.group_size = 2;
        wl.scenario = scenario.to_string();
        wl
    }

    #[test]
    fn jsonl_roundtrip_is_bit_identical_for_every_preset() {
        for name in scenario::names() {
            let tr = Trace::record(&small(name), 2048, 2).unwrap();
            let back = Trace::from_jsonl(&tr.to_jsonl()).unwrap();
            // PartialEq on f64 fields: exact, not approximate.
            assert_eq!(tr, back, "{name} round-trip drifted");
            assert_eq!(back.scenario, name);
            assert!(back.total_calls() > 0);
        }
    }

    #[test]
    fn replayed_trace_matches_regeneration() {
        let wl = small("core_skew");
        let tr = Trace::record(&wl, 7, 3).unwrap();
        let (shaped, scen) = scenario::resolve(&wl).unwrap();
        for (s, recorded) in tr.steps.iter().enumerate() {
            assert_eq!(recorded, &scen.step(&shaped, 7, s));
        }
    }

    #[test]
    fn file_roundtrip() {
        let tr = Trace::record(&small("bursty"), 2048, 2).unwrap();
        let path = std::env::temp_dir().join("flexmarl_trace_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        let back = Trace::read_file(&path).unwrap();
        assert_eq!(tr, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_traces_rejected() {
        assert!(Trace::from_jsonl("").is_err());
        assert!(Trace::from_jsonl("not json\n").is_err());
        // Step before header.
        assert!(Trace::from_jsonl(r#"{"kind":"step","step":0,"trajectories":[]}"#).is_err());
        // Unknown kind.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let bad = tr.to_jsonl().replace("\"header\"", "\"headerz\"");
        assert!(Trace::from_jsonl(&bad).is_err());
        // Header/step-count mismatch.
        let jsonl = tr.to_jsonl();
        let header_only = jsonl.lines().next().unwrap();
        assert!(Trace::from_jsonl(header_only).is_err());
        // Wrong version.
        let wrong = jsonl.replace("\"version\":1", "\"version\":99");
        assert!(Trace::from_jsonl(&wrong).is_err());
    }

    #[test]
    fn out_of_order_step_lines_rejected() {
        // A duplicated step line keeps the header count right but
        // replays a different sequence than recorded — must be a
        // parse error, not a silent divergence.
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 steps");
        let dup = format!("{}\n{}\n{}\n", lines[0], lines[1], lines[1]);
        let err = Trace::from_jsonl(&dup).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");
        let swapped = format!("{}\n{}\n{}\n", lines[0], lines[2], lines[1]);
        assert!(Trace::from_jsonl(&swapped).is_err());
        // A second header mid-file must not rebind provenance.
        let reheader = format!("{}\n{}\n{}\n{}\n", lines[0], lines[1], lines[0], lines[2]);
        let err = Trace::from_jsonl(&reheader).unwrap_err();
        assert!(err.to_string().contains("duplicate header"), "{err}");
    }

    #[test]
    fn out_of_range_agent_is_a_parse_error() {
        // Regression: a corrupted call agent index must fail at parse
        // time, not panic inside the engine.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let jsonl = tr.to_jsonl();
        let a0 = &tr.steps[0].trajectories[0].calls[0];
        let needle = format!("[{},", a0.agent);
        let bad = jsonl.replacen(&needle, "[99,", 1);
        assert_ne!(bad, jsonl, "test setup: call triple not found");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn unknown_scenario_fails_record() {
        let mut wl = small("baseline");
        wl.scenario = "nope".into();
        assert!(Trace::record(&wl, 1, 1).is_err());
        // Zero steps: nothing to replay — rejected at record time.
        assert!(Trace::record(&small("baseline"), 1, 0).is_err());
    }

    #[test]
    fn unknown_header_scenario_is_a_parse_error() {
        // Replay re-shapes the config from the header's scenario name,
        // so a name this build doesn't know must fail at parse time.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let bad = tr
            .to_jsonl()
            .replace("\"scenario\":\"baseline\"", "\"scenario\":\"from_the_future\"");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        assert_eq!(err, PallasError::UnknownScenario("from_the_future".into()));
        assert!(err.to_string().contains("from_the_future"), "{err}");
    }

    #[test]
    fn truncated_final_line_named_specifically() {
        // Regression (DESIGN.md §10 hardening): a trace cut mid-write
        // (partial copy, interrupted download) used to surface as an
        // opaque JSON parse error; it must name the truncation and the
        // line it happened on.
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        // Chop the file mid-way through the final record (drop the
        // trailing newline and the last 10 bytes).
        let cut = &jsonl[..jsonl.trim_end().len() - 10];
        assert!(!cut.ends_with('\n'), "test setup: cut must end mid-line");
        let err = Trace::from_jsonl(cut).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated final record"), "{msg}");
        assert!(msg.contains("trace line 3"), "{msg}");
        assert!(matches!(err, PallasError::Trace(_)), "{err:?}");
    }

    #[test]
    fn corrupt_but_complete_final_line_keeps_generic_error() {
        // The truncation diagnosis requires the missing trailing
        // newline; a complete-but-corrupt last line is still reported
        // as the parse error it is.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let jsonl = tr.to_jsonl();
        let bad = jsonl.replace("\"trajectories\":", "\"trajectories\"~");
        assert!(bad.ends_with('\n'), "test setup: newline must survive");
        let err = Trace::from_jsonl(&bad).unwrap_err();
        let msg = err.to_string();
        assert!(!msg.contains("truncated"), "{msg}");
        assert!(msg.contains("trace line 2"), "{msg}");
    }

    #[test]
    fn oversized_seed_rejected_at_record() {
        // Seeds above 2^53 cannot round-trip through a JSON number.
        let err = Trace::record(&small("baseline"), MAX_SEED + 1, 1).unwrap_err();
        assert!(err.to_string().contains("2^53"), "{err}");
        assert!(Trace::record(&small("baseline"), MAX_SEED, 1).is_ok());
    }

    fn drain(reader: &mut TraceReader) -> Result<Vec<StepWorkload>, PallasError> {
        let mut out = Vec::new();
        while let Some(w) = reader.next_step()? {
            out.push(w);
        }
        Ok(out)
    }

    #[test]
    fn streaming_reader_matches_eager_parse_for_every_preset() {
        for name in scenario::names() {
            let tr = Trace::record(&small(name), 2048, 2).unwrap();
            let jsonl = tr.to_jsonl();
            let mut r = TraceReader::from_text(&jsonl).unwrap();
            assert_eq!(r.workload(), tr.workload);
            assert_eq!(r.scenario(), tr.scenario);
            assert_eq!(r.seed(), tr.seed);
            assert_eq!(r.n_agents(), tr.n_agents);
            assert_eq!(r.steps(), tr.steps.len());
            assert_eq!(r.steps_yielded(), 0);
            let steps = drain(&mut r).unwrap();
            assert_eq!(steps, tr.steps, "{name} streamed parse drifted");
            assert_eq!(r.steps_yielded(), tr.steps.len());
            assert!(r.next_step().unwrap().is_none(), "reader must stay exhausted");
        }
    }

    #[test]
    fn streaming_reader_errors_match_eager_parser_byte_for_byte() {
        // Every single-corruption case must surface through the reader
        // with the exact message the eager parser emits — the streaming
        // plane may not regress a single diagnostic.
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();

        let stream_err = |text: &str| -> PallasError {
            match TraceReader::from_text(text) {
                Err(e) => e,
                Ok(mut r) => loop {
                    match r.next_step() {
                        Err(e) => break e,
                        Ok(Some(_)) => continue,
                        Ok(None) => panic!("expected an error for {text:?}"),
                    }
                },
            }
        };

        let cases: Vec<String> = vec![
            String::new(),                                          // no header
            "not json\n".to_string(),                               // bad first line
            r#"{"kind":"step","step":0,"trajectories":[]}"#.into(), // step before header
            jsonl.replace("\"header\"", "\"headerz\""),             // unknown kind
            format!("{}\n", lines[0]),                              // count mismatch
            jsonl.replace("\"version\":1", "\"version\":99"),       // bad version
            jsonl.replace("\"scenario\":\"baseline\"", "\"scenario\":\"from_the_future\""),
            format!("{}\n{}\n{}\n", lines[0], lines[1], lines[1]), // out of order
            format!("{}\n{}\n{}\n{}\n", lines[0], lines[1], lines[0], lines[2]), // dup header
            jsonl[..jsonl.trim_end().len() - 10].to_string(),      // truncated final line
            jsonl.replace("\"trajectories\":", "\"trajectories\"~"), // corrupt, complete
        ];
        for case in &cases {
            let eager = Trace::from_jsonl(case).unwrap_err();
            let streamed = stream_err(case);
            assert_eq!(
                streamed.to_string(),
                eager.to_string(),
                "reader diverged on {case:?}"
            );
        }
    }

    #[test]
    fn streaming_reader_stops_after_promised_steps() {
        // Documented divergence from the eager parser: once the
        // header's step count has been delivered, the reader returns
        // None without scanning trailing lines — only already-invalid
        // files can tell the difference.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let with_garbage = format!("{}garbage after the last step\n", tr.to_jsonl());
        assert!(Trace::from_jsonl(&with_garbage).is_err());
        let mut r = TraceReader::from_text(&with_garbage).unwrap();
        assert_eq!(drain(&mut r).unwrap(), tr.steps);
    }

    #[test]
    fn streaming_reader_poisons_after_an_error() {
        let tr = Trace::record(&small("baseline"), 1, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        let dup = format!("{}\n{}\n{}\n", lines[0], lines[1], lines[1]);
        let mut r = TraceReader::from_text(&dup).unwrap();
        assert!(r.next_step().unwrap().is_some());
        assert!(r.next_step().is_err());
        assert!(r.next_step().unwrap().is_none(), "poisoned reader must stop");
    }

    #[test]
    fn from_reader_streams_a_live_feed_with_typed_diagnostics() {
        // Serving-plane satellite: the lazy plane can be driven from an
        // arbitrary reader (stdin pipe, file tail). Equivalence with
        // from_text, and the truncated-final-record diagnosis must
        // survive the generic-reader path too.
        let tr = Trace::record(&small("bursty"), 2048, 2).unwrap();
        let jsonl = tr.to_jsonl();
        let boxed: Box<dyn BufRead + Send> =
            Box::new(std::io::Cursor::new(jsonl.as_bytes().to_vec()));
        let mut r = TraceReader::from_reader(boxed).unwrap();
        assert_eq!(drain(&mut r).unwrap(), tr.steps);

        let cut = jsonl[..jsonl.trim_end().len() - 10].to_string();
        let boxed: Box<dyn BufRead + Send> = Box::new(std::io::Cursor::new(cut.into_bytes()));
        let mut r = TraceReader::from_reader(boxed).unwrap();
        let err = loop {
            match r.next_step() {
                Err(e) => break e,
                Ok(Some(_)) => continue,
                Ok(None) => panic!("expected truncation error"),
            }
        };
        assert!(err.to_string().contains("truncated final record"), "{err}");
    }

    #[test]
    fn path_helpers_treat_non_dash_as_files() {
        // "-" means stdin (not testable here without a pipe); any other
        // string must behave exactly like the plain file entry points.
        let tr = Trace::record(&small("baseline"), 1, 1).unwrap();
        let path = std::env::temp_dir().join("flexmarl_trace_path_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        assert_eq!(Trace::read_path(&path).unwrap(), tr);
        let mut r = TraceReader::open_path(&path).unwrap();
        assert_eq!(drain(&mut r).unwrap(), tr.steps);
        let _ = std::fs::remove_file(&path);
        assert!(matches!(
            Trace::read_path(&path).unwrap_err(),
            PallasError::File { .. }
        ));
        assert!(matches!(
            TraceReader::open_path(&path).unwrap_err(),
            PallasError::File { .. }
        ));
    }

    #[test]
    fn streaming_reader_file_roundtrip_and_missing_file() {
        let tr = Trace::record(&small("bursty"), 2048, 2).unwrap();
        let path = std::env::temp_dir().join("flexmarl_trace_reader_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();
        let mut r = TraceReader::open(&path).unwrap();
        assert_eq!(drain(&mut r).unwrap(), tr.steps);
        let _ = std::fs::remove_file(&path);
        let err = TraceReader::open(&path).unwrap_err();
        assert!(matches!(err, PallasError::File { .. }), "{err:?}");
    }
}
