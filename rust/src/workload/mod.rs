//! Workload generator: the synthetic analogue of the proprietary MA/CA
//! e-commerce datasets (§8.1; substitution documented in DESIGN.md §2).
//!
//! A MARL *step* processes `queries_per_step` user queries. Each query is
//! expanded by intra-query parallelism into `group_size` GRPO candidate
//! *trajectories*; each trajectory is a chain of `turns` agent calls
//! (agents drawn from the skewed invocation distribution — Obs. 2:
//! core agents carry >76% of calls). Each call generates a lognormal
//! token count capped at `max_tokens` — the long-tail interaction
//! latency of Fig. 1a — plus an environment/tool latency.
//!
//! The generator is deterministic in (seed, step): both the simulator
//! and the real mini-cluster replay identical workloads.

pub mod arrival;
pub mod corpus;
pub mod scenario;
pub mod source;
pub mod trace;

pub use arrival::{ArrivalProcess, Arrivals};
pub use scenario::Scenario;
pub use source::{LenHint, ScenarioSource, TraceSource, VecSource, WorkloadSource};
pub use trace::{trajectory_from_json, trajectory_to_json, Trace, TraceReader};

use crate::config::WorkloadConfig;
use crate::util::rng::Pcg64;

/// One agent invocation within a trajectory.
///
/// `PartialEq` is exact (bit-level f64 equality) — the trace
/// record/replay round-trip guarantees and asserts bit-identity.
#[derive(Debug, Clone, PartialEq)]
pub struct CallSpec {
    pub agent: usize,
    /// Generated response length in tokens (the service demand).
    pub tokens: f64,
    /// Environment/tool latency paid after generation (seconds).
    pub env_s: f64,
}

/// One GRPO candidate: a dependency chain of calls.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectorySpec {
    pub query: usize,
    pub candidate: usize,
    pub calls: Vec<CallSpec>,
}

impl TrajectorySpec {
    pub fn total_tokens(&self) -> f64 {
        self.calls.iter().map(|c| c.tokens).sum()
    }

    /// Service time of the whole chain on uncontended instances.
    pub fn ideal_latency(&self, decode_tps: impl Fn(usize) -> f64) -> f64 {
        self.calls
            .iter()
            .map(|c| c.tokens / decode_tps(c.agent) + c.env_s)
            .sum()
    }
}

/// The full workload of one MARL step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepWorkload {
    pub step: usize,
    pub trajectories: Vec<TrajectorySpec>,
}

impl StepWorkload {
    pub fn total_tokens(&self) -> f64 {
        self.trajectories.iter().map(|t| t.total_tokens()).sum()
    }

    pub fn total_calls(&self) -> usize {
        self.trajectories.iter().map(|t| t.calls.len()).sum()
    }

    /// Per-agent call counts (the Fig. 8/9 "processed rollout load").
    pub fn calls_per_agent(&self, n_agents: usize) -> Vec<usize> {
        let mut out = vec![0; n_agents];
        for t in &self.trajectories {
            for c in &t.calls {
                out[c.agent] += 1;
            }
        }
        out
    }

    /// Samples (trajectories) that involve agent `a` — its training load.
    pub fn samples_for_agent(&self, a: usize) -> usize {
        self.trajectories
            .iter()
            .filter(|t| t.calls.iter().any(|c| c.agent == a))
            .count()
    }
}

pub struct Generator<'a> {
    wl: &'a WorkloadConfig,
    seed: u64,
}

impl<'a> Generator<'a> {
    pub fn new(wl: &'a WorkloadConfig, seed: u64) -> Self {
        Generator { wl, seed }
    }

    /// Deterministic workload for `step`: queries `0..queries_per_step`
    /// expanded in slot order. Exactly `(0..qps).flat_map(|q| query(step, q))`
    /// — the distributed plane (DESIGN.md §14) relies on that identity
    /// to generate queries on remote workers and reassemble the step
    /// byte-identically.
    pub fn step(&self, step: usize) -> StepWorkload {
        let trajectories = (0..self.wl.queries_per_step)
            .flat_map(|q| self.query(step, q))
            .collect();
        StepWorkload { step, trajectories }
    }

    /// Deterministic trajectory group (all GRPO candidates) for one
    /// query slot. Each query draws from its own PRNG streams keyed by
    /// `(seed, step, q)` — independent of `queries_per_step` and of
    /// every other slot, so a query can be generated anywhere (another
    /// thread, another process) and yield the same bits.
    pub fn query(&self, step: usize, q: usize) -> Vec<TrajectorySpec> {
        let wl = self.wl;
        let weights: Vec<f64> = wl.agents.iter().map(|a| a.invoke_weight).collect();
        // The workflow *skeleton* (agent sequence, turn count) is per
        // query: all GRPO candidates answer the same user query, so
        // they traverse the same agents; token counts differ per
        // candidate (sampling temperature).
        let mut qrng = Pcg64::with_stream(
            self.seed ^ 0x5157_u64,
            (step as u64) << 32 | q as u64,
        );
        let turns = wl.min_turns
            + qrng.below((wl.max_turns - wl.min_turns + 1) as u64) as usize;
        let skeleton: Vec<usize> =
            (0..turns).map(|_| qrng.categorical(&weights)).collect();

        let mut trajectories = Vec::with_capacity(wl.group_size);
        for cand in 0..wl.group_size {
            let mut crng = Pcg64::with_stream(
                self.seed ^ 0xca4d_u64,
                ((step as u64) << 40) | ((q as u64) << 20) | cand as u64,
            );
            let calls = skeleton
                .iter()
                .map(|&agent| {
                    let a = &wl.agents[agent];
                    // Upper bound floored at 8.0 so a degenerate
                    // max_tokens < 8 yields 8.0 (as the historical
                    // min/max chain did) instead of panicking.
                    let tokens = crng
                        .lognormal(a.mean_tokens.ln(), a.token_sigma)
                        .clamp(8.0, wl.max_tokens.max(8.0));
                    let env_s = crng.lognormal(wl.env_mu.ln().max(-3.0), wl.env_sigma);
                    CallSpec {
                        agent,
                        tokens,
                        env_s: env_s.min(30.0),
                    }
                })
                .collect();
            trajectories.push(TrajectorySpec {
                query: q,
                candidate: cand,
                calls,
            });
        }
        trajectories
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn deterministic_per_seed_and_step() {
        let wl = WorkloadConfig::ma();
        let g = Generator::new(&wl, 2048);
        let a = g.step(0);
        let b = g.step(0);
        assert_eq!(a.total_calls(), b.total_calls());
        assert_eq!(a.total_tokens(), b.total_tokens());
        let c = g.step(1);
        assert_ne!(a.total_tokens(), c.total_tokens());
        let g2 = Generator::new(&wl, 1);
        assert_ne!(a.total_tokens(), g2.step(0).total_tokens());
    }

    #[test]
    fn batch_size_is_queries_times_group() {
        let wl = WorkloadConfig::ma();
        let w = Generator::new(&wl, 2048).step(0);
        assert_eq!(
            w.trajectories.len(),
            wl.queries_per_step * wl.group_size
        );
        // §8.1: global batch 64.
        assert_eq!(w.trajectories.len(), 64);
    }

    #[test]
    fn candidates_share_skeleton() {
        let wl = WorkloadConfig::ma();
        let w = Generator::new(&wl, 2048).step(0);
        let q0: Vec<&TrajectorySpec> =
            w.trajectories.iter().filter(|t| t.query == 0).collect();
        let skel: Vec<usize> = q0[0].calls.iter().map(|c| c.agent).collect();
        for t in &q0 {
            let s: Vec<usize> = t.calls.iter().map(|c| c.agent).collect();
            assert_eq!(s, skel);
            // but token counts differ across candidates
        }
        assert!(q0[0].calls[0].tokens != q0[1].calls[0].tokens);
    }

    #[test]
    fn step_is_flat_map_of_per_query_groups() {
        // The dist plane's foundational identity: generating each query
        // slot independently and concatenating in slot order must be
        // bit-identical to the monolithic step (PartialEq on CallSpec
        // is bit-level f64 equality).
        let wl = WorkloadConfig::ma();
        let g = Generator::new(&wl, 2048);
        for step in [0usize, 3, 17] {
            let whole = g.step(step);
            let stitched: Vec<TrajectorySpec> = (0..wl.queries_per_step)
                .flat_map(|q| g.query(step, q))
                .collect();
            assert_eq!(whole.trajectories, stitched, "step {step}");
        }
        // And a slot's bits do not depend on how many slots the step
        // has (the prefix property that makes resizing scenarios safe).
        let mut wider = wl.clone();
        wider.queries_per_step += 5;
        let gw = Generator::new(&wider, 2048);
        assert_eq!(g.query(2, 1), gw.query(2, 1));
    }

    #[test]
    fn core_agents_receive_majority_of_calls() {
        let wl = WorkloadConfig::ma();
        // Average over steps to smooth sampling noise.
        let g = Generator::new(&wl, 2048);
        let mut per_agent = vec![0usize; wl.agents.len()];
        for s in 0..20 {
            let w = g.step(s);
            for (i, c) in w.calls_per_agent(wl.agents.len()).iter().enumerate() {
                per_agent[i] += c;
            }
        }
        let total: usize = per_agent.iter().sum();
        let core = wl.core_agents();
        let core_calls: usize = core.iter().map(|&i| per_agent[i]).sum();
        let share = core_calls as f64 / total as f64;
        // Obs. 2: skewed — small set of core agents dominates.
        assert!(share > 0.40, "core share {share}");
        // and auxiliaries individually small
        let max_aux = per_agent
            .iter()
            .enumerate()
            .filter(|(i, _)| !core.contains(i))
            .map(|(_, &c)| c)
            .max()
            .unwrap();
        assert!(max_aux < *per_agent.iter().max().unwrap());
    }

    #[test]
    fn token_distribution_long_tailed_and_capped() {
        let wl = WorkloadConfig::ma();
        let g = Generator::new(&wl, 2048);
        let mut all: Vec<f64> = Vec::new();
        for s in 0..30 {
            for t in &g.step(s).trajectories {
                for c in &t.calls {
                    all.push(c.tokens);
                }
            }
        }
        let max = all.iter().cloned().fold(0.0, f64::max);
        assert!(max <= wl.max_tokens);
        assert!(max > 0.9 * wl.max_tokens, "tail never reaches cap: {max}");
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(mean > 1.2 * median, "not long-tailed: mean {mean} median {median}");
    }

    #[test]
    fn fig1a_latency_anchor() {
        // Worst user-query interaction latency should land near the
        // paper's ~170 s (Fig. 1a) on uncontended 14B instances.
        let wl = WorkloadConfig::ma();
        let g = Generator::new(&wl, 2048);
        let mut worst: f64 = 0.0;
        for s in 0..10 {
            for t in &g.step(s).trajectories {
                let lat = t.ideal_latency(|a| wl.agents[a].model.decode_tps());
                worst = worst.max(lat);
            }
        }
        assert!(worst > 100.0 && worst < 320.0, "worst {worst}");
    }
}
