//! Open-loop arrival processes: modeled user-traffic generators.
//!
//! The seven original scenario presets are *closed-loop*: every step
//! serves exactly `queries_per_step` queries (scaled by a fixed
//! multiplier), as if a benchmark harness fed the cluster at a constant
//! rate. Real serving pressure is *open-loop* — requests arrive whether
//! or not the system is keeping up. This module models that arrival
//! side: a per-step query count drawn from a seeded stochastic process,
//! consumed by the `poisson` / `diurnal` / `flash_crowd` scenario
//! presets ([`crate::workload::scenario`]).
//!
//! # Determinism & decorrelation
//!
//! Each component draws from its **own** decorrelated [`Pcg64`] stream,
//! keyed by the step index (the same idiom as the fault plane's
//! per-kind streams, DESIGN.md §10):
//!
//! | component   | stream                                       |
//! |-------------|----------------------------------------------|
//! | Poisson     | `Pcg64::with_stream(seed ^ STREAM_POISSON, step)` |
//! | diurnal     | `Pcg64::with_stream(seed ^ STREAM_DIURNAL, step)` |
//! | flash crowd | `Pcg64::with_stream(seed ^ STREAM_FLASH, step)`   |
//!
//! Consequences, all pinned by tests:
//!
//! - same (config, seed, step) → bit-identical [`Arrivals`];
//! - changing the seed moves the draws (seed sensitivity);
//! - enabling or tuning one component cannot move another's draws
//!   (decorrelation) — adding a diurnal swell never reshuffles the
//!   Poisson base, so A/B comparisons across arrival shapes share the
//!   same base traffic;
//! - every step is randomly accessible: `arrivals(seed, s)` never
//!   depends on having computed step `s - 1`, which is what lets the
//!   lazy streaming plane (DESIGN.md §11) generate steps on demand.
//!
//! The total is clamped to `[1, max]` where `max = ceil(base_rate *
//! max_mult)` — the per-step budget bound that keeps a flash crowd from
//! materializing an unbounded step.

use crate::util::rng::Pcg64;

/// Stream selectors for the per-component RNGs (`seed ^ STREAM_*`,
/// step index as the stream key). Disjoint from the fault-plane
/// constants (`0xfa01..=0xfa05`) and the generator's per-query /
/// per-candidate XOR constants (`0x5157`, `0xca4d`).
pub const STREAM_POISSON: u64 = 0x0a71;
pub const STREAM_DIURNAL: u64 = 0x0a72;
pub const STREAM_FLASH: u64 = 0x0a73;
/// Serving-plane tenant decorrelation (DESIGN.md §13): each tenant's
/// arrival process draws from `tenant_seed(plane_seed, tenant_index)`,
/// so tenants never share draws and adding a tenant never reshuffles
/// another's traffic.
pub const STREAM_TENANT: u64 = 0x0a74;

/// Derive tenant `t`'s arrival seed from the plane seed: a SplitMix64
/// scramble (same finalizer as `exec::derive_seed`) over the
/// tenant-tagged stream, so nearby tenant indices land in unrelated
/// parts of the seed space.
pub fn tenant_seed(plane_seed: u64, tenant: u64) -> u64 {
    let mut z = (plane_seed ^ STREAM_TENANT).wrapping_add(tenant.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// An open-loop arrival process: a Poisson base, plus optional diurnal
/// and flash-crowd components, all additive.
///
/// `base_rate` is the mean arrivals per step of the Poisson floor;
/// presets derive it from the workload's `queries_per_step` so the
/// open-loop scenarios stay comparable to the closed-loop ones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalProcess {
    /// Mean arrivals per step of the Poisson base component.
    pub base_rate: f64,
    /// Peak diurnal extra load as a multiple of `base_rate`
    /// (0 disables the component).
    pub diurnal_amp: f64,
    /// Diurnal cycle length in steps.
    pub diurnal_period: usize,
    /// Per-step probability that a flash crowd ignites
    /// (0 disables the component).
    pub flash_prob: f64,
    /// Flash-crowd peak as a multiple of `base_rate`.
    pub flash_mult: f64,
    /// Steps a flash crowd takes to decay (halving per step).
    pub flash_decay_steps: usize,
    /// Per-step budget bound: total arrivals are clamped to
    /// `ceil(base_rate * max_mult)`.
    pub max_mult: f64,
}

impl ArrivalProcess {
    /// A pure Poisson process with the given mean rate and the default
    /// 8x budget bound.
    pub fn poisson(base_rate: f64) -> Self {
        ArrivalProcess {
            base_rate,
            diurnal_amp: 0.0,
            diurnal_period: 1,
            flash_prob: 0.0,
            flash_mult: 0.0,
            flash_decay_steps: 0,
            max_mult: 8.0,
        }
    }

    /// Add a diurnal component: extra Poisson load whose rate swells
    /// from 0 to `amp * base_rate` and back over `period` steps.
    pub fn with_diurnal(mut self, amp: f64, period: usize) -> Self {
        self.diurnal_amp = amp;
        self.diurnal_period = period.max(1);
        self
    }

    /// Add a flash-crowd component: each step ignites with probability
    /// `prob` a spike of roughly `mult * base_rate` arrivals that
    /// halves over each of the next `decay_steps` steps.
    pub fn with_flash(mut self, prob: f64, mult: f64, decay_steps: usize) -> Self {
        self.flash_prob = prob;
        self.flash_mult = mult;
        self.flash_decay_steps = decay_steps;
        self
    }

    /// The hard per-step budget: `ceil(base_rate * max_mult)`, at
    /// least 1.
    pub fn max_arrivals(&self) -> usize {
        (self.base_rate * self.max_mult).ceil().max(1.0) as usize
    }

    /// Diurnal rate multiplier at `step`: a raised cosine in `[0, 1]`,
    /// 0 at the cycle start, 1 at mid-cycle.
    fn diurnal_phase(&self, step: usize) -> f64 {
        let frac = (step % self.diurnal_period) as f64 / self.diurnal_period as f64;
        0.5 * (1.0 - (std::f64::consts::TAU * frac).cos())
    }

    /// Flash-crowd arrivals contributed *to* `step` by an ignition *at*
    /// `step - age` (random access: re-draws that step's ignition from
    /// its own stream, so the answer never depends on iteration order).
    fn flash_from(&self, seed: u64, ignition_step: usize, age: usize) -> usize {
        let mut rng = Pcg64::with_stream(seed ^ STREAM_FLASH, ignition_step as u64);
        if rng.f64() >= self.flash_prob {
            return 0;
        }
        // Spike amplitude in [0.5, 1.5) of the nominal flash size,
        // halving per step of age.
        let amp = 0.5 + rng.f64();
        let peak = self.flash_mult * self.base_rate * amp;
        (peak * 0.5f64.powi(age as i32)).round() as usize
    }

    /// Draw the arrival breakdown for `step`. Deterministic in
    /// `(self, seed, step)` and randomly accessible per step.
    pub fn arrivals(&self, seed: u64, step: usize) -> Arrivals {
        let poisson = {
            let mut rng = Pcg64::with_stream(seed ^ STREAM_POISSON, step as u64);
            poisson_draw(&mut rng, self.base_rate)
        };
        let diurnal = if self.diurnal_amp > 0.0 {
            let lambda = self.diurnal_amp * self.base_rate * self.diurnal_phase(step);
            let mut rng = Pcg64::with_stream(seed ^ STREAM_DIURNAL, step as u64);
            poisson_draw(&mut rng, lambda)
        } else {
            0
        };
        let flash = if self.flash_prob > 0.0 {
            (0..=self.flash_decay_steps)
                .filter(|age| *age <= step)
                .map(|age| self.flash_from(seed, step - age, age))
                .sum()
        } else {
            0
        };
        let total = (poisson + diurnal + flash).clamp(1, self.max_arrivals());
        Arrivals {
            poisson,
            diurnal,
            flash,
            total,
        }
    }
}

/// One step's arrival draw, broken down by component.
///
/// `total` is the clamped sum actually served; the components are the
/// raw (unclamped) draws so tests can assert decorrelation directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrivals {
    pub poisson: usize,
    pub diurnal: usize,
    pub flash: usize,
    /// `(poisson + diurnal + flash).clamp(1, max_arrivals)`.
    pub total: usize,
}

/// Knuth's Poisson sampler: count uniform draws until their product
/// falls below `e^-lambda`. Exact for the rates used here (the
/// per-step budget bound keeps lambda small); the rate is capped at
/// 512 so the loop stays short even for absurd configs.
fn poisson_draw(rng: &mut Pcg64, lambda: f64) -> usize {
    let lambda = lambda.min(512.0);
    if lambda <= 0.0 {
        return 0;
    }
    let limit = (-lambda).exp();
    let mut k = 0usize;
    let mut p = rng.f64();
    while p > limit {
        k += 1;
        p *= rng.f64();
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full() -> ArrivalProcess {
        ArrivalProcess::poisson(6.0)
            .with_diurnal(1.5, 8)
            .with_flash(0.25, 3.0, 2)
    }

    #[test]
    fn same_seed_same_step_is_bit_identical() {
        let p = full();
        for step in 0..64 {
            assert_eq!(p.arrivals(7, step), p.arrivals(7, step));
        }
    }

    #[test]
    fn seed_moves_the_arrival_sequence() {
        let p = full();
        let a: Vec<usize> = (0..64).map(|s| p.arrivals(7, s).total).collect();
        let b: Vec<usize> = (0..64).map(|s| p.arrivals(2048, s).total).collect();
        assert_ne!(a, b, "different seeds must move arrival draws");
    }

    #[test]
    fn steps_are_randomly_accessible() {
        // Querying step 9 cold must match querying it after 0..9.
        let p = full();
        let cold = p.arrivals(42, 9);
        for s in 0..9 {
            let _ = p.arrivals(42, s);
        }
        assert_eq!(cold, p.arrivals(42, 9));
    }

    #[test]
    fn components_are_decorrelated() {
        // Adding (or retuning) diurnal and flash components must not
        // move the Poisson base draws, and vice versa — each component
        // owns its stream.
        let plain = ArrivalProcess::poisson(6.0);
        let loaded = full();
        for step in 0..64 {
            assert_eq!(
                plain.arrivals(7, step).poisson,
                loaded.arrivals(7, step).poisson,
                "diurnal/flash components moved the Poisson base at step {step}"
            );
        }
        let d1 = ArrivalProcess::poisson(6.0).with_diurnal(1.5, 8);
        let d2 = full(); // same diurnal, flash added
        for step in 0..64 {
            assert_eq!(
                d1.arrivals(7, step).diurnal,
                d2.arrivals(7, step).diurnal,
                "flash component moved the diurnal draws at step {step}"
            );
        }
    }

    #[test]
    fn totals_respect_the_per_step_budget() {
        let p = ArrivalProcess::poisson(4.0).with_flash(0.9, 6.0, 3);
        let cap = p.max_arrivals();
        for seed in [1u64, 7, 2048] {
            for step in 0..256 {
                let a = p.arrivals(seed, step);
                assert!(a.total >= 1, "step must serve at least one query");
                assert!(a.total <= cap, "step {step} drew {} > budget {cap}", a.total);
            }
        }
    }

    #[test]
    fn diurnal_phase_peaks_mid_cycle() {
        let p = ArrivalProcess::poisson(6.0).with_diurnal(2.0, 8);
        assert!(p.diurnal_phase(0) < 1e-12);
        assert!((p.diurnal_phase(4) - 1.0).abs() < 1e-12);
        // Mean diurnal extra over many cycles tracks amp/2.
        let n = 4096usize;
        let mean: f64 = (0..n).map(|s| p.arrivals(7, s).diurnal as f64).sum::<f64>() / n as f64;
        let expect = 0.5 * 2.0 * 6.0;
        assert!((mean - expect).abs() < 0.5, "diurnal mean {mean} far from {expect}");
    }

    #[test]
    fn flash_crowds_decay_across_steps() {
        let p = ArrivalProcess::poisson(4.0).with_flash(1.0, 4.0, 2);
        // prob 1.0 → every step ignites; contributions stack but the
        // age-0 spike dominates and later steps carry halved echoes.
        let a = p.arrivals(7, 5);
        assert!(a.flash > 0, "guaranteed ignition must contribute");
        // An ignition at step s contributes half as much at s+1.
        let at_ignition = p.flash_from(7, 5, 0);
        let one_later = p.flash_from(7, 5, 1);
        assert!(one_later <= at_ignition.div_ceil(2) + 1);
    }

    #[test]
    fn tenant_seeds_are_distinct_and_deterministic() {
        // Each serving-plane tenant owns a decorrelated arrival stream.
        let mut seen = std::collections::HashSet::new();
        for t in 0..64u64 {
            let s = tenant_seed(2048, t);
            assert_eq!(s, tenant_seed(2048, t), "tenant seed must be pure");
            assert!(seen.insert(s), "tenant {t} collided");
        }
        assert_ne!(tenant_seed(1, 0), tenant_seed(2, 0), "plane seed must move tenants");
        // Tenants see genuinely different arrival sequences.
        let p = full();
        let a: Vec<usize> = (0..32).map(|s| p.arrivals(tenant_seed(7, 0), s).total).collect();
        let b: Vec<usize> = (0..32).map(|s| p.arrivals(tenant_seed(7, 1), s).total).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn poisson_draw_tracks_lambda() {
        let mut rng = Pcg64::with_stream(99, 0);
        let n = 8192usize;
        let sum: f64 = (0..n).map(|_| poisson_draw(&mut rng, 6.0) as f64).sum();
        let mean = sum / n as f64;
        assert!((mean - 6.0).abs() < 0.2, "poisson mean {mean} far from 6");
        let mut rng = Pcg64::with_stream(99, 1);
        assert_eq!(poisson_draw(&mut rng, 0.0), 0);
    }
}
