//! Pluggable workload scenarios: named traffic shapes that stress the
//! rollout balancer, trajectory scheduler, and agent-centric allocator
//! in different ways.
//!
//! The paper's claims rest on one traffic shape — skewed inter/intra-
//! agent request patterns (Obs. 2) with long-tail response lengths
//! (Fig. 1a). A scheduler that wins there can still lose under uniform
//! load, bursty arrivals, tool-dominated chains, or heterogeneous model
//! ensembles. Each [`Scenario`] preset shapes a base
//! [`WorkloadConfig`] into one such traffic pattern; generation stays
//! deterministic in `(seed, step)`, so every preset can be recorded and
//! replayed bit-identically via [`crate::workload::trace`].
//!
//! The catalogue (preset → what it stresses) is tabulated in
//! DESIGN.md §2.

use crate::config::{ModelScale, WorkloadConfig};
use crate::error::PallasError;
use crate::workload::arrival::ArrivalProcess;
use crate::workload::{Generator, StepWorkload};

/// A named traffic shape. `shape` transforms the base config once (per
/// run); `step` produces the deterministic per-step workload. The
/// default `step` delegates to the standard [`Generator`], optionally
/// modulated by [`Scenario::arrival_mult`] — only presets that need a
/// fundamentally different generation process override it.
///
/// `Send` is a supertrait so a resolved scenario can live inside a
/// [`crate::workload::WorkloadSource`] handed across sweep-executor
/// threads; presets are stateless, so this costs implementors nothing.
pub trait Scenario: Send {
    /// Registry key (lower_snake_case).
    fn name(&self) -> &'static str;

    /// One line: which paper observation/figure this preset stresses.
    fn stresses(&self) -> &'static str;

    /// Transform the base workload config into this scenario's shape.
    /// Must be pure: same base in, same shaped config out.
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig;

    /// Per-step arrival-rate multiplier (diurnal/bursty presets);
    /// 1.0 = steady arrivals.
    fn arrival_mult(&self, step: usize) -> f64 {
        let _ = step;
        1.0
    }

    /// Deterministic query count for `(seed, step)`: how many query
    /// slots this step has. The default derives it from
    /// [`Scenario::arrival_mult`]; open-loop presets override it with a
    /// seeded arrival draw. Split out from [`Scenario::step`] so the
    /// distributed coordinator (DESIGN.md §14) can enumerate a step's
    /// shards without generating any trajectory bytes itself — the
    /// invariant `step(wl, seed, s).trajectories.len() ==
    /// queries(wl, seed, s) * wl.group_size` is pinned by tests.
    fn queries(&self, wl: &WorkloadConfig, seed: u64, step: usize) -> usize {
        let _ = seed;
        let mult = self.arrival_mult(step);
        if mult == 1.0 {
            wl.queries_per_step
        } else {
            ((wl.queries_per_step as f64 * mult).round() as usize).max(1)
        }
    }

    /// Deterministic workload for `(seed, step)` over an already-shaped
    /// config: [`Scenario::queries`] slots expanded by the standard
    /// [`Generator`].
    fn step(&self, wl: &WorkloadConfig, seed: u64, step: usize) -> StepWorkload {
        let n = self.queries(wl, seed, step);
        if n == wl.queries_per_step {
            return Generator::new(wl, seed).step(step);
        }
        // Arrival modulation scales the query count; per-query RNG
        // streams are keyed by (seed, step, q), so a step's first K
        // queries are identical whatever the count — shrinking a
        // burst is a prefix, not a reshuffle.
        let mut resized = wl.clone();
        resized.queries_per_step = n;
        Generator::new(&resized, seed).step(step)
    }
}

// ---------------------------------------------------------------------------
// Presets
// ---------------------------------------------------------------------------

/// The config exactly as given (§8.1 MA/CA defaults).
struct Baseline;

impl Scenario for Baseline {
    fn name(&self) -> &'static str {
        "baseline"
    }
    fn stresses(&self) -> &'static str {
        "§8.1 defaults: the paper's MA/CA shape as configured"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        base.clone()
    }
}

/// Every agent equally likely, homogeneous token budgets, mild tail.
/// The null hypothesis for Obs. 2: the inter-agent balancer should stay
/// near-idle, and any scaling it does here is oscillation.
struct Uniform;

impl Scenario for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }
    fn stresses(&self) -> &'static str {
        "anti-Obs.2 control: no skew, balancer should stay quiet"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        let mut wl = base.clone();
        let mean = wl.agents.iter().map(|a| a.mean_tokens).sum::<f64>()
            / wl.agents.len() as f64;
        for a in &mut wl.agents {
            a.invoke_weight = 1.0;
            a.mean_tokens = mean;
            a.token_sigma = 0.6;
        }
        wl
    }
}

/// Obs. 2 sharpened: the top-2 agents' invocation weight is multiplied
/// so they carry well over the paper's 76% of calls — the regime where
/// hierarchical load balancing pays (Figs. 8/9).
struct CoreSkew;

impl Scenario for CoreSkew {
    fn name(&self) -> &'static str {
        "core_skew"
    }
    fn stresses(&self) -> &'static str {
        "Obs.2 / Figs.8-9: core agents >76% of calls, LB must migrate"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        let mut wl = base.clone();
        let mut idx: Vec<usize> = (0..wl.agents.len()).collect();
        idx.sort_by(|&a, &b| {
            wl.agents[b]
                .invoke_weight
                .partial_cmp(&wl.agents[a].invoke_weight)
                .unwrap()
        });
        for &i in idx.iter().take(2) {
            wl.agents[i].invoke_weight *= 4.0;
        }
        wl
    }
}

/// Diurnal arrivals: query volume swings 0.5×–3× across steps. The
/// static baselines provision for the mean and drown at the peak; the
/// scaler must track the swing without oscillating.
struct Bursty;

/// One "day" of arrival multipliers, cycled over steps.
const DIURNAL: [f64; 6] = [1.0, 0.5, 2.0, 3.0, 1.5, 0.5];

impl Scenario for Bursty {
    fn name(&self) -> &'static str {
        "bursty"
    }
    fn stresses(&self) -> &'static str {
        "Fig.1b queue dynamics under diurnal 0.5x-3x arrival swings"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        base.clone()
    }
    fn arrival_mult(&self, step: usize) -> f64 {
        DIURNAL[step % DIURNAL.len()]
    }
}

/// Tool-dominated multi-turn chains: longer workflows whose per-call
/// env/tool latency rivals decode time. Stresses the dependency-driven
/// scheduler (§5.1) — instances idle on env waits unless other chains
/// fill the slots.
struct ToolHeavy;

impl Scenario for ToolHeavy {
    fn name(&self) -> &'static str {
        "tool_heavy"
    }
    fn stresses(&self) -> &'static str {
        "§5.1 chains: high env_s tool calls, decode no longer dominates"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        let mut wl = base.clone();
        wl.min_turns = base.min_turns + 2;
        wl.max_turns = base.max_turns + 4;
        wl.env_mu = (base.env_mu * 6.0).max(1.5);
        wl.env_sigma = 1.0;
        wl
    }
}

/// Heterogeneous model scales (Table 4 / §6.1): agents cycle through
/// 7B/14B/32B, so instance device footprints and decode rates diverge —
/// the agent-centric allocator has to bind unequal groups on demand.
struct HeteroScale;

impl Scenario for HeteroScale {
    fn name(&self) -> &'static str {
        "hetero_scale"
    }
    fn stresses(&self) -> &'static str {
        "Table 4 / §6.1: mixed 7B/14B/32B ensemble, unequal bindings"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        const SCALES: [ModelScale; 3] = [ModelScale::B7, ModelScale::B14, ModelScale::B32];
        let mut wl = base.clone();
        for (i, a) in wl.agents.iter_mut().enumerate() {
            a.model = SCALES[i % SCALES.len()];
        }
        wl
    }
}

/// Straggler tail: token sigma pushed up so a visible fraction of calls
/// hit the `max_tokens` cap — the Fig. 1a worst case becomes common,
/// and per-step completion is gated on a few giant decodes.
struct Straggler;

impl Scenario for Straggler {
    fn name(&self) -> &'static str {
        "straggler"
    }
    fn stresses(&self) -> &'static str {
        "Fig.1a tail: sigma up, steps gated on capped giant decodes"
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        let mut wl = base.clone();
        for a in &mut wl.agents {
            a.token_sigma = 1.6;
        }
        wl
    }
}

/// Open-loop arrival presets (DESIGN.md §11): the per-step query count
/// is *drawn* from a seeded [`ArrivalProcess`] instead of fixed at
/// `queries_per_step` — load is driven by modeled user arrivals, not by
/// the closed-loop step clock. `queries_per_step` becomes the mean
/// arrival rate, so open-loop runs stay comparable to closed-loop ones.
///
/// Per-query generator streams are keyed by `(seed, step, q)`, so
/// resizing the arrival count keeps a shared query prefix rather than
/// reshuffling the step — the same property that makes `arrival_mult`
/// presets replayable makes these recordable/replayable through the
/// existing trace machinery unchanged.
struct OpenLoop {
    name: &'static str,
    stresses: &'static str,
    /// `(amp, period)` of the diurnal component, if any.
    diurnal: Option<(f64, usize)>,
    /// `(prob, mult, decay_steps)` of the flash-crowd component, if any.
    flash: Option<(f64, f64, usize)>,
}

impl OpenLoop {
    /// Memoryless Poisson arrivals around the configured mean rate.
    fn poisson() -> OpenLoop {
        OpenLoop {
            name: "poisson",
            stresses: "open-loop floor: memoryless Poisson arrivals replace fixed load",
            diurnal: None,
            flash: None,
        }
    }

    /// Poisson base plus a raised-cosine day/night swell.
    fn diurnal() -> OpenLoop {
        OpenLoop {
            name: "diurnal",
            stresses: "open-loop day cycle: raised-cosine swell over the Poisson base",
            diurnal: Some((1.5, 8)),
            flash: None,
        }
    }

    /// Poisson base plus randomly igniting, geometrically decaying
    /// traffic spikes.
    fn flash_crowd() -> OpenLoop {
        OpenLoop {
            name: "flash_crowd",
            stresses: "open-loop spikes: flash crowds ignite at random and decay",
            diurnal: None,
            flash: Some((0.25, 3.0, 2)),
        }
    }

    fn process(&self, wl: &WorkloadConfig) -> ArrivalProcess {
        let mut p = ArrivalProcess::poisson(wl.queries_per_step as f64);
        if let Some((amp, period)) = self.diurnal {
            p = p.with_diurnal(amp, period);
        }
        if let Some((prob, mult, decay)) = self.flash {
            p = p.with_flash(prob, mult, decay);
        }
        p
    }
}

impl Scenario for OpenLoop {
    fn name(&self) -> &'static str {
        self.name
    }
    fn stresses(&self) -> &'static str {
        self.stresses
    }
    fn shape(&self, base: &WorkloadConfig) -> WorkloadConfig {
        base.clone()
    }
    /// The seeded arrival draw *is* the query count; the default
    /// [`Scenario::step`] then resizes around it — same prefix property
    /// as `arrival_mult` modulation: per-query streams are keyed by
    /// `(seed, step, q)`, so the drawn count only truncates or extends
    /// the step, never reshuffles it.
    fn queries(&self, wl: &WorkloadConfig, seed: u64, step: usize) -> usize {
        self.process(wl).arrivals(seed, step).total
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// All presets, in catalogue order (DESIGN.md §2; open-loop arrival
/// presets in §11).
pub fn all() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(Baseline),
        Box::new(Uniform),
        Box::new(CoreSkew),
        Box::new(Bursty),
        Box::new(ToolHeavy),
        Box::new(HeteroScale),
        Box::new(Straggler),
        Box::new(OpenLoop::poisson()),
        Box::new(OpenLoop::diurnal()),
        Box::new(OpenLoop::flash_crowd()),
    ]
}

/// Registry keys, same order as [`all`].
pub fn names() -> Vec<&'static str> {
    all().iter().map(|s| s.name()).collect()
}

/// Registry keys as owned strings (grid axes, config plumbing).
pub fn owned_names() -> Vec<String> {
    names().iter().map(|s| s.to_string()).collect()
}

/// Lookup, tolerant of `-`/space separators and case.
pub fn by_name(name: &str) -> Option<Box<dyn Scenario>> {
    let n = name.to_ascii_lowercase().replace(['-', ' '], "_");
    all().into_iter().find(|s| s.name() == n)
}

/// The one unknown-scenario error message — the `Display` text of
/// [`PallasError::UnknownScenario`], so config validation, trace
/// parsing, and resolution all report it identically.
pub fn unknown_error(name: &str) -> String {
    format!("unknown scenario '{name}' (have: {})", names().join(", "))
}

/// Resolve the scenario named in `wl.scenario`: returns the shaped
/// config plus the scenario object that generates its per-step
/// workloads. The shaped config carries the *canonical* preset name,
/// so reports and trace headers agree whatever alias spelling
/// ("Core-Skew", "TOOL HEAVY") the caller used — byte-identical
/// replay==generate diffs depend on it.
pub fn resolve(wl: &WorkloadConfig) -> Result<(WorkloadConfig, Box<dyn Scenario>), PallasError> {
    let scen = by_name(&wl.scenario)
        .ok_or_else(|| PallasError::UnknownScenario(wl.scenario.clone()))?;
    let mut shaped = scen.shape(wl);
    shaped.scenario = scen.name().to_string();
    Ok((shaped, scen))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> WorkloadConfig {
        WorkloadConfig::ma()
    }

    fn core_share(wl: &WorkloadConfig, w: &StepWorkload) -> f64 {
        let per_agent = w.calls_per_agent(wl.agents.len());
        let total: usize = per_agent.iter().sum();
        let core = wl.core_agents();
        let core_calls: usize = core.iter().map(|&i| per_agent[i]).sum();
        core_calls as f64 / total as f64
    }

    #[test]
    fn registry_resolves_every_preset() {
        for name in names() {
            let s = by_name(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(by_name("Core-Skew").is_some());
        assert!(by_name("TOOL HEAVY").is_some());
        assert!(by_name("nope").is_none());
        // Aliases canonicalize in the shaped config (report/trace
        // headers must agree with canonically-spelled runs).
        let mut wl = WorkloadConfig::ma();
        wl.scenario = "Core-Skew".into();
        let (shaped, _) = resolve(&wl).unwrap();
        assert_eq!(shaped.scenario, "core_skew");
    }

    #[test]
    fn resolve_reports_known_names_on_error() {
        let mut wl = base();
        wl.scenario = "gibberish".into();
        let err = resolve(&wl).unwrap_err();
        assert_eq!(err, PallasError::UnknownScenario("gibberish".into()));
        let msg = err.to_string();
        assert!(msg.contains("gibberish") && msg.contains("core_skew"), "{msg}");
        // The typed variant renders exactly the registry's message.
        assert_eq!(msg, unknown_error("gibberish"));
    }

    #[test]
    fn baseline_shape_is_identity_generation() {
        let wl = base();
        let (shaped, scen) = resolve(&wl).unwrap();
        let a = scen.step(&shaped, 2048, 0);
        let b = Generator::new(&wl, 2048).step(0);
        assert_eq!(a, b);
    }

    #[test]
    fn every_preset_generates_deterministically() {
        for scen in all() {
            let shaped = scen.shape(&base());
            let a = scen.step(&shaped, 2048, 1);
            let b = scen.step(&shaped, 2048, 1);
            assert_eq!(a, b, "{} not deterministic", scen.name());
            assert!(a.total_calls() > 0, "{} empty", scen.name());
            let c = scen.step(&shaped, 7, 1);
            assert_ne!(
                a.total_tokens(),
                c.total_tokens(),
                "{} ignores seed",
                scen.name()
            );
        }
    }

    #[test]
    fn uniform_flattens_the_skew() {
        let wl = base();
        let (u_wl, u) = {
            let mut w = wl.clone();
            w.scenario = "uniform".into();
            resolve(&w).unwrap()
        };
        let mut share_base = 0.0;
        let mut share_uniform = 0.0;
        for s in 0..10 {
            share_base += core_share(&wl, &Generator::new(&wl, 2048).step(s));
            // Core agents of the *base* config: uniform spreads load off them.
            let w = u.step(&u_wl, 2048, s);
            let per_agent = w.calls_per_agent(wl.agents.len());
            let total: usize = per_agent.iter().sum();
            let core: usize = wl.core_agents().iter().map(|&i| per_agent[i]).sum();
            share_uniform += core as f64 / total as f64;
        }
        assert!(
            share_uniform < 0.7 * share_base,
            "uniform {share_uniform} vs base {share_base}"
        );
    }

    #[test]
    fn core_skew_sharpens_beyond_baseline() {
        let mut w = base();
        w.scenario = "core_skew".into();
        let (shaped, scen) = resolve(&w).unwrap();
        let mut share = 0.0;
        for s in 0..10 {
            share += core_share(&base(), &scen.step(&shaped, 2048, s)) / 10.0;
        }
        // Paper: >76% on the core agents.
        assert!(share > 0.70, "core share only {share}");
    }

    #[test]
    fn bursty_modulates_arrivals_across_steps() {
        let mut w = base();
        w.scenario = "bursty".into();
        let (shaped, scen) = resolve(&w).unwrap();
        let counts: Vec<usize> = (0..DIURNAL.len())
            .map(|s| scen.step(&shaped, 2048, s).trajectories.len())
            .collect();
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(max >= 4 * min, "no burst: {counts:?}");
        // Peak step matches the multiplier schedule.
        let peak = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        assert_eq!(DIURNAL[peak], 3.0);
    }

    #[test]
    fn tool_heavy_env_latency_dominates() {
        let mut w = base();
        w.scenario = "tool_heavy".into();
        let (shaped, scen) = resolve(&w).unwrap();
        let mean_env = |wk: &StepWorkload| {
            let (sum, n) = wk.trajectories.iter().flat_map(|t| &t.calls).fold(
                (0.0, 0usize),
                |(s, n), c| (s + c.env_s, n + 1),
            );
            sum / n as f64
        };
        let heavy = mean_env(&scen.step(&shaped, 2048, 0));
        let plain = mean_env(&Generator::new(&base(), 2048).step(0));
        assert!(heavy > 2.0 * plain, "env {heavy} vs {plain}");
        // Chains lengthened too.
        assert!(shaped.min_turns > base().min_turns);
        assert!(shaped.max_turns > base().max_turns);
    }

    #[test]
    fn hetero_scale_mixes_model_sizes() {
        let mut w = base();
        w.scenario = "hetero_scale".into();
        let (shaped, _) = resolve(&w).unwrap();
        let mut sizes: Vec<u64> = shaped
            .agents
            .iter()
            .map(|a| a.model.params_b as u64)
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        assert!(sizes.len() >= 3, "{sizes:?}");
    }

    #[test]
    fn straggler_fattens_the_tail() {
        let mut w = base();
        w.scenario = "straggler".into();
        let (shaped, scen) = resolve(&w).unwrap();
        let capped = |wk: &StepWorkload, cap: f64| {
            wk.trajectories
                .iter()
                .flat_map(|t| &t.calls)
                .filter(|c| c.tokens >= cap)
                .count()
        };
        let mut strag = 0;
        let mut plain = 0;
        for s in 0..10 {
            strag += capped(&scen.step(&shaped, 2048, s), shaped.max_tokens);
            plain += capped(&Generator::new(&base(), 2048).step(s), base().max_tokens);
        }
        assert!(strag > 2 * plain.max(1), "capped calls {strag} vs {plain}");
    }

    #[test]
    fn open_loop_presets_vary_query_counts_within_budget() {
        for name in ["poisson", "diurnal", "flash_crowd"] {
            let mut w = base();
            w.scenario = name.into();
            let (shaped, scen) = resolve(&w).unwrap();
            let cap = (shaped.queries_per_step as f64 * 8.0).ceil() as usize;
            let queries: Vec<usize> = (0..32)
                .map(|s| scen.step(&shaped, 2048, s).trajectories.len() / shaped.group_size)
                .collect();
            assert!(
                queries.iter().any(|&q| q != shaped.queries_per_step),
                "{name} never deviates from the closed-loop count: {queries:?}"
            );
            assert!(
                queries.iter().all(|&q| (1..=cap).contains(&q)),
                "{name} broke the per-step budget: {queries:?}"
            );
        }
    }

    #[test]
    fn queries_count_agrees_with_step_for_every_preset() {
        // The dist coordinator plans shard assignment from
        // `Scenario::queries` alone; if a preset's `step` ever disagreed
        // with it, workers would generate the wrong slots.
        for scen in all() {
            let shaped = scen.shape(&base());
            for step in 0..12 {
                let n = scen.queries(&shaped, 2048, step);
                let w = scen.step(&shaped, 2048, step);
                assert_eq!(
                    w.trajectories.len(),
                    n * shaped.group_size,
                    "{} step {step}: queries() says {n}",
                    scen.name()
                );
                // And the step is exactly those slots, stitched in order.
                let mut resized = shaped.clone();
                resized.queries_per_step = n;
                let g = Generator::new(&resized, 2048);
                let stitched: Vec<_> = (0..n).flat_map(|q| g.query(step, q)).collect();
                assert_eq!(w.trajectories, stitched, "{} step {step}", scen.name());
            }
        }
    }

    #[test]
    fn open_loop_steps_share_a_query_prefix_with_closed_loop() {
        // The drawn arrival count truncates or extends a step; it never
        // reshuffles it — this is what lets the trace machinery record
        // and replay open-loop runs unchanged.
        let mut w = base();
        w.scenario = "flash_crowd".into();
        let (shaped, scen) = resolve(&w).unwrap();
        for step in 0..8 {
            let open = scen.step(&shaped, 2048, step);
            let closed = Generator::new(&shaped, 2048).step(step);
            let shared = open.trajectories.len().min(closed.trajectories.len());
            assert_eq!(
                open.trajectories[..shared],
                closed.trajectories[..shared],
                "step {step} reshuffled instead of resizing"
            );
        }
    }
}
