//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and execute them from the Rust request path.
//!
//! Flow per artifact: HLO text → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → execute.
//! Text (not serialized proto) is the interchange format — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns them (see python/compile/aot.py).
//!
//! All entry computations are lowered with `return_tuple=True`, so every
//! execution returns one tuple literal that we decompose. On the CPU
//! PJRT backend "device" buffers live in host memory, so the
//! literal round-trip is a memcpy, not a PCIe transfer (§Perf/L3 in
//! EXPERIMENTS.md quantifies it).

pub mod marl;
pub mod policy;

// The xla_extension crate is not vendored in this offline image; the
// inert stub keeps this layer compiling (see src/xla_stub.rs for how
// to swap the real bindings back in).
use crate::util::json::{parse, Json};
use crate::xla_stub as xla;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}
impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError(format!("xla: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

// ---------------------------------------------------------------------------
// Manifest (the Python→Rust ABI)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub num_params: usize,
    pub kl_beta: f64,
    pub clip_eps: f64,
}

#[derive(Debug, Clone)]
pub struct Shapes {
    pub b_roll: usize,
    pub t_prompt: usize,
    pub b_grad: usize,
    pub t_train: usize,
    /// Tokens per `decode_blk` execution (0 = artifact absent).
    pub decode_block: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub shapes: Shapes,
    pub param_spec: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn spec_from_json(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError("missing shape".into()))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect(),
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError(format!("{path}: {e} (run `make artifacts`)")))?;
        let j = parse(&text).map_err(|e| RuntimeError(e.to_string()))?;
        let dir = Path::new(path)
            .parent()
            .unwrap_or(Path::new("."))
            .to_path_buf();
        let get = |p: &[&str]| -> Result<usize> {
            j.at(p)
                .and_then(Json::as_usize)
                .ok_or_else(|| RuntimeError(format!("manifest missing {p:?}")))
        };
        let model = ModelInfo {
            vocab: get(&["model", "vocab"])?,
            d_model: get(&["model", "d_model"])?,
            n_layers: get(&["model", "n_layers"])?,
            n_heads: get(&["model", "n_heads"])?,
            max_seq: get(&["model", "max_seq"])?,
            num_params: get(&["model", "num_params"])?,
            kl_beta: j.at(&["model", "kl_beta"]).and_then(Json::as_f64).unwrap_or(0.02),
            clip_eps: j.at(&["model", "clip_eps"]).and_then(Json::as_f64).unwrap_or(0.2),
        };
        let shapes = Shapes {
            b_roll: get(&["shapes", "b_roll"])?,
            t_prompt: get(&["shapes", "t_prompt"])?,
            b_grad: get(&["shapes", "b_grad"])?,
            t_train: get(&["shapes", "t_train"])?,
            decode_block: j
                .at(&["shapes", "decode_block"])
                .and_then(Json::as_usize)
                .unwrap_or(0),
        };
        let param_spec = j
            .at(&["param_spec"])
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError("missing param_spec".into()))?
            .iter()
            .map(spec_from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (name, art) in j
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .ok_or_else(|| RuntimeError("missing artifacts".into()))?
        {
            let inputs = art
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(spec_from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = art
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(spec_from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: art
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| RuntimeError(format!("artifact {name}: no file")))?
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest {
            dir,
            model,
            shapes,
            param_spec,
            artifacts,
        })
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "model: vocab={} d_model={} layers={} heads={} max_seq={} params={:.1}M\n\
             shapes: b_roll={} t_prompt={} b_grad={} t_train={}\nartifacts:\n",
            self.model.vocab,
            self.model.d_model,
            self.model.n_layers,
            self.model.n_heads,
            self.model.max_seq,
            self.model.num_params as f64 / 1e6,
            self.shapes.b_roll,
            self.shapes.t_prompt,
            self.shapes.b_grad,
            self.shapes.t_train,
        );
        for (name, a) in &self.artifacts {
            s.push_str(&format!(
                "  {:<8} {} ({} in, {} out)\n",
                name,
                a.file,
                a.inputs.len(),
                a.outputs.len()
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Compiled executables
// ---------------------------------------------------------------------------

pub struct Executable {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(RuntimeError(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            return Err(RuntimeError(format!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                outs.len()
            )));
        }
        Ok(outs)
    }
}

/// The compiled model bundle: one per AOT artifact set; shared by every
/// agent whose policy uses this architecture (parameters are data, not
/// code — all agents run the same executables with their own weights).
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub client: xla::PjRtClient,
    exes: BTreeMap<String, Executable>,
}

impl ModelRuntime {
    pub fn load(artifacts_dir: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(
            &Path::new(artifacts_dir)
                .join("manifest.json")
                .to_string_lossy(),
        )?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let path = manifest.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_string_lossy().as_ref())?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(
                name.clone(),
                Executable {
                    name: name.clone(),
                    spec: spec.clone(),
                    exe,
                },
            );
        }
        Ok(ModelRuntime {
            manifest,
            client,
            exes,
        })
    }

    pub fn exe(&self, name: &str) -> Result<&Executable> {
        self.exes
            .get(name)
            .ok_or_else(|| RuntimeError(format!("no artifact '{name}'")))
    }

    pub fn n_params(&self) -> usize {
        self.manifest.param_spec.len()
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn first_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_path() -> Option<String> {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        std::path::Path::new(p).exists().then(|| p.to_string())
    }

    #[test]
    fn manifest_parses_and_summarizes() {
        let Some(p) = manifest_path() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.param_spec.len(), 10);
        assert!(m.artifacts.contains_key("init"));
        assert!(m.artifacts.contains_key("grad"));
        assert!(m.model.num_params > 1_000_000);
        let s = m.summary();
        assert!(s.contains("prefill"));
        // Param spec total matches declared count.
        let total: usize = m.param_spec.iter().map(|p| p.elems()).sum();
        assert_eq!(total, m.model.num_params);
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let e = Manifest::load("/nonexistent/manifest.json").unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
