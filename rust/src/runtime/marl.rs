//! Real end-to-end MARL training loop (the `examples/marl_train.rs`
//! driver): multiple transformer agent policies, genuine autoregressive
//! rollout through the PJRT executables, rule-based rewards, GRPO group
//! advantages, the experience store as the data plane, and the
//! micro-batch grad→accumulate→apply pipeline — the full FlexMARL
//! dataflow with every layer real (L1 Pallas kernels inside the HLO,
//! L2 JAX graph, L3 this coordinator).
//!
//! The multi-agent workflow mirrors the paper's assistant chains: each
//! user query carries a topic; a chain of agents answers in turn, each
//! seeing a prompt derived from the upstream agent's best candidate.
//! Rewards are the synthetic-corpus band task (see
//! [`crate::workload::corpus`]) — learnable within tens of steps, so the
//! run demonstrably trains (EXPERIMENTS.md §E2E records the curves).

use super::policy::AgentPolicy;
use super::{ModelRuntime, Result, RuntimeError};
use crate::grpo::{group_advantages, make_row, TrainRow};
use crate::store::{grpo_schema, Blob, ExperienceStore, SampleId, Value};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::workload::corpus::{CorpusConfig, N_TOPICS};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct E2eOptions {
    pub n_queries: usize,
    pub chain_len: usize,
    pub gen_len: usize,
    pub temperature: f32,
    /// Unconditional (per-agent fixed band) reward — see
    /// [`CorpusConfig::easy`]; the conditional task needs more
    /// model/sample scale than this container affords.
    pub easy_task: bool,
}

impl Default for E2eOptions {
    fn default() -> Self {
        E2eOptions {
            n_queries: 2,
            chain_len: 2,
            gen_len: 32,
            temperature: 1.0,
            easy_task: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct StepLog {
    pub step: usize,
    pub mean_reward: f64,
    pub mean_loss: f64,
    pub mean_kl: f64,
    pub rollout_s: f64,
    pub train_s: f64,
    pub per_agent_reward: Vec<f64>,
}

pub fn train_e2e(
    artifacts_dir: &str,
    n_agents: usize,
    steps: usize,
    seed: u64,
    lr: f32,
    verbose: bool,
) -> Result<String> {
    let opts = E2eOptions::default();
    let logs = run_loop(artifacts_dir, n_agents, steps, seed, lr, &opts, verbose)?;
    // Persist the loss/reward curves next to the artifacts.
    let j = Json::arr(logs.iter().map(|l| {
        Json::obj(vec![
            ("step", Json::num(l.step as f64)),
            ("mean_reward", Json::num(l.mean_reward)),
            ("mean_loss", Json::num(l.mean_loss)),
            ("mean_kl", Json::num(l.mean_kl)),
            ("rollout_s", Json::num(l.rollout_s)),
            ("train_s", Json::num(l.train_s)),
        ])
    }));
    let path = format!("{artifacts_dir}/e2e_metrics.json");
    let _ = std::fs::write(&path, j.to_pretty());
    let first = logs.first().cloned().unwrap_or_default();
    let last = logs.last().cloned().unwrap_or_default();
    let r_tot: f64 = logs.iter().map(|l| l.rollout_s).sum();
    let t_tot: f64 = logs.iter().map(|l| l.train_s).sum();
    Ok(format!(
        "e2e: {steps} steps × {n_agents} agents | reward {:.3} → {:.3} | loss {:.3} → {:.3} \
         | rollout {:.1}s train {:.1}s | curves: {path}",
        first.mean_reward, last.mean_reward, first.mean_loss, last.mean_loss, r_tot, t_tot
    ))
}

pub fn run_loop(
    artifacts_dir: &str,
    n_agents: usize,
    steps: usize,
    seed: u64,
    lr: f32,
    opts: &E2eOptions,
    verbose: bool,
) -> Result<Vec<StepLog>> {
    if n_agents == 0 || steps == 0 {
        return Err(RuntimeError("need ≥1 agent and ≥1 step".into()));
    }
    let rt = ModelRuntime::load(artifacts_dir)?;
    let sh = rt.manifest.shapes.clone();
    let corpus = if opts.easy_task {
        CorpusConfig::easy(rt.manifest.model.vocab, sh.t_prompt)
    } else {
        CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt)
    };
    let mut policies: Vec<AgentPolicy> = (0..n_agents)
        .map(|a| AgentPolicy::new(&rt, a, seed.wrapping_add(a as u64)))
        .collect::<Result<Vec<_>>>()?;
    let store = ExperienceStore::new();
    for a in 0..n_agents {
        store.create_table(&akey(a), &grpo_schema());
    }
    let mut wrng = Pcg64::with_stream(seed, 0x770f_0c4b);
    let mut logs = Vec::with_capacity(steps);

    for step in 0..steps {
        let t0 = Instant::now();
        let mut reward_sum = vec![0.0f64; n_agents];
        let mut reward_n = vec![0usize; n_agents];
        let mut sample_seq = 0u64;

        // ---- rollout phase ------------------------------------------------
        for q in 0..opts.n_queries {
            let topic = wrng.below(N_TOPICS as u64) as usize;
            let mut prompt = corpus.make_prompt(&mut wrng, topic);
            for turn in 0..opts.chain_len {
                let agent = (q + turn + step) % n_agents;
                let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();
                let rollouts =
                    policies[agent].generate_block(&rt, &prompts, opts.gen_len, opts.temperature)?;
                let rewards: Vec<f64> = rollouts
                    .iter()
                    .map(|r| corpus.reward(agent, topic, &r.response))
                    .collect();
                let advs = group_advantages(&rewards);
                for (c, (r, (&rew, &adv))) in rollouts
                    .iter()
                    .zip(rewards.iter().zip(&advs))
                    .enumerate()
                {
                    let id = SampleId::new(sample_seq, turn as u32, c as u64);
                    let v = step as u64;
                    store.insert(&akey(agent), v, id).unwrap();
                    store
                        .set_blob(&akey(agent), v, id, "prompt", Blob::Tokens(prompt.clone()))
                        .unwrap();
                    store
                        .set_blob(&akey(agent), v, id, "response", Blob::Tokens(r.response.clone()))
                        .unwrap();
                    store
                        .set_blob(&akey(agent), v, id, "old_logp", Blob::Floats(r.logp.clone()))
                        .unwrap();
                    store
                        .set_value(&akey(agent), v, id, "reward", Value::Float(rew))
                        .unwrap();
                    store
                        .set_value(&akey(agent), v, id, "advantage", Value::Float(adv))
                        .unwrap();
                    reward_sum[agent] += rew;
                    reward_n[agent] += 1;
                }
                sample_seq += 1;
                // Downstream prompt: topic marker + the best candidate's
                // response (the selected branch of the workflow DAG).
                let best = rewards
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                prompt = next_prompt(&corpus, topic, &rollouts[best].response, sh.t_prompt);
            }
        }
        let rollout_s = t0.elapsed().as_secs_f64();

        // ---- training phase (micro-batch pipeline) -------------------------
        let t1 = Instant::now();
        let mut loss_sum = 0.0f64;
        let mut kl_sum = 0.0f64;
        let mut n_micro = 0usize;
        for agent in 0..n_agents {
            loop {
                let fetched = store.fetch_ready(&akey(agent), Some(step as u64), sh.b_grad);
                if fetched.is_empty() {
                    break;
                }
                let rows: Vec<TrainRow> = fetched
                    .iter()
                    .map(|f| {
                        let prompt = blob_tokens(&store, f.value("prompt"));
                        let response = blob_tokens(&store, f.value("response"));
                        let logp = blob_floats(&store, f.value("old_logp"));
                        let adv = f
                            .value("advantage")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(0.0) as f32;
                        make_row(&prompt, &response, &logp, adv, sh.t_train)
                    })
                    .collect();
                let stats = policies[agent].grad_on_rows(&rt, &rows)?;
                loss_sum += stats.loss as f64;
                kl_sum += stats.kl as f64;
                n_micro += 1;
                let keys: Vec<_> = fetched.iter().map(|f| f.key).collect();
                store.complete(&akey(agent), &keys).unwrap();
            }
            if policies[agent].cached_micro_batches() > 0 {
                policies[agent].apply(&rt, lr)?;
            }
        }
        let train_s = t1.elapsed().as_secs_f64();

        let total_r: f64 = reward_sum.iter().sum();
        let total_n: usize = reward_n.iter().sum();
        let log = StepLog {
            step,
            mean_reward: total_r / total_n.max(1) as f64,
            mean_loss: loss_sum / n_micro.max(1) as f64,
            mean_kl: kl_sum / n_micro.max(1) as f64,
            rollout_s,
            train_s,
            per_agent_reward: reward_sum
                .iter()
                .zip(&reward_n)
                .map(|(&s, &n)| s / n.max(1) as f64)
                .collect(),
        };
        if verbose {
            println!(
                "step {:>3}  reward {:.3}  loss {:+.3}  kl {:.4}  rollout {:.1}s  train {:.1}s",
                log.step, log.mean_reward, log.mean_loss, log.mean_kl, log.rollout_s, log.train_s
            );
        }
        logs.push(log);
    }
    Ok(logs)
}

fn akey(a: usize) -> String {
    format!("agent{a}")
}

fn blob_tokens(store: &ExperienceStore, v: Option<&Value>) -> Vec<i32> {
    match v {
        Some(Value::Ref(k)) => match store.blob(*k) {
            Some(Blob::Tokens(t)) => t,
            _ => Vec::new(),
        },
        _ => Vec::new(),
    }
}

fn blob_floats(store: &ExperienceStore, v: Option<&Value>) -> Vec<f32> {
    match v {
        Some(Value::Ref(k)) => match store.blob(*k) {
            Some(Blob::Floats(f)) => f,
            _ => Vec::new(),
        },
        _ => Vec::new(),
    }
}

/// Build the downstream agent's prompt from the upstream best response.
fn next_prompt(corpus: &CorpusConfig, topic: usize, response: &[i32], tp: usize) -> Vec<i32> {
    let mut p = Vec::with_capacity(tp);
    p.push(corpus.topic_token(topic));
    for &t in response.iter().take(tp - 2) {
        p.push(t);
    }
    while p.len() < tp - 1 {
        p.push(corpus.topic_token(topic));
    }
    p.push(corpus.topic_token(topic));
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_prompt_shape_and_topic() {
        let c = CorpusConfig::new(512, 32);
        let p = next_prompt(&c, 3, &[1, 2, 3], 32);
        assert_eq!(p.len(), 32);
        assert_eq!(c.topic_of_prompt(&p), Some(3));
        let long: Vec<i32> = (0..100).collect();
        assert_eq!(next_prompt(&c, 0, &long, 32).len(), 32);
    }
}
