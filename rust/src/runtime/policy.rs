//! Per-agent policy state over the shared [`ModelRuntime`]: parameters +
//! optimizer state + gradient cache, with the rollout (prefill/decode)
//! and training (grad/accum/apply) entry points.
//!
//! This realizes the §4.3 decoupling on the real runtime: `grad_on_rows`
//! only *computes and caches* gradients (micro batches); `apply` performs
//! the unified parameter update and bumps `policy_version` — exactly the
//! contract the simulator's pipeline assumes.

use super::{lit_f32, lit_i32, scalar_f32, scalar_i32, to_f32, ModelRuntime, Result, RuntimeError};
use crate::grpo::TrainRow;
use crate::util::rng::Pcg64;
use crate::xla_stub as xla;

/// One generated candidate: sampled tokens + their behaviour logprobs.
#[derive(Debug, Clone)]
pub struct Rollout {
    pub response: Vec<i32>,
    pub logp: Vec<f32>,
}

/// Diagnostics of one gradient micro batch.
#[derive(Debug, Clone, Copy)]
pub struct GradStats {
    pub loss: f32,
    pub kl: f32,
    pub ratio: f32,
    pub entropy: f32,
    pub grad_norm: f32,
    pub rows: usize,
}

pub struct AgentPolicy {
    pub agent_id: usize,
    pub version: u64,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    count: xla::Literal,
    grad_cache: Option<Vec<xla::Literal>>,
    n_cached: usize,
    rng: Pcg64,
}

fn zeros_like_params(rt: &ModelRuntime) -> Vec<xla::Literal> {
    rt.manifest
        .param_spec
        .iter()
        .map(|s| {
            let dims: Vec<usize> = s.shape.clone();
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims)
        })
        .collect()
}

impl AgentPolicy {
    pub fn new(rt: &ModelRuntime, agent_id: usize, seed: u64) -> Result<AgentPolicy> {
        let outs = rt.exe("init")?.run(&[scalar_i32(seed as i32)])?;
        Ok(AgentPolicy {
            agent_id,
            version: 0,
            params: outs,
            m: zeros_like_params(rt),
            v: zeros_like_params(rt),
            count: scalar_i32(0),
            grad_cache: None,
            n_cached: 0,
            rng: Pcg64::with_stream(seed, 0xa9e17 + agent_id as u64),
        })
    }

    // ---- rollout path -------------------------------------------------------

    /// Generate `gen_len` tokens for `b_roll` prompts in one batch
    /// (intra-query parallelism: the GRPO candidate group).
    pub fn generate(
        &mut self,
        rt: &ModelRuntime,
        prompts: &[Vec<i32>],
        gen_len: usize,
        temperature: f32,
    ) -> Result<Vec<Rollout>> {
        let sh = &rt.manifest.shapes;
        let b = sh.b_roll;
        let tp = sh.t_prompt;
        let vocab = rt.manifest.model.vocab;
        if prompts.len() != b || prompts.iter().any(|p| p.len() != tp) {
            return Err(RuntimeError(format!(
                "generate expects {b} prompts of {tp} tokens"
            )));
        }
        let max_gen = rt.manifest.model.max_seq - tp;
        let gen_len = gen_len.min(max_gen);

        let flat: Vec<i32> = prompts.iter().flatten().copied().collect();
        let tokens = lit_i32(&flat, &[b as i64, tp as i64])?;
        let mut inputs: Vec<xla::Literal> = self.params.to_vec();
        inputs.push(tokens);
        let mut outs = rt.exe("prefill")?.run(&inputs)?;
        let mut vc = outs.pop().unwrap();
        let mut kc = outs.pop().unwrap();
        let mut logits = outs.pop().unwrap();

        let mut rollouts: Vec<Rollout> = (0..b)
            .map(|_| Rollout {
                response: Vec::with_capacity(gen_len),
                logp: Vec::with_capacity(gen_len),
            })
            .collect();

        for step in 0..gen_len {
            let logits_host = to_f32(&logits)?;
            let mut next = Vec::with_capacity(b);
            for (row, r) in rollouts.iter_mut().enumerate() {
                let row_logits = &logits_host[row * vocab..(row + 1) * vocab];
                let (tok, logp) = sample_token(row_logits, temperature, &mut self.rng);
                r.response.push(tok);
                r.logp.push(logp);
                next.push(tok);
            }
            if step + 1 == gen_len {
                break;
            }
            let pos = (tp + step) as i32;
            let mut dec_in: Vec<xla::Literal> = self.params.to_vec();
            dec_in.push(kc);
            dec_in.push(vc);
            dec_in.push(lit_i32(&next, &[b as i64])?);
            dec_in.push(scalar_i32(pos));
            let mut douts = rt.exe("decode")?.run(&dec_in)?;
            vc = douts.pop().unwrap();
            kc = douts.pop().unwrap();
            logits = douts.pop().unwrap();
        }
        Ok(rollouts)
    }

    /// Block-decode generation (§Perf/L2+L3): `decode_blk` runs
    /// `decode_block` tokens per executable call with sampling on-graph,
    /// cutting the per-token host↔device literal traffic by the block
    /// factor. Numerically equivalent decode path; sampling RNG differs
    /// from [`Self::generate`] (jax threefry vs host PCG), both seeded
    /// deterministically.
    pub fn generate_block(
        &mut self,
        rt: &ModelRuntime,
        prompts: &[Vec<i32>],
        gen_len: usize,
        temperature: f32,
    ) -> Result<Vec<Rollout>> {
        let sh = &rt.manifest.shapes;
        let (b, tp) = (sh.b_roll, sh.t_prompt);
        let vocab = rt.manifest.model.vocab;
        let block = rt.manifest.shapes.decode_block;
        if block == 0 {
            return self.generate(rt, prompts, gen_len, temperature);
        }
        if prompts.len() != b || prompts.iter().any(|p| p.len() != tp) {
            return Err(RuntimeError(format!(
                "generate_block expects {b} prompts of {tp} tokens"
            )));
        }
        let max_gen = rt.manifest.model.max_seq - tp;
        let gen_len = gen_len.min(max_gen);

        let flat: Vec<i32> = prompts.iter().flatten().copied().collect();
        let mut inputs: Vec<xla::Literal> = self.params.to_vec();
        inputs.push(lit_i32(&flat, &[b as i64, tp as i64])?);
        let mut outs = rt.exe("prefill")?.run(&inputs)?;
        let mut vc = outs.pop().unwrap();
        let mut kc = outs.pop().unwrap();
        let logits = outs.pop().unwrap();

        // First token sampled host-side from the prefill logits.
        let logits_host = to_f32(&logits)?;
        let mut rollouts: Vec<Rollout> = Vec::with_capacity(b);
        let mut last = Vec::with_capacity(b);
        for row in 0..b {
            let (tok, logp) =
                sample_token(&logits_host[row * vocab..(row + 1) * vocab], temperature, &mut self.rng);
            rollouts.push(Rollout {
                response: vec![tok],
                logp: vec![logp],
            });
            last.push(tok);
        }

        let mut pos = tp; // position of the last sampled token
        while rollouts[0].response.len() < gen_len {
            let seed = self.rng.next_u64() as i32;
            let mut dec_in: Vec<xla::Literal> = self.params.to_vec();
            dec_in.push(kc);
            dec_in.push(vc);
            dec_in.push(lit_i32(&last, &[b as i64])?);
            dec_in.push(scalar_i32(pos as i32));
            dec_in.push(scalar_i32(seed));
            dec_in.push(scalar_f32(temperature));
            let mut bouts = rt.exe("decode_blk")?.run(&dec_in)?;
            vc = bouts.pop().unwrap();
            kc = bouts.pop().unwrap();
            let logps = to_f32(&bouts.pop().unwrap())?; // [block, B]
            let toks = bouts.pop().unwrap().to_vec::<i32>()?; // [block, B]
            let take = block.min(gen_len - rollouts[0].response.len());
            for step in 0..take {
                for row in 0..b {
                    rollouts[row].response.push(toks[step * b + row]);
                    rollouts[row].logp.push(logps[step * b + row]);
                }
            }
            for row in 0..b {
                last[row] = toks[(block - 1) * b + row];
            }
            pos += block;
            if pos + block >= rt.manifest.model.max_seq {
                break;
            }
        }
        for r in &mut rollouts {
            r.response.truncate(gen_len);
            r.logp.truncate(gen_len);
        }
        Ok(rollouts)
    }

    // ---- training path ------------------------------------------------------

    /// Compute gradients on up to `b_grad` rows and fold them into the
    /// agent's gradient cache (§4.3: no parameter update here).
    pub fn grad_on_rows(&mut self, rt: &ModelRuntime, rows: &[TrainRow]) -> Result<GradStats> {
        let sh = &rt.manifest.shapes;
        let (b, t) = (sh.b_grad, sh.t_train);
        if rows.is_empty() || rows.len() > b {
            return Err(RuntimeError(format!(
                "grad batch must have 1..={b} rows, got {}",
                rows.len()
            )));
        }
        // Pad to the compiled batch with zero-mask rows.
        let mut tokens = vec![0i32; b * t];
        let mut targets = vec![0i32; b * t];
        let mut adv = vec![0f32; b * t];
        let mut old_logp = vec![0f32; b * t];
        let mut mask = vec![0f32; b * t];
        for (i, row) in rows.iter().enumerate() {
            tokens[i * t..(i + 1) * t].copy_from_slice(&row.tokens);
            targets[i * t..(i + 1) * t].copy_from_slice(&row.targets);
            adv[i * t..(i + 1) * t].copy_from_slice(&row.adv);
            old_logp[i * t..(i + 1) * t].copy_from_slice(&row.old_logp);
            mask[i * t..(i + 1) * t].copy_from_slice(&row.mask);
        }
        let dims = [b as i64, t as i64];
        let mut inputs: Vec<xla::Literal> = self.params.to_vec();
        inputs.push(lit_i32(&tokens, &dims)?);
        inputs.push(lit_i32(&targets, &dims)?);
        inputs.push(lit_f32(&adv, &dims)?);
        inputs.push(lit_f32(&old_logp, &dims)?);
        // Reference policy = behaviour policy snapshot (strictly
        // on-policy per step), so ref_logp == old_logp.
        inputs.push(lit_f32(&old_logp, &dims)?);
        inputs.push(lit_f32(&mask, &dims)?);
        let mut outs = rt.exe("grad")?.run(&inputs)?;
        let gnorm = super::first_f32(&outs.pop().unwrap())?;
        let ent = super::first_f32(&outs.pop().unwrap())?;
        let ratio = super::first_f32(&outs.pop().unwrap())?;
        let kl = super::first_f32(&outs.pop().unwrap())?;
        let loss = super::first_f32(&outs.pop().unwrap())?;
        let grads = outs;

        self.grad_cache = Some(match self.grad_cache.take() {
            None => grads,
            Some(acc) => {
                let mut inputs = acc;
                inputs.extend(grads);
                rt.exe("accum")?.run(&inputs)?
            }
        });
        self.n_cached += 1;
        Ok(GradStats {
            loss,
            kl,
            ratio,
            entropy: ent,
            grad_norm: gnorm,
            rows: rows.len(),
        })
    }

    pub fn cached_micro_batches(&self) -> usize {
        self.n_cached
    }

    /// Unified parameter update from the gradient cache: Adam step with
    /// scale 1/n_cached (micro-batch mean ≡ full-batch mean), then
    /// `policy_version += 1`.
    pub fn apply(&mut self, rt: &ModelRuntime, lr: f32) -> Result<()> {
        let acc = self
            .grad_cache
            .take()
            .ok_or_else(|| RuntimeError("apply with empty gradient cache".into()))?;
        let scale = 1.0 / self.n_cached as f32;
        // Move (not clone) the old params/optimizer state into the call:
        // they are replaced wholesale by the outputs, so the host
        // round-trip copy a clone would cost (~16 × model bytes) is pure
        // waste (§Perf/L3, measured in benches/hotpath.rs).
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(4 * acc.len() + 3);
        inputs.extend(std::mem::take(&mut self.params));
        inputs.extend(std::mem::take(&mut self.m));
        inputs.extend(std::mem::take(&mut self.v));
        inputs.push(std::mem::replace(&mut self.count, scalar_i32(0)));
        inputs.extend(acc);
        inputs.push(scalar_f32(scale));
        inputs.push(scalar_f32(lr));
        let mut outs = rt.exe("apply")?.run(&inputs)?;
        let np = rt.n_params();
        self.count = outs.pop().unwrap();
        self.v = outs.split_off(np * 2);
        self.m = outs.split_off(np);
        self.params = outs;
        self.n_cached = 0;
        self.version += 1;
        Ok(())
    }

    /// Evaluate per-token logprobs of given sequences (ref-policy eval).
    pub fn token_logprobs(
        &self,
        rt: &ModelRuntime,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<Vec<f32>> {
        let sh = &rt.manifest.shapes;
        let dims = [sh.b_grad as i64, sh.t_train as i64];
        let mut inputs: Vec<xla::Literal> = self.params.to_vec();
        inputs.push(lit_i32(tokens, &dims)?);
        inputs.push(lit_i32(targets, &dims)?);
        let outs = rt.exe("logprob")?.run(&inputs)?;
        to_f32(&outs[0])
    }

    /// Serialize weights as one contiguous buffer (the §9 O(1) lesson) —
    /// used for instance weight migration and training-state swap.
    pub fn weights_blob(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        for p in &self.params {
            let v = to_f32(p)?;
            out.extend(v.iter().flat_map(|x| x.to_le_bytes()));
        }
        Ok(out)
    }

    /// Restore weights from a contiguous buffer (shapes from the manifest).
    pub fn load_weights_blob(&mut self, rt: &ModelRuntime, blob: &[u8]) -> Result<()> {
        let total: usize = rt.manifest.param_spec.iter().map(|s| s.elems()).sum();
        if blob.len() != total * 4 {
            return Err(RuntimeError(format!(
                "weight blob size {} != expected {}",
                blob.len(),
                total * 4
            )));
        }
        let mut off = 0;
        let mut params = Vec::with_capacity(rt.manifest.param_spec.len());
        for s in &rt.manifest.param_spec {
            let n = s.elems();
            let floats: Vec<f32> = blob[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
            params.push(lit_f32(&floats, &dims)?);
            off += n * 4;
        }
        self.params = params;
        Ok(())
    }
}

/// Temperature sampling with logprob of the chosen token.
pub fn sample_token(logits: &[f32], temperature: f32, rng: &mut Pcg64) -> (i32, f32) {
    let t = temperature.max(1e-4);
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - max) / t).exp()).collect();
    let sum: f32 = exps.iter().sum();
    let mut x = rng.f64() as f32 * sum;
    let mut idx = exps.len() - 1;
    for (i, &e) in exps.iter().enumerate() {
        x -= e;
        if x <= 0.0 {
            idx = i;
            break;
        }
    }
    // logp under the *untempered* distribution (behaviour logprob used
    // by the ratio must match what grad-time log_softmax computes).
    let lse = {
        let s: f32 = logits.iter().map(|&l| (l - max).exp()).sum();
        max + s.ln()
    };
    (idx as i32, logits[idx] - lse)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_token_respects_distribution() {
        let mut rng = Pcg64::new(1);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            let (tok, logp) = sample_token(&logits, 1.0, &mut rng);
            assert!(logp <= 0.0);
            if tok == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn sample_token_low_temperature_is_greedy() {
        let mut rng = Pcg64::new(2);
        let logits = vec![1.0f32, 1.2, 0.9];
        for _ in 0..50 {
            let (tok, _) = sample_token(&logits, 0.01, &mut rng);
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn logp_is_log_softmax_of_choice() {
        let mut rng = Pcg64::new(3);
        let logits = vec![0.5f32, -0.5];
        let (tok, logp) = sample_token(&logits, 1.0, &mut rng);
        let z = (0.5f32).exp() + (-0.5f32).exp();
        let expect = logits[tok as usize] - z.ln();
        assert!((logp - expect).abs() < 1e-5);
    }
}
