//! Structured error type for the engine's public surface.
//!
//! Every fallible `pub` function reachable from `lib.rs` returns
//! [`PallasError`] instead of the bare `Result<_, String>` the crate
//! grew up with. The enum is `#[non_exhaustive]` so future PRs can add
//! variants (new policy kinds, new config sections) without a breaking
//! release, and its `Display` output reproduces the former `String`
//! messages **byte-for-byte** — the CLI's stderr and the CI byte-diff
//! jobs observe no change from the typed migration.
//!
//! Mapping rules (DESIGN.md §8):
//!
//! * a *registry miss* (scenario/framework/workload name nobody knows)
//!   gets its own variant carrying the offending name;
//! * a *config-shape* violation is [`PallasError::UnknownKey`] (typos
//!   rejected with a nearest-valid-key suggestion) or
//!   [`PallasError::InvalidConfig`] (semantic validation);
//! * *trace* record/parse violations are [`PallasError::Trace`] with
//!   the line-tagged message preformatted at the detection site, plus
//!   the structured [`PallasError::TraceAgentMismatch`] for the one
//!   replay-compatibility check callers branch on;
//! * file-system / file-parse failures are [`PallasError::File`],
//!   rendered `"{path}: {error}"` as before.

use std::fmt;

/// Error type of the engine's public API (config parsing, workload
/// resolution, trace record/replay, simulation entry points).
///
/// `Display` strings are stable: they match the pre-typed `String`
/// messages exactly, so they are safe to byte-diff in CI.
#[non_exhaustive]
#[derive(Debug, Clone, PartialEq)]
pub enum PallasError {
    /// A scenario name not present in the preset registry
    /// ([`crate::workload::scenario`]).
    UnknownScenario(String),
    /// A framework name [`crate::config::framework_by_name`] does not
    /// recognize.
    UnknownFramework(String),
    /// A workload preset name other than `MA`/`CA`.
    UnknownWorkload(String),
    /// A config JSON key the parser does not understand — rejected
    /// loudly (with the nearest valid key when one is close) instead
    /// of the old behaviour of silently ignoring typos.
    UnknownKey {
        /// The offending key as written.
        key: String,
        /// Which object it appeared in (`"config"`, `"pipeline"`,
        /// `"cluster"`, `"workload_overrides"`).
        section: &'static str,
        /// The keys the section accepts.
        valid: &'static [&'static str],
        /// Closest valid key by edit distance, if any is close enough
        /// to plausibly be a typo.
        nearest: Option<String>,
    },
    /// Trace record/parse violation (zero steps, bad line, version or
    /// count mismatch, …). The message is preformatted where the
    /// violation is detected and already carries the line number.
    Trace(String),
    /// A trace whose recorded agent count does not match the config it
    /// is being replayed against.
    TraceAgentMismatch {
        /// Path of the trace file.
        path: String,
        /// Agent count in the trace header.
        trace_agents: usize,
        /// Agent count of the (shaped) config.
        config_agents: usize,
    },
    /// File read/write/parse failure, rendered `"{path}: {error}"`.
    File {
        /// The file involved.
        path: String,
        /// The underlying error, already rendered.
        error: String,
    },
    /// Semantic config validation failure
    /// ([`crate::config::ExperimentConfig::validate`]).
    InvalidConfig(String),
    /// The engine's run-loop event budget tripped (a livelock guard:
    /// no simulation of any shipped scale comes near it). Carries the
    /// virtual time and the per-kind event histogram at trip time.
    /// `Display` keeps the retired panic's message prefix and
    /// histogram rendering (the panic's trailing per-agent
    /// tstate/steps-done dump is not carried), so the infallible
    /// wrappers ([`crate::experiment::Experiment::run`], the
    /// deprecated `simulate`) still panic with the recognizable
    /// message.
    EventBudget {
        /// Virtual time at which the budget tripped.
        t: f64,
        /// `(event name, count)` pairs, one per engine event kind.
        histogram: Vec<(&'static str, u64)>,
    },
    /// An inference instance was lost to fault injection while the
    /// bundle's recovery policy is fail-fast
    /// ([`crate::policy::FailFast`]): the run aborts instead of
    /// re-dispatching the displaced work (DESIGN.md §10).
    InstanceLost {
        /// Virtual time of the fatal fault.
        t: f64,
        /// Agent the lost instance was serving.
        agent: usize,
        /// The lost instance's id.
        instance: usize,
    },
    /// A checkpoint file that cannot be accepted: corrupt or truncated
    /// payload, checksum mismatch, stale/unknown format version, or a
    /// snapshot recorded under a different config than the one it is
    /// being restored into (DESIGN.md §12). Plain I/O failures on
    /// checkpoint paths stay [`PallasError::File`]; this variant is the
    /// *format/compatibility* rejection — always typed, never a panic.
    Checkpoint {
        /// The checkpoint file involved (empty for in-memory snapshots).
        path: String,
        /// What was wrong, preformatted at the detection site.
        reason: String,
    },
    /// A run ended with no completed steps to aggregate: a zero-step
    /// experiment, or an early-stop sink cut the run before the first
    /// step boundary. Distinct from [`PallasError::InvalidConfig`] —
    /// the config may be perfectly valid, the *run* was just empty;
    /// drive a [`crate::orchestrator::Session`] and use
    /// [`crate::orchestrator::SimOutcome::evaluate`] to handle partial
    /// outcomes without this error.
    EmptyRun,
    /// A distributed-plane link failed (DESIGN.md §14): a worker
    /// process/thread died, a socket broke, or a frame arrived
    /// malformed. `endpoint` names the link ("worker 2 (socket)",
    /// "127.0.0.1:4471"); `reason` is preformatted at the detection
    /// site and, for frame-level failures, carries the 1-based frame
    /// index plus recovery guidance — the
    /// [`crate::workload::TraceReader`] diagnostic style.
    Transport {
        /// The link involved.
        endpoint: String,
        /// What went wrong, preformatted at the detection site.
        reason: String,
    },
    /// A well-formed frame that violates the coordinator/worker
    /// protocol (DESIGN.md §14): an unexpected message kind, a result
    /// for a shard the sender never claimed, or a shard index summary
    /// that disagrees with the shipped trajectories. Always a bug or a
    /// tampered peer — typed, never a panic.
    Protocol {
        /// What the state machine was waiting for.
        expected: String,
        /// What actually arrived.
        got: String,
    },
    /// The serving plane refused a session request at admission
    /// (DESIGN.md §13). Overload is an *expected* outcome there, so the
    /// rejection is typed — callers branch on [`AdmissionReject`], the
    /// load report counts it, and nothing is dropped silently.
    Admission {
        /// Tenant that issued the request.
        tenant: String,
        /// Plane-wide arrival sequence number of the request.
        request: u64,
        /// Which admission rule refused it.
        reject: AdmissionReject,
        /// The limit that was hit (queue capacity or tenant quota).
        limit: usize,
    },
}

/// Why the serving plane refused a session request (DESIGN.md §13).
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionReject {
    /// The bounded intake queue was at capacity.
    QueueFull,
    /// The tenant already had its quota of outstanding sessions.
    QuotaExceeded,
}

impl fmt::Display for PallasError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PallasError::UnknownScenario(name) => {
                // Single source of the message: the scenario registry,
                // so config validation, trace parsing, and resolution
                // keep reporting it identically.
                write!(f, "{}", crate::workload::scenario::unknown_error(name))
            }
            PallasError::UnknownFramework(name) => write!(f, "unknown framework '{name}'"),
            PallasError::UnknownWorkload(name) => write!(f, "unknown workload '{name}'"),
            PallasError::UnknownKey {
                key,
                section,
                valid,
                nearest,
            } => match nearest {
                Some(n) => write!(f, "unknown {section} key '{key}' (did you mean '{n}'?)"),
                None => write!(f, "unknown {section} key '{key}' (valid: {})", valid.join(", ")),
            },
            PallasError::Trace(msg) => write!(f, "{msg}"),
            PallasError::TraceAgentMismatch {
                path,
                trace_agents,
                config_agents,
            } => write!(
                f,
                "trace {path} has {trace_agents} agents, config has {config_agents}"
            ),
            PallasError::File { path, error } => write!(f, "{path}: {error}"),
            PallasError::InvalidConfig(msg) => write!(f, "{msg}"),
            PallasError::EventBudget { t, histogram } => write!(
                f,
                "event-budget exceeded (livelock?) at t={t}: {histogram:?}"
            ),
            PallasError::InstanceLost { t, agent, instance } => write!(
                f,
                "instance {instance} (agent {agent}) lost at t={t} \
                 (fail-fast recovery policy)"
            ),
            PallasError::Checkpoint { path, reason } => {
                if path.is_empty() {
                    write!(f, "checkpoint: {reason}")
                } else {
                    write!(f, "checkpoint {path}: {reason}")
                }
            }
            PallasError::EmptyRun => write!(
                f,
                "run completed no steps to evaluate (zero-step experiment, or \
                 stopped before the first step boundary)"
            ),
            PallasError::Transport { endpoint, reason } => {
                write!(f, "transport {endpoint}: {reason}")
            }
            PallasError::Protocol { expected, got } => {
                write!(f, "protocol violation: expected {expected}, got {got}")
            }
            PallasError::Admission {
                tenant,
                request,
                reject,
                limit,
            } => match reject {
                AdmissionReject::QueueFull => write!(
                    f,
                    "serve: request {request} (tenant '{tenant}') rejected: \
                     intake queue full (cap {limit})"
                ),
                AdmissionReject::QuotaExceeded => write!(
                    f,
                    "serve: request {request} (tenant '{tenant}') rejected: \
                     tenant quota {limit} outstanding sessions reached"
                ),
            },
        }
    }
}

impl std::error::Error for PallasError {}

impl PallasError {
    /// Build an [`PallasError::UnknownKey`] for `key` in `section`,
    /// suggesting the nearest valid key when one is within a plausible
    /// typo distance.
    pub fn unknown_key(
        key: &str,
        section: &'static str,
        valid: &'static [&'static str],
    ) -> PallasError {
        let nearest = valid
            .iter()
            .map(|v| (edit_distance(key, v), *v))
            .min()
            .filter(|&(d, v)| d <= 2.max(v.len() / 3))
            .map(|(_, v)| v.to_string());
        PallasError::UnknownKey {
            key: key.to_string(),
            section,
            valid,
            nearest,
        }
    }
}

/// Levenshtein edit distance (insert/delete/substitute, unit costs) —
/// small inputs only (config keys), O(|a|·|b|) with a rolling row.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_strings() {
        // These strings are byte-diffed by CI; they must not drift.
        assert_eq!(
            PallasError::UnknownFramework("Zeta".into()).to_string(),
            "unknown framework 'Zeta'"
        );
        assert_eq!(
            PallasError::UnknownWorkload("MB".into()).to_string(),
            "unknown workload 'MB'"
        );
        assert_eq!(
            PallasError::File {
                path: "cfg.json".into(),
                error: "No such file or directory (os error 2)".into()
            }
            .to_string(),
            "cfg.json: No such file or directory (os error 2)"
        );
        assert_eq!(
            PallasError::TraceAgentMismatch {
                path: "t.jsonl".into(),
                trace_agents: 8,
                config_agents: 6
            }
            .to_string(),
            "trace t.jsonl has 8 agents, config has 6"
        );
        assert_eq!(
            PallasError::Trace("trace: no header line".into()).to_string(),
            "trace: no header line"
        );
        let unk = PallasError::UnknownScenario("gibberish".into()).to_string();
        assert!(unk.starts_with("unknown scenario 'gibberish'"), "{unk}");
        assert!(unk.contains("core_skew"), "{unk}");
    }

    #[test]
    fn event_budget_keeps_the_panic_text() {
        // The run loop's livelock guard used to panic with exactly this
        // prefix and histogram rendering; the typed variant's Display
        // must keep the words so the infallible wrappers panic
        // unchanged.
        let e = PallasError::EventBudget {
            t: 12.5,
            histogram: vec![("StartStep", 3), ("Poll", 999_997)],
        };
        assert_eq!(
            e.to_string(),
            "event-budget exceeded (livelock?) at t=12.5: \
             [(\"StartStep\", 3), (\"Poll\", 999997)]"
        );
    }

    #[test]
    fn instance_lost_names_the_casualty() {
        let e = PallasError::InstanceLost {
            t: 5.5,
            agent: 2,
            instance: 7,
        };
        assert_eq!(
            e.to_string(),
            "instance 7 (agent 2) lost at t=5.5 (fail-fast recovery policy)"
        );
    }

    #[test]
    fn unknown_key_suggests_nearest() {
        let e = PallasError::unknown_key("scenarrio", "config", &["scenario", "seed", "steps"]);
        assert_eq!(
            e.to_string(),
            "unknown config key 'scenarrio' (did you mean 'scenario'?)"
        );
        // Nothing close → list the valid keys instead.
        let e = PallasError::unknown_key("xyzzy", "pipeline", &["micro_batch", "global_batch"]);
        let s = e.to_string();
        assert!(s.contains("valid: micro_batch, global_batch"), "{s}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("scenario", "scenario"), 0);
        assert_eq!(edit_distance("scenarrio", "scenario"), 1);
        assert_eq!(edit_distance("sceanrio", "scenario"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn checkpoint_rejection_names_path_and_reason() {
        let e = PallasError::Checkpoint {
            path: "ck.json".into(),
            reason: "checksum mismatch".into(),
        };
        assert_eq!(e.to_string(), "checkpoint ck.json: checksum mismatch");
        let e = PallasError::Checkpoint {
            path: String::new(),
            reason: "snapshot missing 'engine'".into(),
        };
        assert_eq!(e.to_string(), "checkpoint: snapshot missing 'engine'");
    }

    #[test]
    fn transport_and_protocol_rejections_are_pinned() {
        // Distributed-plane contract (DESIGN.md §14): link failures and
        // protocol violations are typed, and the dist-equivalence CI
        // job's kill-a-worker smoke greps these strings.
        let e = PallasError::Transport {
            endpoint: "worker 2 (socket)".into(),
            reason: "frame 3: checksum mismatch — corrupt or truncated".into(),
        };
        assert_eq!(
            e.to_string(),
            "transport worker 2 (socket): frame 3: checksum mismatch — corrupt or truncated"
        );
        let e = PallasError::Protocol {
            expected: "result for a claimed shard".into(),
            got: "result for step 4 slot 1 from worker 0".into(),
        };
        assert_eq!(
            e.to_string(),
            "protocol violation: expected result for a claimed shard, \
             got result for step 4 slot 1 from worker 0"
        );
    }

    #[test]
    fn admission_rejections_name_tenant_request_and_limit() {
        // Serving-plane contract: overload is typed and countable, and
        // these strings are byte-diffed by the serve-smoke CI job.
        let e = PallasError::Admission {
            tenant: "burst".into(),
            request: 41,
            reject: AdmissionReject::QueueFull,
            limit: 16,
        };
        assert_eq!(
            e.to_string(),
            "serve: request 41 (tenant 'burst') rejected: intake queue full (cap 16)"
        );
        let e = PallasError::Admission {
            tenant: "steady".into(),
            request: 7,
            reject: AdmissionReject::QuotaExceeded,
            limit: 4,
        };
        assert_eq!(
            e.to_string(),
            "serve: request 7 (tenant 'steady') rejected: \
             tenant quota 4 outstanding sessions reached"
        );
        assert!(matches!(
            e,
            PallasError::Admission {
                reject: AdmissionReject::QuotaExceeded,
                ..
            }
        ));
    }

    #[test]
    fn error_trait_object() {
        let e: Box<dyn std::error::Error> =
            Box::new(PallasError::InvalidConfig("no agents".into()));
        assert_eq!(e.to_string(), "no agents");
    }
}
