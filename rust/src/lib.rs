//! # FlexMARL
//!
//! Reproduction of *"Rollout-Training Co-Design for Efficient LLM-Based
//! Multi-Agent Reinforcement Learning"* (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's system contribution: joint
//!   orchestrator (experience store + micro-batch asynchronous pipeline),
//!   rollout engine (parallel sampling + hierarchical load balancing),
//!   training engine (agent-centric allocation + state swap), the Set/Get
//!   heterogeneous object store, baselines, a discrete-event cluster
//!   simulator for paper-scale experiments, a multi-tenant
//!   Rollout-as-a-Service serving plane ([`serve`], DESIGN.md §13),
//!   a distributed coordinator/worker plane over pluggable transports
//!   ([`dist`], DESIGN.md §14),
//!   and a PJRT runtime that executes the AOT-compiled policy models
//!   for the real end-to-end run.
//!
//! The engine's public API is the [`experiment::Experiment`] builder
//! over pluggable framework [`policy`] objects (DESIGN.md §8); every
//! fallible entry point reports a structured [`error::PallasError`].
//! Execution is streaming-first (DESIGN.md §9): an
//! [`orchestrator::Session`] steps the engine one MARL step at a time,
//! typed [`orchestrator::EngineEvent`]s flow to attached
//! [`orchestrator::EventSink`]s, and a sink can stop a run early with
//! a well-formed partial outcome.
//! * **L2 (python/compile/model.py)** — GRPO policy transformer, lowered
//!   once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas flash-attention and fused
//!   GRPO-loss kernels, validated against pure-jnp oracles.
//!
//! Python never runs on the request path: the Rust binary loads
//! `artifacts/*.hlo.txt` via PJRT and is self-contained.

pub mod baselines;
pub mod ckpt;
pub mod cluster;
pub mod config;
pub mod dist;
pub mod error;
pub mod exec;
pub mod experiment;
pub mod fault;
pub mod grpo;
pub mod memstore;
pub mod metrics;
pub mod orchestrator;
pub mod policy;
pub mod rollout;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod store;
pub mod training;
pub mod util;
pub mod workload;
pub mod xla_stub;
