//! Experience store (§4.2): the structured data plane between rollout
//! and training under the disaggregated architecture.
//!
//! Multi-table organization: each agent gets a dedicated table (enabling
//! heterogeneous policies/configs per agent, §4.3). Each table has three
//! column categories:
//!  * meta-information — `policy_version`, `sample_id`
//!    (`{input_id}_{number_of_turns}_{trajectory_id}`, globally unique,
//!    deterministically ordered, traceable), and a `processing` flag
//!    (read-but-not-yet-updated);
//!  * data columns — user-defined fields (prompt, response, rewards…);
//!  * status columns — one boolean per data column: fully generated?
//!
//! Type-aware hybrid storage: simple scalars (int/float/bool) are stored
//! by value in the table; complex payloads (strings, token lists,
//! tensors) are stored by reference — the table records only the location
//! key of a blob parked in the store's arena (standing in for the
//! Set/Get heterogeneous-object plane of §7).
//!
//! # Layout & hot-path invariants (see also rust/DESIGN.md §3)
//!
//! The store is on the per-call critical path of the micro-batch
//! pipeline, so tables are **columnar over a slot slab** rather than a
//! key-ordered row map:
//!
//! ```text
//!  index: FastMap<SampleKey, slot>         key → slot lookup, O(1)
//!  keys/processing/missing/occupied: Vec   one entry per slot
//!  cols[c].data: contiguous typed Vec      one column per schema field
//!  cols[c].set:  Vec<bool>                 the paired status column
//!  free: Vec<slot>                         slot free-list (slab reuse)
//!  ready: BTreeSet<SampleKey>             dispatch-ready rows, key order
//!  ready_by_version: BTreeMap<u64,usize>   O(log V) ready counts
//! ```
//!
//! Invariants maintained by every mutation (checked by the scan-path
//! property tests):
//!  * `ready` contains exactly the keys of occupied rows with
//!    `missing == 0 && !processing` — it is updated **on status-column
//!    writes**, never by scanning;
//!  * `ready_by_version[v]` equals the number of ready keys with
//!    version `v`; entries are removed when they reach zero;
//!  * dispatch order is ascending `(version, sample_id)` — identical to
//!    the old `BTreeMap` scan path;
//!  * a slot on the free-list has been removed from `index` and `ready`.
//!
//! Locking discipline (deadlock-free by construction):
//!  1. the table-map `RwLock` is only held to clone a table's `Arc`;
//!  2. each table is an independent `Mutex` shard — producers for agent
//!     A never contend with consumers of agent B;
//!  3. blob-arena shard locks are never taken while a table lock is
//!     held; blobs are parked **before** the referencing status column
//!     is set, so a ready row's blob refs always resolve.

use crate::ckpt::{as_ji64, as_ju64, ji64, ju64};
use crate::util::hash::FastMap;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

// ---------------------------------------------------------------------------
// Sample identity
// ---------------------------------------------------------------------------

/// `sample_id = {input_id}_{number_of_turns}_{trajectory_id}` (§4.2).
/// Ordering is lexicographic on the numeric triple → deterministic
/// dispatch order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SampleId {
    pub input_id: u64,
    pub turns: u32,
    pub trajectory_id: u64,
}

impl SampleId {
    pub const MIN: SampleId = SampleId {
        input_id: 0,
        turns: 0,
        trajectory_id: 0,
    };
    pub const MAX: SampleId = SampleId {
        input_id: u64::MAX,
        turns: u32::MAX,
        trajectory_id: u64::MAX,
    };

    pub fn new(input_id: u64, turns: u32, trajectory_id: u64) -> Self {
        SampleId {
            input_id,
            turns,
            trajectory_id,
        }
    }
}

impl fmt::Display for SampleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}_{}_{}", self.input_id, self.turns, self.trajectory_id)
    }
}

/// Combined with `policy_version`, the identifier is globally unique
/// across asynchronous retries of the same trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SampleKey {
    pub version: u64,
    pub id: SampleId,
}

// ---------------------------------------------------------------------------
// Hybrid value model
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnType {
    Int,
    Float,
    Bool,
    /// Complex payload — stored by reference.
    Blob,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    /// Location key into the blob arena.
    Ref(u64),
}

impl Value {
    pub fn column_type(&self) -> ColumnType {
        match self {
            Value::Int(_) => ColumnType::Int,
            Value::Float(_) => ColumnType::Float,
            Value::Bool(_) => ColumnType::Bool,
            Value::Ref(_) => ColumnType::Blob,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// Opaque complex payloads (token sequences, logprob rows, tensors).
#[derive(Debug, Clone, PartialEq)]
pub enum Blob {
    Tokens(Vec<i32>),
    Floats(Vec<f32>),
    Text(String),
}

/// One field of a batched [`ExperienceStore::put_rows`] write: either a
/// scalar stored by value or a payload parked in the blob arena.
#[derive(Debug, Clone)]
pub enum Field {
    Value(Value),
    Blob(Blob),
}

/// One row of a batched write (all fields set under a single table-lock
/// acquisition — the micro-batch producer path).
#[derive(Debug, Clone)]
pub struct PutRow<'a> {
    pub version: u64,
    pub id: SampleId,
    pub fields: Vec<(&'a str, Field)>,
}

// ---------------------------------------------------------------------------
// Columnar table
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum ColData {
    Int(Vec<i64>),
    Float(Vec<f64>),
    Bool(Vec<bool>),
    /// Blob location keys.
    Blob(Vec<u64>),
}

impl ColData {
    fn new(ty: ColumnType) -> ColData {
        match ty {
            ColumnType::Int => ColData::Int(Vec::new()),
            ColumnType::Float => ColData::Float(Vec::new()),
            ColumnType::Bool => ColData::Bool(Vec::new()),
            ColumnType::Blob => ColData::Blob(Vec::new()),
        }
    }

    fn push_default(&mut self) {
        match self {
            ColData::Int(v) => v.push(0),
            ColData::Float(v) => v.push(0.0),
            ColData::Bool(v) => v.push(false),
            ColData::Blob(v) => v.push(0),
        }
    }

    fn write(&mut self, slot: usize, value: &Value) {
        match (self, value) {
            (ColData::Int(v), Value::Int(x)) => v[slot] = *x,
            (ColData::Float(v), Value::Float(x)) => v[slot] = *x,
            (ColData::Bool(v), Value::Bool(x)) => v[slot] = *x,
            (ColData::Blob(v), Value::Ref(x)) => v[slot] = *x,
            _ => unreachable!("type checked before write"),
        }
    }

    fn read(&self, slot: usize) -> Value {
        match self {
            ColData::Int(v) => Value::Int(v[slot]),
            ColData::Float(v) => Value::Float(v[slot]),
            ColData::Bool(v) => Value::Bool(v[slot]),
            ColData::Blob(v) => Value::Ref(v[slot]),
        }
    }
}

#[derive(Debug)]
struct Column {
    data: ColData,
    /// The paired status column: value fully generated?
    set: Vec<bool>,
}

/// One agent's table: a columnar slot slab plus the ready-set index.
#[derive(Debug)]
struct Table {
    schema: Vec<(String, ColumnType)>,
    cols: Vec<Column>,
    /// Per-slot row metadata.
    keys: Vec<SampleKey>,
    processing: Vec<bool>,
    /// Status columns still unset for this row.
    missing: Vec<u32>,
    occupied: Vec<bool>,
    /// Slot free-list (slab reuse; steady state allocates nothing).
    free: Vec<u32>,
    /// key → slot.
    index: FastMap<SampleKey, u32>,
    /// Dispatch-ready rows in deterministic (version, id) order.
    ready: BTreeSet<SampleKey>,
    ready_by_version: BTreeMap<u64, usize>,
    live_rows: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    NoSuchTable(String),
    NoSuchColumn(String),
    TypeMismatch { column: String, expected: ColumnType },
    DuplicateSample(SampleKey),
    UnknownSample(SampleKey),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::NoSuchTable(a) => write!(f, "no table for agent {a}"),
            StoreError::NoSuchColumn(c) => write!(f, "no column {c}"),
            StoreError::TypeMismatch { column, expected } => {
                write!(f, "column {column} expects {expected:?}")
            }
            StoreError::DuplicateSample(k) => write!(f, "duplicate sample {} v{}", k.id, k.version),
            StoreError::UnknownSample(k) => write!(f, "unknown sample {} v{}", k.id, k.version),
        }
    }
}

impl std::error::Error for StoreError {}

impl Table {
    fn new(schema: Vec<(String, ColumnType)>) -> Table {
        let cols = schema
            .iter()
            .map(|&(_, ty)| Column {
                data: ColData::new(ty),
                set: Vec::new(),
            })
            .collect();
        Table {
            schema,
            cols,
            keys: Vec::new(),
            processing: Vec::new(),
            missing: Vec::new(),
            occupied: Vec::new(),
            free: Vec::new(),
            index: FastMap::default(),
            ready: BTreeSet::new(),
            ready_by_version: BTreeMap::new(),
            live_rows: 0,
        }
    }

    fn col(&self, name: &str) -> Result<usize, StoreError> {
        self.schema
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| StoreError::NoSuchColumn(name.to_string()))
    }

    fn mark_ready(&mut self, key: SampleKey) {
        if self.ready.insert(key) {
            *self.ready_by_version.entry(key.version).or_insert(0) += 1;
        }
    }

    fn unmark_ready(&mut self, key: SampleKey) {
        if self.ready.remove(&key) {
            let c = self
                .ready_by_version
                .get_mut(&key.version)
                .expect("ready count out of sync");
            *c -= 1;
            if *c == 0 {
                self.ready_by_version.remove(&key.version);
            }
        }
    }

    fn insert(&mut self, key: SampleKey) -> Result<(), StoreError> {
        if self.index.contains_key(&key) {
            return Err(StoreError::DuplicateSample(key));
        }
        let slot = match self.free.pop() {
            Some(s) => {
                let s = s as usize;
                self.keys[s] = key;
                self.processing[s] = false;
                self.occupied[s] = true;
                self.missing[s] = self.cols.len() as u32;
                for c in &mut self.cols {
                    c.set[s] = false;
                }
                s
            }
            None => {
                let s = self.keys.len();
                self.keys.push(key);
                self.processing.push(false);
                self.occupied.push(true);
                self.missing.push(self.cols.len() as u32);
                for c in &mut self.cols {
                    c.set.push(false);
                    c.data.push_default();
                }
                s
            }
        };
        self.index.insert(key, slot as u32);
        self.live_rows += 1;
        if self.cols.is_empty() {
            self.mark_ready(key); // degenerate meta-only schema
        }
        Ok(())
    }

    fn set(&mut self, key: SampleKey, column: &str, value: &Value) -> Result<(), StoreError> {
        let ci = self.col(column)?;
        let expected = self.schema[ci].1;
        if value.column_type() != expected {
            return Err(StoreError::TypeMismatch {
                column: column.to_string(),
                expected,
            });
        }
        let slot = *self
            .index
            .get(&key)
            .ok_or(StoreError::UnknownSample(key))? as usize;
        self.cols[ci].data.write(slot, value);
        if !self.cols[ci].set[slot] {
            self.cols[ci].set[slot] = true;
            self.missing[slot] -= 1;
            if self.missing[slot] == 0 && !self.processing[slot] {
                self.mark_ready(key);
            }
        }
        Ok(())
    }

    /// Ready keys in dispatch order, optionally restricted to a version.
    fn ready_range(&self, version: Option<u64>, limit: usize) -> Vec<SampleKey> {
        match version {
            None => self.ready.iter().take(limit).copied().collect(),
            Some(v) => {
                let lo = SampleKey {
                    version: v,
                    id: SampleId::MIN,
                };
                let hi = SampleKey {
                    version: v,
                    id: SampleId::MAX,
                };
                self.ready.range(lo..=hi).take(limit).copied().collect()
            }
        }
    }

    fn count_ready(&self, version: Option<u64>) -> usize {
        match version {
            None => self.ready.len(),
            Some(v) => self.ready_by_version.get(&v).copied().unwrap_or(0),
        }
    }

    fn sample(&self, slot: usize, key: SampleKey) -> FetchedSample {
        let values = self
            .schema
            .iter()
            .enumerate()
            .map(|(ci, (n, _))| (n.clone(), self.cols[ci].data.read(slot)))
            .collect();
        FetchedSample {
            key,
            values,
            blobs: Vec::new(),
        }
    }

    /// Dispatch up to `limit` ready samples, marking them `processing`.
    fn fetch(&mut self, version: Option<u64>, limit: usize) -> Vec<FetchedSample> {
        let keys = self.ready_range(version, limit);
        let mut out = Vec::with_capacity(keys.len());
        for key in keys {
            let slot = self.index[&key] as usize;
            self.processing[slot] = true;
            self.unmark_ready(key);
            out.push(self.sample(slot, key));
        }
        out
    }

    /// Blob location keys referenced by a row's set blob columns,
    /// tagged with the column index.
    fn blob_refs(&self, slot: usize) -> Vec<(usize, u64)> {
        let mut out = Vec::new();
        for (ci, c) in self.cols.iter().enumerate() {
            if c.set[slot] {
                if let ColData::Blob(v) = &c.data {
                    out.push((ci, v[slot]));
                }
            }
        }
        out
    }

    /// Return a (still-indexed-out) row's slot to the free-list.
    fn free_row(&mut self, key: SampleKey, slot: usize) {
        self.unmark_ready(key);
        self.occupied[slot] = false;
        self.free.push(slot as u32);
        self.live_rows -= 1;
    }

    /// Remove a row, returning its blob location keys for arena cleanup.
    fn remove_row(&mut self, key: SampleKey) -> Result<Vec<u64>, StoreError> {
        let slot = self
            .index
            .remove(&key)
            .ok_or(StoreError::UnknownSample(key))? as usize;
        let refs = self.blob_refs(slot);
        self.free_row(key, slot);
        Ok(refs.into_iter().map(|(_, k)| k).collect())
    }

    /// Fused fetch+consume: dispatch and remove in one pass. Returns the
    /// samples plus each row's (column, blob key) refs for the caller to
    /// resolve against the arena.
    #[allow(clippy::type_complexity)]
    fn take(
        &mut self,
        version: Option<u64>,
        limit: usize,
    ) -> Vec<(FetchedSample, Vec<(usize, u64)>)> {
        let keys = self.ready_range(version, limit);
        let mut out = Vec::with_capacity(keys.len());
        for key in keys {
            let slot = self.index.remove(&key).expect("ready key indexed") as usize;
            let sample = self.sample(slot, key);
            let refs = self.blob_refs(slot);
            self.free_row(key, slot);
            out.push((sample, refs));
        }
        out
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: the slot slab verbatim — keys (including
    /// stale entries on freed slots; they are never read but keeping
    /// the slab byte-exact keeps future slot assignment identical),
    /// flags, free-list order, and every typed column with its status
    /// column. `index`/`ready`/`ready_by_version` are derived views and
    /// are rebuilt at restore.
    fn snapshot(&self) -> Json {
        let bools = |v: &[bool]| Json::arr(v.iter().map(|&b| Json::Bool(b)));
        Json::obj(vec![
            (
                "keys",
                Json::arr(self.keys.iter().map(|k| {
                    Json::arr([
                        ju64(k.version),
                        ju64(k.id.input_id),
                        Json::num(k.id.turns as f64),
                        ju64(k.id.trajectory_id),
                    ])
                })),
            ),
            ("processing", bools(&self.processing)),
            (
                "missing",
                Json::arr(self.missing.iter().map(|&m| Json::num(m as f64))),
            ),
            ("occupied", bools(&self.occupied)),
            (
                "free",
                Json::arr(self.free.iter().map(|&s| Json::num(s as f64))),
            ),
            (
                "cols",
                Json::arr(self.cols.iter().map(|c| {
                    let data = match &c.data {
                        ColData::Int(v) => Json::arr(v.iter().map(|&x| ji64(x))),
                        ColData::Float(v) => Json::arr(v.iter().map(|&x| Json::num(x))),
                        ColData::Bool(v) => bools(v),
                        ColData::Blob(v) => Json::arr(v.iter().map(|&x| ju64(x))),
                    };
                    Json::obj(vec![("data", data), ("set", bools(&c.set))])
                })),
            ),
        ])
    }

    /// Rebuild a table from [`Table::snapshot`] given its schema (the
    /// schema itself is config-derived and comes from the engine's
    /// `create_table` calls at restore).
    fn restore(schema: Vec<(String, ColumnType)>, j: &Json) -> Result<Table, String> {
        fn bools(j: Option<&Json>, what: &str) -> Result<Vec<bool>, String> {
            j.and_then(Json::as_arr)
                .ok_or(format!("table missing '{what}'"))?
                .iter()
                .map(|b| b.as_bool().ok_or(format!("bad '{what}' entry")))
                .collect()
        }
        let keys = j
            .get("keys")
            .and_then(Json::as_arr)
            .ok_or("table missing 'keys'")?
            .iter()
            .map(|k| {
                let k = k.as_arr().filter(|k| k.len() == 4).ok_or("bad sample key")?;
                Ok::<SampleKey, String>(SampleKey {
                    version: as_ju64(&k[0]).ok_or("bad key version")?,
                    id: SampleId {
                        input_id: as_ju64(&k[1]).ok_or("bad key input_id")?,
                        turns: k[2].as_u64().ok_or("bad key turns")? as u32,
                        trajectory_id: as_ju64(&k[3]).ok_or("bad key trajectory_id")?,
                    },
                })
            })
            .collect::<Result<Vec<_>, _>>()?;
        let n_slots = keys.len();
        let processing = bools(j.get("processing"), "processing")?;
        let occupied = bools(j.get("occupied"), "occupied")?;
        let missing = j
            .get("missing")
            .and_then(Json::as_arr)
            .ok_or("table missing 'missing'")?
            .iter()
            .map(|m| m.as_u64().map(|m| m as u32).ok_or("bad 'missing' entry"))
            .collect::<Result<Vec<_>, _>>()?;
        let free = j
            .get("free")
            .and_then(Json::as_arr)
            .ok_or("table missing 'free'")?
            .iter()
            .map(|s| s.as_u64().map(|s| s as u32).ok_or("bad free-list entry"))
            .collect::<Result<Vec<_>, _>>()?;
        if processing.len() != n_slots || occupied.len() != n_slots || missing.len() != n_slots {
            return Err("table slab column lengths disagree".to_string());
        }
        let cols_j = j
            .get("cols")
            .and_then(Json::as_arr)
            .ok_or("table missing 'cols'")?;
        if cols_j.len() != schema.len() {
            return Err(format!(
                "table has {} columns, checkpoint has {}",
                schema.len(),
                cols_j.len()
            ));
        }
        let mut cols = Vec::with_capacity(cols_j.len());
        for (cj, &(ref name, ty)) in cols_j.iter().zip(&schema) {
            let dj = cj
                .get("data")
                .and_then(Json::as_arr)
                .ok_or(format!("column '{name}' missing 'data'"))?;
            if dj.len() != n_slots {
                return Err(format!("column '{name}' length != slab size"));
            }
            let data = match ty {
                ColumnType::Int => ColData::Int(
                    dj.iter()
                        .map(|x| as_ji64(x).ok_or("bad int cell"))
                        .collect::<Result<_, _>>()?,
                ),
                ColumnType::Float => ColData::Float(
                    dj.iter()
                        .map(|x| x.as_f64().ok_or("bad float cell"))
                        .collect::<Result<_, _>>()?,
                ),
                ColumnType::Bool => ColData::Bool(
                    dj.iter()
                        .map(|x| x.as_bool().ok_or("bad bool cell"))
                        .collect::<Result<_, _>>()?,
                ),
                ColumnType::Blob => ColData::Blob(
                    dj.iter()
                        .map(|x| as_ju64(x).ok_or("bad blob ref cell"))
                        .collect::<Result<_, _>>()?,
                ),
            };
            let set = bools(cj.get("set"), "set")?;
            if set.len() != n_slots {
                return Err(format!("column '{name}' status length != slab size"));
            }
            cols.push(Column { data, set });
        }
        let mut t = Table {
            schema,
            cols,
            keys,
            processing,
            missing,
            occupied,
            free,
            index: FastMap::default(),
            ready: BTreeSet::new(),
            ready_by_version: BTreeMap::new(),
            live_rows: 0,
        };
        // Derived views: index over occupied slots; the ready set is
        // exactly "occupied && complete && not processing" (the
        // documented invariant the property tests pin).
        for s in 0..t.keys.len() {
            if !t.occupied[s] {
                continue;
            }
            let key = t.keys[s];
            if t.index.insert(key, s as u32).is_some() {
                return Err(format!("duplicate sample key {} v{}", key.id, key.version));
            }
            t.live_rows += 1;
            if t.missing[s] == 0 && !t.processing[s] {
                t.mark_ready(key);
            }
        }
        Ok(t)
    }

    /// The pre-columnar reference path: recompute the ready set by a
    /// full slab scan. Only used by diagnostics and the property tests
    /// that pin the ready-set index to identical dispatch behaviour.
    fn scan_ready(&self, version: Option<u64>) -> Vec<SampleKey> {
        let mut out: Vec<SampleKey> = (0..self.keys.len())
            .filter(|&s| {
                self.occupied[s]
                    && !self.processing[s]
                    && self.missing[s] == 0
                    && version.map(|v| self.keys[s].version == v).unwrap_or(true)
            })
            .map(|s| self.keys[s])
            .collect();
        out.sort_unstable();
        out
    }
}

// ---------------------------------------------------------------------------
// Store
// ---------------------------------------------------------------------------

/// A sample handed to the training engine.
#[derive(Debug, Clone)]
pub struct FetchedSample {
    pub key: SampleKey,
    pub values: Vec<(String, Value)>,
    /// Blob payloads resolved inline by [`ExperienceStore::take_batch`]
    /// (empty for plain `fetch_ready`, where payloads stay in the arena
    /// until `complete`).
    pub blobs: Vec<(String, Blob)>,
}

impl FetchedSample {
    pub fn value(&self, column: &str) -> Option<&Value> {
        self.values
            .iter()
            .find(|(n, _)| n == column)
            .map(|(_, v)| v)
    }

    pub fn blob(&self, column: &str) -> Option<&Blob> {
        self.blobs
            .iter()
            .find(|(n, _)| n == column)
            .map(|(_, b)| b)
    }
}

const BLOB_SHARDS: usize = 16;

/// The experience store: thread-safe (rollout workers produce, trainer
/// process groups consume), deterministic dispatch order. Tables are
/// independent lock shards; the blob arena is sharded by key.
pub struct ExperienceStore {
    tables: RwLock<BTreeMap<String, Arc<Mutex<Table>>>>,
    blobs: Vec<Mutex<FastMap<u64, Blob>>>,
    next_blob: AtomicU64,
}

impl Default for ExperienceStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ExperienceStore {
    pub fn new() -> Self {
        ExperienceStore {
            tables: RwLock::new(BTreeMap::new()),
            blobs: (0..BLOB_SHARDS).map(|_| Mutex::new(FastMap::default())).collect(),
            next_blob: AtomicU64::new(1),
        }
    }

    fn table(&self, agent: &str) -> Result<Arc<Mutex<Table>>, StoreError> {
        self.tables
            .read()
            .unwrap()
            .get(agent)
            .cloned()
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))
    }

    fn blob_shard(&self, key: u64) -> &Mutex<FastMap<u64, Blob>> {
        &self.blobs[key as usize & (BLOB_SHARDS - 1)]
    }

    /// Create (or replace) an agent's table with the given data columns.
    pub fn create_table(&self, agent: &str, schema: &[(&str, ColumnType)]) {
        let schema = schema.iter().map(|(n, t)| (n.to_string(), *t)).collect();
        self.tables
            .write()
            .unwrap()
            .insert(agent.to_string(), Arc::new(Mutex::new(Table::new(schema))));
    }

    pub fn agents(&self) -> Vec<String> {
        self.tables.read().unwrap().keys().cloned().collect()
    }

    /// Register a new sample row (meta columns only).
    pub fn insert(&self, agent: &str, version: u64, id: SampleId) -> Result<(), StoreError> {
        let t = self.table(agent)?;
        let mut t = t.lock().unwrap();
        t.insert(SampleKey { version, id })
    }

    /// Write a scalar field; marks its status column generated.
    pub fn set_value(
        &self,
        agent: &str,
        version: u64,
        id: SampleId,
        column: &str,
        value: Value,
    ) -> Result<(), StoreError> {
        let t = self.table(agent)?;
        let mut t = t.lock().unwrap();
        t.set(SampleKey { version, id }, column, &value)
    }

    /// Write a complex payload: parks the blob, stores the reference
    /// (type-aware hybrid storage). The blob is parked *before* the
    /// status column flips so a concurrent consumer that sees the row
    /// become ready can always resolve the reference.
    pub fn set_blob(
        &self,
        agent: &str,
        version: u64,
        id: SampleId,
        column: &str,
        blob: Blob,
    ) -> Result<u64, StoreError> {
        let t = self.table(agent)?;
        let blob_key = self.next_blob.fetch_add(1, Ordering::Relaxed);
        self.blob_shard(blob_key).lock().unwrap().insert(blob_key, blob);
        let res = {
            let mut t = t.lock().unwrap();
            t.set(SampleKey { version, id }, column, &Value::Ref(blob_key))
        };
        match res {
            Ok(()) => Ok(blob_key),
            Err(e) => {
                self.blob_shard(blob_key).lock().unwrap().remove(&blob_key);
                Err(e)
            }
        }
    }

    /// Batched producer write: insert `rows` and set all their fields
    /// under a single table-lock acquisition (the micro-batch pipeline's
    /// group-completion path). Blobs are parked in the arena first.
    ///
    /// On error, everything up to the failing field remains applied
    /// (same per-call semantics as the unbatched API — the failing row
    /// may remain inserted with its earlier fields set); parked blobs
    /// whose references never reached a column are released.
    pub fn put_rows(&self, agent: &str, rows: Vec<PutRow<'_>>) -> Result<(), StoreError> {
        let table = self.table(agent)?;
        // Park blobs first (see `set_blob`), remembering (row, field)
        // so an error can release exactly the blobs whose refs never
        // reached a column.
        let mut parked: Vec<(usize, usize, u64)> = Vec::new();
        let mut converted: Vec<(SampleKey, Vec<(&str, Value)>)> = Vec::with_capacity(rows.len());
        for (ri, row) in rows.into_iter().enumerate() {
            let key = SampleKey {
                version: row.version,
                id: row.id,
            };
            let mut vals = Vec::with_capacity(row.fields.len());
            for (fi, (name, field)) in row.fields.into_iter().enumerate() {
                match field {
                    Field::Value(v) => vals.push((name, v)),
                    Field::Blob(b) => {
                        let k = self.next_blob.fetch_add(1, Ordering::Relaxed);
                        self.blob_shard(k).lock().unwrap().insert(k, b);
                        parked.push((ri, fi, k));
                        vals.push((name, Value::Ref(k)));
                    }
                }
            }
            converted.push((key, vals));
        }
        // On failure, (row, field) of the first field that did NOT
        // apply — every parked blob at or after it is unreferenced.
        let mut failed: Option<(usize, usize, StoreError)> = None;
        {
            let mut t = table.lock().unwrap();
            'rows: for (ri, (key, vals)) in converted.iter().enumerate() {
                if let Err(e) = t.insert(*key) {
                    failed = Some((ri, 0, e));
                    break 'rows;
                }
                for (fi, (name, v)) in vals.iter().enumerate() {
                    if let Err(e) = t.set(*key, name, v) {
                        failed = Some((ri, fi, e));
                        break 'rows;
                    }
                }
            }
        }
        if let Some((ri, fi, e)) = failed {
            for &(bri, bfi, k) in &parked {
                if (bri, bfi) >= (ri, fi) {
                    self.blob_shard(k).lock().unwrap().remove(&k);
                }
            }
            return Err(e);
        }
        Ok(())
    }

    pub fn blob(&self, key: u64) -> Option<Blob> {
        self.blob_shard(key).lock().unwrap().get(&key).cloned()
    }

    /// Number of fully-generated, not-yet-dispatched samples — the
    /// micro-batch trigger input (§4.3). O(1)/O(log V) off the ready
    /// index; never scans.
    pub fn count_ready(&self, agent: &str, version: Option<u64>) -> usize {
        match self.table(agent) {
            Ok(t) => t.lock().unwrap().count_ready(version),
            Err(_) => 0,
        }
    }

    /// Dispatch up to `limit` ready samples (deterministic order: version,
    /// then sample id), marking them `processing` so concurrent fetches
    /// never double-dispatch. `version` filters to one policy snapshot —
    /// the consistency guarantee that keeps training on-policy.
    pub fn fetch_ready(
        &self,
        agent: &str,
        version: Option<u64>,
        limit: usize,
    ) -> Vec<FetchedSample> {
        match self.table(agent) {
            Ok(t) => t.lock().unwrap().fetch(version, limit),
            Err(_) => Vec::new(),
        }
    }

    /// Fused dispatch+consume for pipelines that never requeue a
    /// micro-batch (one table-lock acquisition instead of
    /// `fetch_ready` + `complete`). Rows are removed; blob payloads are
    /// pulled from the arena and returned inline on each sample.
    pub fn take_batch(
        &self,
        agent: &str,
        version: Option<u64>,
        limit: usize,
    ) -> Vec<FetchedSample> {
        let Ok(table) = self.table(agent) else {
            return Vec::new();
        };
        let taken = table.lock().unwrap().take(version, limit);
        let mut out = Vec::with_capacity(taken.len());
        for (mut sample, refs) in taken {
            for (ci, bkey) in refs {
                if let Some(b) = self.blob_shard(bkey).lock().unwrap().remove(&bkey) {
                    sample.blobs.push((sample.values[ci].0.clone(), b));
                }
            }
            out.push(sample);
        }
        out
    }

    /// Consume dispatched samples after their gradient is computed
    /// (removes rows and their blobs).
    pub fn complete(&self, agent: &str, keys: &[SampleKey]) -> Result<(), StoreError> {
        let table = self.table(agent)?;
        let mut blob_keys = Vec::new();
        let mut failed = None;
        {
            let mut t = table.lock().unwrap();
            for k in keys {
                match t.remove_row(*k) {
                    Ok(mut bs) => blob_keys.append(&mut bs),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
        }
        for b in blob_keys {
            self.blob_shard(b).lock().unwrap().remove(&b);
        }
        match failed {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Fault tolerance: a trainer died — return its samples to the pool.
    pub fn requeue(&self, agent: &str, keys: &[SampleKey]) -> Result<(), StoreError> {
        let table = self.table(agent)?;
        let mut t = table.lock().unwrap();
        for k in keys {
            let slot = *t.index.get(k).ok_or(StoreError::UnknownSample(*k))? as usize;
            t.processing[slot] = false;
            if t.missing[slot] == 0 {
                t.mark_ready(*k);
            }
        }
        Ok(())
    }

    /// Drop all rows belonging to policy versions older than `min_version`
    /// (stale data from cancelled asynchronous rollouts). Their blobs are
    /// released from the arena as well.
    pub fn evict_stale(&self, agent: &str, min_version: u64) -> usize {
        let Ok(table) = self.table(agent) else {
            return 0;
        };
        let mut blob_keys = Vec::new();
        let n = {
            let mut t = table.lock().unwrap();
            let mut stale: Vec<SampleKey> = t
                .index
                .keys()
                .filter(|k| k.version < min_version)
                .copied()
                .collect();
            stale.sort_unstable();
            for k in &stale {
                if let Ok(mut bs) = t.remove_row(*k) {
                    blob_keys.append(&mut bs);
                }
            }
            stale.len()
        };
        for b in blob_keys {
            self.blob_shard(b).lock().unwrap().remove(&b);
        }
        n
    }

    /// Ready keys in dispatch order from the maintained index (read-only
    /// diagnostic / verification aid).
    pub fn ready_keys(&self, agent: &str, version: Option<u64>) -> Vec<SampleKey> {
        match self.table(agent) {
            Ok(t) => t.lock().unwrap().ready_range(version, usize::MAX),
            Err(_) => Vec::new(),
        }
    }

    /// Ready keys recomputed by the pre-columnar full-scan path. The
    /// property tests assert this always matches [`Self::ready_keys`];
    /// production code must never need it.
    pub fn scan_ready_keys(&self, agent: &str, version: Option<u64>) -> Vec<SampleKey> {
        match self.table(agent) {
            Ok(t) => t.lock().unwrap().scan_ready(version),
            Err(_) => Vec::new(),
        }
    }

    pub fn total_rows(&self) -> usize {
        self.tables
            .read()
            .unwrap()
            .values()
            .map(|t| t.lock().unwrap().live_rows)
            .sum()
    }

    pub fn total_blobs(&self) -> usize {
        self.blobs.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: every table slab, the blob arena (keyed
    /// payloads, sorted by key for stable output), and the arena's id
    /// counter. Shard assignment is a pure function of the key, so
    /// shards are not part of the format.
    pub fn snapshot(&self) -> Json {
        let tables = self.tables.read().unwrap();
        let mut blob_map: BTreeMap<String, Json> = BTreeMap::new();
        for shard in &self.blobs {
            for (&k, b) in shard.lock().unwrap().iter() {
                let (ty, v) = match b {
                    Blob::Tokens(t) => (
                        "tokens",
                        Json::arr(t.iter().map(|&x| Json::num(x as f64))),
                    ),
                    Blob::Floats(f) => (
                        "floats",
                        Json::arr(f.iter().map(|&x| Json::num(x as f64))),
                    ),
                    Blob::Text(s) => ("text", Json::str(s.clone())),
                };
                blob_map.insert(
                    k.to_string(),
                    Json::obj(vec![("ty", Json::str(ty)), ("v", v)]),
                );
            }
        }
        Json::obj(vec![
            (
                "tables",
                Json::Obj(
                    tables
                        .iter()
                        .map(|(name, t)| (name.clone(), t.lock().unwrap().snapshot()))
                        .collect(),
                ),
            ),
            ("blobs", Json::Obj(blob_map)),
            ("next_blob", ju64(self.next_blob.load(Ordering::SeqCst))),
        ])
    }

    /// Restore an [`ExperienceStore::snapshot`] into a store whose
    /// tables were already created (by engine construction) with the
    /// same names and schemas. The checkpoint's table set must match
    /// exactly — a mismatch means it came from a different config.
    pub fn restore_from(&self, j: &Json) -> Result<(), String> {
        let tj = j
            .get("tables")
            .and_then(Json::as_obj)
            .ok_or("store missing 'tables'")?;
        {
            let mut tables = self.tables.write().unwrap();
            if tables.len() != tj.len() || !tables.keys().all(|k| tj.contains_key(k)) {
                return Err(format!(
                    "store has tables [{}], checkpoint has [{}]",
                    tables.keys().cloned().collect::<Vec<_>>().join(", "),
                    tj.keys().cloned().collect::<Vec<_>>().join(", ")
                ));
            }
            for (name, snap) in tj {
                let slot = tables.get_mut(name).expect("checked above");
                let schema = slot.lock().unwrap().schema.clone();
                let restored = Table::restore(schema, snap)
                    .map_err(|e| format!("table '{name}': {e}"))?;
                *slot = Arc::new(Mutex::new(restored));
            }
        }
        let bj = j
            .get("blobs")
            .and_then(Json::as_obj)
            .ok_or("store missing 'blobs'")?;
        for shard in &self.blobs {
            shard.lock().unwrap().clear();
        }
        for (ks, bv) in bj {
            let k: u64 = ks.parse().map_err(|_| format!("bad blob key '{ks}'"))?;
            let v = bv.get("v").ok_or("blob missing 'v'")?;
            let blob = match bv.get("ty").and_then(Json::as_str) {
                Some("tokens") => Blob::Tokens(
                    v.as_arr()
                        .ok_or("bad tokens blob")?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as i32).ok_or("bad token"))
                        .collect::<Result<_, _>>()?,
                ),
                Some("floats") => Blob::Floats(
                    v.as_arr()
                        .ok_or("bad floats blob")?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as f32).ok_or("bad float"))
                        .collect::<Result<_, _>>()?,
                ),
                Some("text") => Blob::Text(v.as_str().ok_or("bad text blob")?.to_string()),
                other => return Err(format!("unknown blob type {other:?}")),
            };
            self.blob_shard(k).lock().unwrap().insert(k, blob);
        }
        let next = j
            .get("next_blob")
            .and_then(as_ju64)
            .ok_or("store missing 'next_blob'")?;
        self.next_blob.store(next, Ordering::SeqCst);
        Ok(())
    }
}

/// The standard GRPO sample schema used by the orchestrator.
pub fn grpo_schema() -> Vec<(&'static str, ColumnType)> {
    vec![
        ("prompt", ColumnType::Blob),
        ("response", ColumnType::Blob),
        ("old_logp", ColumnType::Blob),
        ("reward", ColumnType::Float),
        ("advantage", ColumnType::Float),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn store_with(agent: &str) -> ExperienceStore {
        let s = ExperienceStore::new();
        s.create_table(agent, &grpo_schema());
        s
    }

    fn fill(s: &ExperienceStore, agent: &str, v: u64, id: SampleId) {
        s.insert(agent, v, id).unwrap();
        s.set_blob(agent, v, id, "prompt", Blob::Tokens(vec![1, 2])).unwrap();
        s.set_blob(agent, v, id, "response", Blob::Tokens(vec![3])).unwrap();
        s.set_blob(agent, v, id, "old_logp", Blob::Floats(vec![-0.5])).unwrap();
        s.set_value(agent, v, id, "reward", Value::Float(0.7)).unwrap();
        s.set_value(agent, v, id, "advantage", Value::Float(0.1)).unwrap();
    }

    #[test]
    fn sample_id_format_and_order() {
        let id = SampleId::new(12, 3, 7);
        assert_eq!(id.to_string(), "12_3_7");
        let a = SampleId::new(1, 1, 1);
        let b = SampleId::new(1, 2, 0);
        let c = SampleId::new(2, 0, 0);
        assert!(a < b && b < c);
    }

    #[test]
    fn partial_rows_are_not_ready() {
        let s = store_with("a");
        let id = SampleId::new(0, 1, 0);
        s.insert("a", 1, id).unwrap();
        assert_eq!(s.count_ready("a", None), 0);
        s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1])).unwrap();
        s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2])).unwrap();
        s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-1.0])).unwrap();
        s.set_value("a", 1, id, "reward", Value::Float(1.0)).unwrap();
        assert_eq!(s.count_ready("a", None), 0); // advantage still missing
        s.set_value("a", 1, id, "advantage", Value::Float(0.5)).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
    }

    #[test]
    fn fetch_marks_processing_no_double_dispatch() {
        let s = store_with("a");
        for i in 0..5 {
            fill(&s, "a", 1, SampleId::new(i, 1, 0));
        }
        let first = s.fetch_ready("a", Some(1), 3);
        assert_eq!(first.len(), 3);
        let second = s.fetch_ready("a", Some(1), 10);
        assert_eq!(second.len(), 2); // only the remaining two
        let third = s.fetch_ready("a", Some(1), 10);
        assert!(third.is_empty());
    }

    #[test]
    fn version_filtering_keeps_on_policy() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        fill(&s, "a", 2, SampleId::new(1, 1, 0));
        assert_eq!(s.count_ready("a", Some(1)), 1);
        assert_eq!(s.count_ready("a", Some(2)), 1);
        assert_eq!(s.count_ready("a", None), 2);
        let f = s.fetch_ready("a", Some(2), 10);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].key.version, 2);
    }

    #[test]
    fn complete_removes_rows_and_blobs() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        assert_eq!(s.total_blobs(), 3);
        let f = s.fetch_ready("a", None, 1);
        s.complete("a", &[f[0].key]).unwrap();
        assert_eq!(s.total_rows(), 0);
        assert_eq!(s.total_blobs(), 0);
    }

    #[test]
    fn requeue_returns_samples() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        let f = s.fetch_ready("a", None, 1);
        assert_eq!(s.count_ready("a", None), 0);
        s.requeue("a", &[f[0].key]).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
    }

    #[test]
    fn evict_stale_versions() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        fill(&s, "a", 2, SampleId::new(1, 1, 0));
        assert_eq!(s.evict_stale("a", 2), 1);
        assert_eq!(s.count_ready("a", None), 1);
        // Evicted rows release their blobs too.
        assert_eq!(s.total_blobs(), 3);
    }

    #[test]
    fn duplicate_and_type_errors() {
        let s = store_with("a");
        let id = SampleId::new(0, 1, 0);
        s.insert("a", 1, id).unwrap();
        assert!(matches!(
            s.insert("a", 1, id),
            Err(StoreError::DuplicateSample(_))
        ));
        assert!(matches!(
            s.set_value("a", 1, id, "reward", Value::Bool(true)),
            Err(StoreError::TypeMismatch { .. })
        ));
        assert!(matches!(
            s.set_value("a", 1, id, "nope", Value::Float(0.0)),
            Err(StoreError::NoSuchColumn(_))
        ));
        assert!(matches!(
            s.insert("b", 1, id),
            Err(StoreError::NoSuchTable(_))
        ));
    }

    #[test]
    fn per_agent_tables_are_independent() {
        let s = ExperienceStore::new();
        s.create_table("a", &grpo_schema());
        s.create_table("b", &[("reward", ColumnType::Float)]);
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        let id = SampleId::new(0, 1, 0); // same id, different table — fine
        s.insert("b", 1, id).unwrap();
        s.set_value("b", 1, id, "reward", Value::Float(1.0)).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
        assert_eq!(s.count_ready("b", None), 1);
    }

    #[test]
    fn fetch_order_is_deterministic() {
        let s = store_with("a");
        // Insert out of order.
        for &(inp, tr) in &[(3u64, 0u64), (1, 1), (1, 0), (2, 0)] {
            fill(&s, "a", 1, SampleId::new(inp, 1, tr));
        }
        let f = s.fetch_ready("a", None, 10);
        let ids: Vec<String> = f.iter().map(|x| x.key.id.to_string()).collect();
        assert_eq!(ids, vec!["1_1_0", "1_1_1", "2_1_0", "3_1_0"]);
    }

    #[test]
    fn slab_reuses_slots_after_complete() {
        let s = store_with("a");
        for round in 0..4u64 {
            for i in 0..8 {
                fill(&s, "a", 1, SampleId::new(round * 8 + i, 1, 0));
            }
            let f = s.fetch_ready("a", None, 8);
            assert_eq!(f.len(), 8);
            let keys: Vec<SampleKey> = f.iter().map(|x| x.key).collect();
            s.complete("a", &keys).unwrap();
        }
        assert_eq!(s.total_rows(), 0);
        assert_eq!(s.total_blobs(), 0);
    }

    #[test]
    fn put_rows_batch_and_take_batch_roundtrip() {
        let s = store_with("a");
        let rows: Vec<PutRow> = (0..16u64)
            .map(|i| PutRow {
                version: 1,
                id: SampleId::new(i, 1, 0),
                fields: vec![
                    ("prompt", Field::Blob(Blob::Tokens(vec![1; 4]))),
                    ("response", Field::Blob(Blob::Tokens(vec![2; 4]))),
                    ("old_logp", Field::Blob(Blob::Floats(vec![-0.5; 4]))),
                    ("reward", Field::Value(Value::Float(0.5))),
                    ("advantage", Field::Value(Value::Float(0.1))),
                ],
            })
            .collect();
        s.put_rows("a", rows).unwrap();
        assert_eq!(s.count_ready("a", Some(1)), 16);
        assert_eq!(s.total_blobs(), 48);
        let taken = s.take_batch("a", Some(1), 16);
        assert_eq!(taken.len(), 16);
        for t in &taken {
            assert!(matches!(t.blob("prompt"), Some(Blob::Tokens(v)) if v.len() == 4));
            assert!(matches!(t.blob("old_logp"), Some(Blob::Floats(_))));
            assert_eq!(t.value("reward"), Some(&Value::Float(0.5)));
        }
        // Fused consume: rows and blobs are gone.
        assert_eq!(s.total_rows(), 0);
        assert_eq!(s.total_blobs(), 0);
        assert!(s.take_batch("a", Some(1), 16).is_empty());
    }

    #[test]
    fn put_rows_error_releases_unapplied_blobs() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        let before = s.total_blobs();
        let rows = vec![
            PutRow {
                version: 1,
                id: SampleId::new(0, 1, 0), // duplicate → fails
                fields: vec![("prompt", Field::Blob(Blob::Tokens(vec![9])))],
            },
            PutRow {
                version: 1,
                id: SampleId::new(1, 1, 0),
                fields: vec![("prompt", Field::Blob(Blob::Tokens(vec![9])))],
            },
        ];
        assert!(matches!(
            s.put_rows("a", rows),
            Err(StoreError::DuplicateSample(_))
        ));
        // The failing row never inserted, so its parked blob and every
        // later row's parked blob were all released again.
        assert_eq!(s.total_blobs(), before);
        assert_eq!(s.total_rows(), 1);
    }

    #[test]
    fn meta_only_schema_rows_ready_on_insert() {
        let s = ExperienceStore::new();
        s.create_table("m", &[]);
        s.insert("m", 3, SampleId::new(0, 1, 0)).unwrap();
        assert_eq!(s.count_ready("m", Some(3)), 1);
        let f = s.fetch_ready("m", None, 4);
        assert_eq!(f.len(), 1);
        assert!(f[0].values.is_empty());
    }

    #[test]
    fn ready_index_matches_scan_path() {
        let s = store_with("a");
        for i in 0..10 {
            fill(&s, "a", 1 + i % 3, SampleId::new(i, 1, 0));
        }
        // Partially-filled row is in neither view.
        s.insert("a", 1, SampleId::new(99, 1, 0)).unwrap();
        let f = s.fetch_ready("a", Some(2), 2); // processing rows drop out
        assert_eq!(f.len(), 2);
        for v in [None, Some(1), Some(2), Some(3)] {
            assert_eq!(s.ready_keys("a", v), s.scan_ready_keys("a", v), "{v:?}");
        }
    }

    /// Satellite: the `processing` flag and status columns must
    /// round-trip identically through the scan path and the ready-set
    /// index — same samples dispatched in the same deterministic order,
    /// under arbitrary interleavings of the full mutation API.
    #[test]
    fn prop_ready_index_equals_scan_under_random_ops() {
        forall("ready index == scan path", 80, |rng| {
            let s = store_with("a");
            let mut next_input = 0u64;
            let mut dispatched: Vec<SampleKey> = Vec::new();
            let mut partial: Vec<SampleKey> = Vec::new();
            for _ in 0..120 {
                match rng.below(6) {
                    0 | 1 => {
                        // New fully-generated sample.
                        let v = 1 + rng.below(3);
                        fill(&s, "a", v, SampleId::new(next_input, 1, 0));
                        next_input += 1;
                    }
                    2 => {
                        // Partially-generated sample (status columns
                        // incomplete → must never appear ready).
                        let v = 1 + rng.below(3);
                        let id = SampleId::new(next_input, 1, 0);
                        next_input += 1;
                        s.insert("a", v, id).unwrap();
                        s.set_value("a", v, id, "reward", Value::Float(0.0)).unwrap();
                        partial.push(SampleKey { version: v, id });
                    }
                    3 => {
                        // Finish a pending partial row.
                        if let Some(k) = partial.pop() {
                            let (v, id) = (k.version, k.id);
                            s.set_blob("a", v, id, "prompt", Blob::Tokens(vec![1])).unwrap();
                            s.set_blob("a", v, id, "response", Blob::Tokens(vec![2])).unwrap();
                            s.set_blob("a", v, id, "old_logp", Blob::Floats(vec![-1.0])).unwrap();
                            s.set_value("a", v, id, "advantage", Value::Float(0.1)).unwrap();
                        }
                    }
                    4 => {
                        // Dispatch a batch; order must equal the scan
                        // path's prefix.
                        let version = if rng.below(2) == 0 {
                            None
                        } else {
                            Some(1 + rng.below(3))
                        };
                        let limit = rng.below(5) as usize + 1;
                        let expect: Vec<SampleKey> = s
                            .scan_ready_keys("a", version)
                            .into_iter()
                            .take(limit)
                            .collect();
                        let got: Vec<SampleKey> = s
                            .fetch_ready("a", version, limit)
                            .iter()
                            .map(|f| f.key)
                            .collect();
                        assert_eq!(got, expect, "dispatch order diverged");
                        dispatched.extend(got);
                    }
                    _ => {
                        // Resolve some dispatched rows: complete or
                        // requeue (the `processing` round-trip).
                        if let Some(k) = dispatched.pop() {
                            if rng.below(2) == 0 {
                                s.complete("a", &[k]).unwrap();
                            } else {
                                s.requeue("a", &[k]).unwrap();
                            }
                        }
                    }
                }
                for v in [None, Some(1), Some(2), Some(3)] {
                    let idx = s.ready_keys("a", v);
                    assert_eq!(idx, s.scan_ready_keys("a", v), "index/scan split at {v:?}");
                    assert_eq!(idx.len(), s.count_ready("a", v), "count_ready stale");
                }
            }
        });
    }

    #[test]
    fn prop_dispatch_exactly_once() {
        forall("store dispatches each ready sample exactly once", 60, |rng| {
            let s = store_with("a");
            let n = rng.below(40) as usize + 1;
            for i in 0..n {
                fill(&s, "a", 1, SampleId::new(i as u64, 1, 0));
            }
            let mut seen = std::collections::BTreeSet::new();
            loop {
                let batch = rng.below(7) as usize + 1;
                let f = s.fetch_ready("a", None, batch);
                if f.is_empty() {
                    break;
                }
                for x in &f {
                    assert!(seen.insert(x.key), "double dispatch {:?}", x.key);
                }
                // Randomly complete or requeue-and-refetch.
                let keys: Vec<SampleKey> = f.iter().map(|x| x.key).collect();
                if rng.f64() < 0.8 {
                    s.complete("a", &keys).unwrap();
                } else {
                    s.requeue("a", &keys).unwrap();
                    for k in &keys {
                        seen.remove(k);
                    }
                }
            }
            assert_eq!(seen.len(), n);
            assert_eq!(s.total_rows(), 0);
        });
    }
}
