//! Experience store (§4.2): the structured data plane between rollout
//! and training under the disaggregated architecture.
//!
//! Multi-table organization: each agent gets a dedicated table (enabling
//! heterogeneous policies/configs per agent, §4.3). Each table has three
//! column categories:
//!  * meta-information — `policy_version`, `sample_id`
//!    (`{input_id}_{number_of_turns}_{trajectory_id}`, globally unique,
//!    deterministically ordered, traceable), and a `processing` flag
//!    (read-but-not-yet-updated);
//!  * data columns — user-defined fields (prompt, response, rewards…);
//!  * status columns — one boolean per data column: fully generated?
//!
//! Type-aware hybrid storage: simple scalars (int/float/bool) are stored
//! by value in the table; complex payloads (strings, token lists,
//! tensors) are stored by reference — the table records only the location
//! key of a blob parked in the store's arena (standing in for the
//! Set/Get heterogeneous-object plane of §7).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

// ---------------------------------------------------------------------------
// Sample identity
// ---------------------------------------------------------------------------

/// `sample_id = {input_id}_{number_of_turns}_{trajectory_id}` (§4.2).
/// Ordering is lexicographic on the numeric triple → deterministic
/// dispatch order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SampleId {
    pub input_id: u64,
    pub turns: u32,
    pub trajectory_id: u64,
}

impl SampleId {
    pub fn new(input_id: u64, turns: u32, trajectory_id: u64) -> Self {
        SampleId {
            input_id,
            turns,
            trajectory_id,
        }
    }
}

impl fmt::Display for SampleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}_{}_{}", self.input_id, self.turns, self.trajectory_id)
    }
}

/// Combined with `policy_version`, the identifier is globally unique
/// across asynchronous retries of the same trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SampleKey {
    pub version: u64,
    pub id: SampleId,
}

// ---------------------------------------------------------------------------
// Hybrid value model
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnType {
    Int,
    Float,
    Bool,
    /// Complex payload — stored by reference.
    Blob,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    /// Location key into the blob arena.
    Ref(u64),
}

impl Value {
    pub fn column_type(&self) -> ColumnType {
        match self {
            Value::Int(_) => ColumnType::Int,
            Value::Float(_) => ColumnType::Float,
            Value::Bool(_) => ColumnType::Bool,
            Value::Ref(_) => ColumnType::Blob,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// Opaque complex payloads (token sequences, logprob rows, tensors).
#[derive(Debug, Clone, PartialEq)]
pub enum Blob {
    Tokens(Vec<i32>),
    Floats(Vec<f32>),
    Text(String),
}

// ---------------------------------------------------------------------------
// Table
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct Row {
    /// Data column values (None until first write).
    values: Vec<Option<Value>>,
    /// Paired status columns: value fully generated?
    status: Vec<bool>,
    /// Read-but-not-yet-consumed (dispatched to a trainer).
    processing: bool,
    /// Insertion sequence — FIFO tie-break within a version.
    seq: u64,
}

/// One agent's table.
#[derive(Debug)]
pub struct Table {
    pub agent: String,
    schema: Vec<(String, ColumnType)>,
    rows: BTreeMap<SampleKey, Row>,
    seq: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    NoSuchTable(String),
    NoSuchColumn(String),
    TypeMismatch { column: String, expected: ColumnType },
    DuplicateSample(SampleKey),
    UnknownSample(SampleKey),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::NoSuchTable(a) => write!(f, "no table for agent {a}"),
            StoreError::NoSuchColumn(c) => write!(f, "no column {c}"),
            StoreError::TypeMismatch { column, expected } => {
                write!(f, "column {column} expects {expected:?}")
            }
            StoreError::DuplicateSample(k) => write!(f, "duplicate sample {} v{}", k.id, k.version),
            StoreError::UnknownSample(k) => write!(f, "unknown sample {} v{}", k.id, k.version),
        }
    }
}

impl std::error::Error for StoreError {}

impl Table {
    fn col(&self, name: &str) -> Result<usize, StoreError> {
        self.schema
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| StoreError::NoSuchColumn(name.to_string()))
    }

    fn insert(&mut self, key: SampleKey) -> Result<(), StoreError> {
        if self.rows.contains_key(&key) {
            return Err(StoreError::DuplicateSample(key));
        }
        let n = self.schema.len();
        self.rows.insert(
            key,
            Row {
                values: vec![None; n],
                status: vec![false; n],
                processing: false,
                seq: self.seq,
            },
        );
        self.seq += 1;
        Ok(())
    }

    fn set(&mut self, key: SampleKey, column: &str, value: Value) -> Result<(), StoreError> {
        let ci = self.col(column)?;
        let expected = self.schema[ci].1;
        if value.column_type() != expected {
            return Err(StoreError::TypeMismatch {
                column: column.to_string(),
                expected,
            });
        }
        let row = self
            .rows
            .get_mut(&key)
            .ok_or(StoreError::UnknownSample(key))?;
        row.values[ci] = Some(value);
        row.status[ci] = true;
        Ok(())
    }

    fn ready(&self, key: &SampleKey) -> bool {
        self.rows
            .get(key)
            .map(|r| !r.processing && r.status.iter().all(|&s| s))
            .unwrap_or(false)
    }
}

// ---------------------------------------------------------------------------
// Store
// ---------------------------------------------------------------------------

/// A sample handed to the training engine.
#[derive(Debug, Clone)]
pub struct FetchedSample {
    pub key: SampleKey,
    pub values: Vec<(String, Value)>,
}

impl FetchedSample {
    pub fn value(&self, column: &str) -> Option<&Value> {
        self.values
            .iter()
            .find(|(n, _)| n == column)
            .map(|(_, v)| v)
    }
}

#[derive(Default)]
struct Inner {
    tables: BTreeMap<String, Table>,
    blobs: BTreeMap<u64, Blob>,
}

/// The experience store: thread-safe (rollout workers produce, trainer
/// process groups consume), deterministic dispatch order.
pub struct ExperienceStore {
    inner: Mutex<Inner>,
    next_blob: AtomicU64,
}

impl Default for ExperienceStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ExperienceStore {
    pub fn new() -> Self {
        ExperienceStore {
            inner: Mutex::new(Inner::default()),
            next_blob: AtomicU64::new(1),
        }
    }

    /// Create (or replace) an agent's table with the given data columns.
    pub fn create_table(&self, agent: &str, schema: &[(&str, ColumnType)]) {
        let mut g = self.inner.lock().unwrap();
        g.tables.insert(
            agent.to_string(),
            Table {
                agent: agent.to_string(),
                schema: schema
                    .iter()
                    .map(|(n, t)| (n.to_string(), *t))
                    .collect(),
                rows: BTreeMap::new(),
                seq: 0,
            },
        );
    }

    pub fn agents(&self) -> Vec<String> {
        self.inner.lock().unwrap().tables.keys().cloned().collect()
    }

    /// Register a new sample row (meta columns only).
    pub fn insert(&self, agent: &str, version: u64, id: SampleId) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        let t = g
            .tables
            .get_mut(agent)
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))?;
        t.insert(SampleKey { version, id })
    }

    /// Write a scalar field; marks its status column generated.
    pub fn set_value(
        &self,
        agent: &str,
        version: u64,
        id: SampleId,
        column: &str,
        value: Value,
    ) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        let t = g
            .tables
            .get_mut(agent)
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))?;
        t.set(SampleKey { version, id }, column, value)
    }

    /// Write a complex payload: parks the blob, stores the reference
    /// (type-aware hybrid storage).
    pub fn set_blob(
        &self,
        agent: &str,
        version: u64,
        id: SampleId,
        column: &str,
        blob: Blob,
    ) -> Result<u64, StoreError> {
        let blob_key = self.next_blob.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        let t = g
            .tables
            .get_mut(agent)
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))?;
        t.set(SampleKey { version, id }, column, Value::Ref(blob_key))?;
        g.blobs.insert(blob_key, blob);
        Ok(blob_key)
    }

    pub fn blob(&self, key: u64) -> Option<Blob> {
        self.inner.lock().unwrap().blobs.get(&key).cloned()
    }

    /// Number of fully-generated, not-yet-dispatched samples — the
    /// micro-batch trigger input (§4.3).
    pub fn count_ready(&self, agent: &str, version: Option<u64>) -> usize {
        let g = self.inner.lock().unwrap();
        g.tables
            .get(agent)
            .map(|t| {
                t.rows
                    .keys()
                    .filter(|k| version.map(|v| k.version == v).unwrap_or(true))
                    .filter(|k| t.ready(k))
                    .count()
            })
            .unwrap_or(0)
    }

    /// Dispatch up to `limit` ready samples (deterministic order: version,
    /// then sample id), marking them `processing` so concurrent fetches
    /// never double-dispatch. `version` filters to one policy snapshot —
    /// the consistency guarantee that keeps training on-policy.
    pub fn fetch_ready(
        &self,
        agent: &str,
        version: Option<u64>,
        limit: usize,
    ) -> Vec<FetchedSample> {
        let mut g = self.inner.lock().unwrap();
        let Inner { tables, blobs: _ } = &mut *g;
        let Some(t) = tables.get_mut(agent) else {
            return Vec::new();
        };
        let keys: Vec<SampleKey> = t
            .rows
            .iter()
            .filter(|(k, r)| {
                version.map(|v| k.version == v).unwrap_or(true)
                    && !r.processing
                    && r.status.iter().all(|&s| s)
            })
            .map(|(k, _)| *k)
            .take(limit)
            .collect();
        let mut out = Vec::with_capacity(keys.len());
        for k in keys {
            let row = t.rows.get_mut(&k).unwrap();
            row.processing = true;
            let values = t
                .schema
                .iter()
                .zip(&row.values)
                .map(|((n, _), v)| (n.clone(), v.clone().unwrap()))
                .collect();
            out.push(FetchedSample { key: k, values });
        }
        out
    }

    /// Consume dispatched samples after their gradient is computed
    /// (removes rows and their blobs).
    pub fn complete(&self, agent: &str, keys: &[SampleKey]) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        let t = g
            .tables
            .get_mut(agent)
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))?;
        let mut blob_keys = Vec::new();
        for k in keys {
            let row = t.rows.remove(k).ok_or(StoreError::UnknownSample(*k))?;
            for v in row.values.into_iter().flatten() {
                if let Value::Ref(b) = v {
                    blob_keys.push(b);
                }
            }
        }
        for b in blob_keys {
            g.blobs.remove(&b);
        }
        Ok(())
    }

    /// Fault tolerance: a trainer died — return its samples to the pool.
    pub fn requeue(&self, agent: &str, keys: &[SampleKey]) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        let t = g
            .tables
            .get_mut(agent)
            .ok_or_else(|| StoreError::NoSuchTable(agent.to_string()))?;
        for k in keys {
            let row = t.rows.get_mut(k).ok_or(StoreError::UnknownSample(*k))?;
            row.processing = false;
        }
        Ok(())
    }

    /// Drop all rows belonging to policy versions older than `min_version`
    /// (stale data from cancelled asynchronous rollouts).
    pub fn evict_stale(&self, agent: &str, min_version: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let Some(t) = g.tables.get_mut(agent) else {
            return 0;
        };
        let stale: Vec<SampleKey> = t
            .rows
            .keys()
            .filter(|k| k.version < min_version)
            .copied()
            .collect();
        for k in &stale {
            t.rows.remove(k);
        }
        stale.len()
    }

    pub fn total_rows(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.tables.values().map(|t| t.rows.len()).sum()
    }

    pub fn total_blobs(&self) -> usize {
        self.inner.lock().unwrap().blobs.len()
    }
}

/// The standard GRPO sample schema used by the orchestrator.
pub fn grpo_schema() -> Vec<(&'static str, ColumnType)> {
    vec![
        ("prompt", ColumnType::Blob),
        ("response", ColumnType::Blob),
        ("old_logp", ColumnType::Blob),
        ("reward", ColumnType::Float),
        ("advantage", ColumnType::Float),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn store_with(agent: &str) -> ExperienceStore {
        let s = ExperienceStore::new();
        s.create_table(agent, &grpo_schema());
        s
    }

    fn fill(s: &ExperienceStore, agent: &str, v: u64, id: SampleId) {
        s.insert(agent, v, id).unwrap();
        s.set_blob(agent, v, id, "prompt", Blob::Tokens(vec![1, 2])).unwrap();
        s.set_blob(agent, v, id, "response", Blob::Tokens(vec![3])).unwrap();
        s.set_blob(agent, v, id, "old_logp", Blob::Floats(vec![-0.5])).unwrap();
        s.set_value(agent, v, id, "reward", Value::Float(0.7)).unwrap();
        s.set_value(agent, v, id, "advantage", Value::Float(0.1)).unwrap();
    }

    #[test]
    fn sample_id_format_and_order() {
        let id = SampleId::new(12, 3, 7);
        assert_eq!(id.to_string(), "12_3_7");
        let a = SampleId::new(1, 1, 1);
        let b = SampleId::new(1, 2, 0);
        let c = SampleId::new(2, 0, 0);
        assert!(a < b && b < c);
    }

    #[test]
    fn partial_rows_are_not_ready() {
        let s = store_with("a");
        let id = SampleId::new(0, 1, 0);
        s.insert("a", 1, id).unwrap();
        assert_eq!(s.count_ready("a", None), 0);
        s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1])).unwrap();
        s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2])).unwrap();
        s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-1.0])).unwrap();
        s.set_value("a", 1, id, "reward", Value::Float(1.0)).unwrap();
        assert_eq!(s.count_ready("a", None), 0); // advantage still missing
        s.set_value("a", 1, id, "advantage", Value::Float(0.5)).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
    }

    #[test]
    fn fetch_marks_processing_no_double_dispatch() {
        let s = store_with("a");
        for i in 0..5 {
            fill(&s, "a", 1, SampleId::new(i, 1, 0));
        }
        let first = s.fetch_ready("a", Some(1), 3);
        assert_eq!(first.len(), 3);
        let second = s.fetch_ready("a", Some(1), 10);
        assert_eq!(second.len(), 2); // only the remaining two
        let third = s.fetch_ready("a", Some(1), 10);
        assert!(third.is_empty());
    }

    #[test]
    fn version_filtering_keeps_on_policy() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        fill(&s, "a", 2, SampleId::new(1, 1, 0));
        assert_eq!(s.count_ready("a", Some(1)), 1);
        assert_eq!(s.count_ready("a", Some(2)), 1);
        assert_eq!(s.count_ready("a", None), 2);
        let f = s.fetch_ready("a", Some(2), 10);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].key.version, 2);
    }

    #[test]
    fn complete_removes_rows_and_blobs() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        assert_eq!(s.total_blobs(), 3);
        let f = s.fetch_ready("a", None, 1);
        s.complete("a", &[f[0].key]).unwrap();
        assert_eq!(s.total_rows(), 0);
        assert_eq!(s.total_blobs(), 0);
    }

    #[test]
    fn requeue_returns_samples() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        let f = s.fetch_ready("a", None, 1);
        assert_eq!(s.count_ready("a", None), 0);
        s.requeue("a", &[f[0].key]).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
    }

    #[test]
    fn evict_stale_versions() {
        let s = store_with("a");
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        fill(&s, "a", 2, SampleId::new(1, 1, 0));
        assert_eq!(s.evict_stale("a", 2), 1);
        assert_eq!(s.count_ready("a", None), 1);
    }

    #[test]
    fn duplicate_and_type_errors() {
        let s = store_with("a");
        let id = SampleId::new(0, 1, 0);
        s.insert("a", 1, id).unwrap();
        assert!(matches!(
            s.insert("a", 1, id),
            Err(StoreError::DuplicateSample(_))
        ));
        assert!(matches!(
            s.set_value("a", 1, id, "reward", Value::Bool(true)),
            Err(StoreError::TypeMismatch { .. })
        ));
        assert!(matches!(
            s.set_value("a", 1, id, "nope", Value::Float(0.0)),
            Err(StoreError::NoSuchColumn(_))
        ));
        assert!(matches!(
            s.insert("b", 1, id),
            Err(StoreError::NoSuchTable(_))
        ));
    }

    #[test]
    fn per_agent_tables_are_independent() {
        let s = ExperienceStore::new();
        s.create_table("a", &grpo_schema());
        s.create_table("b", &[("reward", ColumnType::Float)]);
        fill(&s, "a", 1, SampleId::new(0, 1, 0));
        let id = SampleId::new(0, 1, 0); // same id, different table — fine
        s.insert("b", 1, id).unwrap();
        s.set_value("b", 1, id, "reward", Value::Float(1.0)).unwrap();
        assert_eq!(s.count_ready("a", None), 1);
        assert_eq!(s.count_ready("b", None), 1);
    }

    #[test]
    fn fetch_order_is_deterministic() {
        let s = store_with("a");
        // Insert out of order.
        for &(inp, tr) in &[(3u64, 0u64), (1, 1), (1, 0), (2, 0)] {
            fill(&s, "a", 1, SampleId::new(inp, 1, tr));
        }
        let f = s.fetch_ready("a", None, 10);
        let ids: Vec<String> = f.iter().map(|x| x.key.id.to_string()).collect();
        assert_eq!(ids, vec!["1_1_0", "1_1_1", "2_1_0", "3_1_0"]);
    }

    #[test]
    fn prop_dispatch_exactly_once() {
        forall("store dispatches each ready sample exactly once", 60, |rng| {
            let s = store_with("a");
            let n = rng.below(40) as usize + 1;
            for i in 0..n {
                fill(&s, "a", 1, SampleId::new(i as u64, 1, 0));
            }
            let mut seen = std::collections::BTreeSet::new();
            loop {
                let batch = rng.below(7) as usize + 1;
                let f = s.fetch_ready("a", None, batch);
                if f.is_empty() {
                    break;
                }
                for x in &f {
                    assert!(seen.insert(x.key), "double dispatch {:?}", x.key);
                }
                // Randomly complete or requeue-and-refetch.
                let keys: Vec<SampleKey> = f.iter().map(|x| x.key).collect();
                if rng.f64() < 0.8 {
                    s.complete("a", &keys).unwrap();
                } else {
                    s.requeue("a", &keys).unwrap();
                    for k in &keys {
                        seen.remove(k);
                    }
                }
            }
            assert_eq!(seen.len(), n);
            assert_eq!(s.total_rows(), 0);
        });
    }
}
